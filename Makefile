# Convenience targets. The Rust build needs no artifacts; `make artifacts`
# requires a python environment with jax (the AOT layer is optional).

.PHONY: build test artifacts artifacts-quick bench bench-fast tcp-smoke chaos-smoke metrics-smoke fmt

build:
	cargo build --release

test:
	cargo test -q

# AOT-lower the Pallas kernels to HLO text for the PJRT runtime
# (used by `--kernel boruvka-xla` in builds with --features backend-xla).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-quick:
	cd python && python -m compile.aot --out-dir ../artifacts --quick

# Run both recorded bench binaries (fast shapes), verify no bench section
# disappeared from the BENCH_e7/e8 JSON schemas, and run the multi-process
# loopback smoke. CI runs the same sequence in the bench-smoke + tcp-smoke
# jobs.
bench:
	DEMST_BENCH_FAST=1 cargo bench --bench e7_kernel
	DEMST_BENCH_FAST=1 cargo bench --bench e8_end_to_end
	python3 scripts/check_bench_schema.py BENCH_e7.json BENCH_e8.json
	$(MAKE) tcp-smoke
	$(MAKE) chaos-smoke

# Loopback multi-process smoke: leader + 2 `demst worker` processes on
# 127.0.0.1, asserting exit 0 and a sim-identical MST checksum.
tcp-smoke: build
	./scripts/tcp_smoke.sh

# Elastic failover smoke: 2 workers, one dies abruptly (SIGKILL-style, via
# the DEMST_CHAOS_EXIT_AFTER_JOBS hook) around 50% of its deck; asserts
# exit 0, a sim-identical MST checksum, and a reported reassignment.
chaos-smoke: build
	./scripts/chaos_smoke.sh

# Fleet-metrics smoke: scrape the leader's live /metrics mid-run, validate
# the exposition + report histograms, and exercise the `report diff`
# regression gates (including an injected regression that must trip them).
metrics-smoke: build
	./scripts/metrics_smoke.sh

# Quick benchmark sweep (reduced shapes/samples); e7 writes BENCH_e7.json.
bench-fast:
	DEMST_BENCH_FAST=1 cargo bench --bench e7_kernel

fmt:
	cargo fmt --all
