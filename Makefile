# Convenience targets. The Rust build needs no artifacts; `make artifacts`
# requires a python environment with jax (the AOT layer is optional).

.PHONY: build test artifacts artifacts-quick bench bench-fast fmt

build:
	cargo build --release

test:
	cargo test -q

# AOT-lower the Pallas kernels to HLO text for the PJRT runtime
# (used by `--kernel boruvka-xla` in builds with --features backend-xla).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

artifacts-quick:
	cd python && python -m compile.aot --out-dir ../artifacts --quick

# Run both recorded bench binaries (fast shapes) and verify no bench
# section disappeared from the BENCH_e7/e8 JSON schemas. CI runs the same
# sequence in the bench-smoke job.
bench:
	DEMST_BENCH_FAST=1 cargo bench --bench e7_kernel
	DEMST_BENCH_FAST=1 cargo bench --bench e8_end_to_end
	python3 scripts/check_bench_schema.py BENCH_e7.json BENCH_e8.json

# Quick benchmark sweep (reduced shapes/samples); e7 writes BENCH_e7.json.
bench-fast:
	DEMST_BENCH_FAST=1 cargo bench --bench e7_kernel

fmt:
	cargo fmt --all
