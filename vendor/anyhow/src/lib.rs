//! A vendored, API-compatible subset of the `anyhow` crate.
//!
//! This workspace builds fully offline (no crates.io access), so the error
//! type the codebase leans on is provided here as a small local crate with
//! the same surface the code actually uses:
//!
//! - [`Error`] / [`Result`] with context chains
//! - [`anyhow!`], [`bail!`], [`ensure!`]
//! - [`Context`] for `Result<T, E: std::error::Error>`, `Result<T, Error>`,
//!   and `Option<T>`
//! - `Display` prints the outermost message; `{:#}` prints the full chain
//!   colon-separated; `Debug` prints the message plus a `Caused by:` list —
//!   all matching upstream `anyhow` conventions.
//!
//! If the build ever regains registry access, deleting this crate and
//! pointing the workspace at `anyhow = "1"` is a drop-in change.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context frames (outermost first).
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first; the last entry is the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Self { frames }
    }
}

/// Conversion into [`Error`] — implemented for std errors and for `Error`
/// itself (mirrors anyhow's internal `ext::StdError`), so [`Context`] works
/// on both `Result<T, E: std::error::Error>` and `Result<T, Error>`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Attach context to errors (and convert `Option` to `Result`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($args:tt)*) => {
        return Err($crate::anyhow!($($args)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($args:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($args)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file gone");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Err::<(), _>(io_err())
            .context("layer one")
            .context("layer two")
            .unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("layer two"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("file gone"), "{dbg}");
    }

    #[test]
    fn macros_format() {
        fn fails(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails with {}", 42)
        }
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(fails(true).unwrap_err().to_string(), "always fails with 42");
        let e = anyhow!("literal");
        assert_eq!(e.to_string(), "literal");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let some: Option<u32> = Some(3);
        assert_eq!(some.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn result_error_context_chains() {
        fn inner() -> Result<()> {
            bail!("root cause")
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
        assert_eq!(e.root_cause(), "root cause");
        assert_eq!(e.chain().count(), 2);
    }
}
