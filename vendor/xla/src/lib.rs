//! Offline **API stub** of the `xla` crate (PJRT bindings over
//! `xla_extension`), exposing exactly the surface `demst::runtime` consumes.
//!
//! Why a stub: this workspace must compile with `--features backend-xla` in a
//! container with no crates.io access and no `xla_extension` shared library.
//! The stub keeps the PJRT code path *compiling* (types, signatures, error
//! plumbing) while every operation that would require the real runtime
//! returns a descriptive error. Deployments with the real library swap the
//! `[dependencies] xla` path in the workspace `Cargo.toml` for the actual
//! crate — no demst source change needed, the API is signature-compatible.
//!
//! Behavior contract the stub honors (relied on by `demst` failure-path
//! tests):
//! - `PjRtClient::cpu()` succeeds (creating a client allocates nothing).
//! - `HloModuleProto::from_text_file` reads the file (so missing-file errors
//!   name the path) and then fails parsing with a "stub" error.
//! - Everything downstream of a successful parse is unreachable offline.

use std::fmt;
use std::path::Path;

/// Stub error type; `Debug`-formatted into `anyhow` messages by callers.
pub struct XlaError(String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

const STUB_NOTE: &str =
    "xla stub: PJRT runtime not linked (vendor/xla is an offline API stub; \
     point the workspace at the real `xla` crate to execute artifacts)";

/// PJRT client handle (stub: no device behind it).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client. Succeeds in the stub so that artifact
    /// *metadata* paths (manifest listing, bucket selection, parse-failure
    /// reporting) behave identically with and without the real runtime.
    pub fn cpu() -> Result<Self, XlaError> {
        Ok(Self { _private: () })
    }

    /// Compile a computation. Unreachable offline (parsing fails first);
    /// errors defensively if reached.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }
}

/// Parsed HLO module proto (stub: never successfully constructed).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Read and "parse" an HLO text file. The read is real — missing files
    /// produce errors naming the path — the parse always fails in the stub.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self, XlaError> {
        let path = path.as_ref();
        match std::fs::read_to_string(path) {
            Ok(_) => Err(XlaError(format!("cannot parse {}: {STUB_NOTE}", path.display()))),
            Err(e) => Err(XlaError(format!("reading {}: {e}", path.display()))),
        }
    }
}

/// An XLA computation built from a module proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A compiled executable (stub: never constructed offline).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments, returning per-device output buffers.
    pub fn execute<T: BufferArgument>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }
}

/// Types accepted as executable arguments.
pub trait BufferArgument {}

impl BufferArgument for Literal {}

/// Element types a literal can be read back as.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side literal (stub: shape metadata only, no data plane).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Self {
        Self { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Self { _private: () })
    }

    /// Destructure a 1-tuple output.
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }

    /// Destructure a 2-tuple output.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError(STUB_NOTE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creates_and_parse_fails_with_path() {
        let _client = PjRtClient::cpu().unwrap();
        let err = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("x.hlo.txt"), "{msg}");
    }

    #[test]
    fn existing_file_fails_as_stub_parse() {
        let dir = std::env::temp_dir().join("xla_stub_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule m").unwrap();
        let err = HloModuleProto::from_text_file(&path).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("m.hlo.txt"), "{msg}");
    }
}
