#!/usr/bin/env python3
"""Run-report guard: validate a ``demst run --report-out`` JSON document.

Run by the CI tcp-smoke / chaos-smoke jobs (and ``make`` smoke targets)
against the freshly written report. It fails loudly when the report stops
being machine-readable or its numbers stop reconciling — e.g. a refactor
dropping a metrics field, the span digest drifting from the counters, or
the per-worker roster losing a mid-run-admitted worker.

Checks:
- schema: versioned top level, config fingerprint, required metric keys,
  per-worker roster sized ``config.workers + workers_admitted``;
- accounting: ``dist_evals == local_mst_evals + pair_evals`` exactly;
- span digest (when tracing was on): one job span per executed pair job;
  span eval sums reconcile with the counters — exactly on clean runs,
  as a lower bound under ``--chaos`` (a killed worker's spans are
  synthesized at the leader with zero eval args);
- histograms (when the run was metrics-armed): per histogram, the occupied
  bucket counts sum to ``count``; on clean runs the fleet-merged pair-job
  latency histogram counts every executed job exactly (skipped under
  ``--chaos``: a killed worker's final snapshot never ships, while a job
  it had already pushed metrics for is recounted by the survivor);
- ``--trace TRACE.json``: the Chrome-trace export parses as JSON, carries
  one ``job`` duration event per pair job, and (under ``--chaos``) the
  failure shows up as a ``stall``/``failover`` instant.

Usage: check_run_report.py RUN.json [--trace TRACE.json] [--chaos]
"""

import json
import sys

REQUIRED_TOP_KEYS = {"report_version", "tool", "config", "metrics", "workers",
                     "histograms", "spans"}
REQUIRED_METRIC_KEYS = {
    "wall_s", "jobs", "dist_evals", "local_mst_evals", "pair_evals",
    "scatter_bytes", "gather_bytes", "control_bytes", "messages",
    "union_edges", "jobs_stolen", "panel_hits", "panel_misses",
    "panel_flops", "reduce_folds", "reduce_fold_edges", "peer_bytes",
    "peer_ships", "worker_failures", "jobs_reassigned", "stalls_detected",
    "heartbeats_sent", "workers_admitted", "chaos_faults_injected",
    "busy_efficiency", "imbalance",
}


def check_report(path, chaos):
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return None, [f"{path}: unreadable ({e})"]
    missing = REQUIRED_TOP_KEYS - doc.keys()
    if missing:
        return None, [f"{path}: missing top-level keys {sorted(missing)}"]
    if doc["report_version"] != 1:
        errors.append(f"{path}: report_version {doc['report_version']!r} != 1")
    if doc["tool"] != "demst":
        errors.append(f"{path}: tool {doc['tool']!r} != 'demst'")

    config = doc["config"]
    fp = config.get("fingerprint", "")
    if not (isinstance(fp, str) and fp.startswith("0x") and len(fp) == 18):
        errors.append(f"{path}: config.fingerprint {fp!r} is not an 0x-prefixed u64")

    metrics = doc["metrics"]
    lost = REQUIRED_METRIC_KEYS - metrics.keys()
    if lost:
        errors.append(f"{path}: metrics keys disappeared: {sorted(lost)}")
        return doc, errors

    if metrics["dist_evals"] != metrics["local_mst_evals"] + metrics["pair_evals"]:
        errors.append(
            f"{path}: eval decomposition broken: dist_evals "
            f"{metrics['dist_evals']} != local_mst {metrics['local_mst_evals']}"
            f" + pair {metrics['pair_evals']}")

    # satellite: the roster must cover the *final* fleet — starting workers
    # plus every mid-run admission
    expect_roster = config.get("workers", 0) + metrics["workers_admitted"]
    if len(doc["workers"]) != expect_roster:
        errors.append(
            f"{path}: per-worker roster has {len(doc['workers'])} rows, "
            f"expected {expect_roster} (workers + workers_admitted)")

    hists = doc["histograms"]
    for fam, h in hists.items():
        if not isinstance(h, dict) or "buckets" not in h:
            continue  # scalar annotations like workers_reporting
        occupied = sum(b.get("count", 0) for b in h["buckets"])
        if occupied != h.get("count"):
            errors.append(
                f"{path}: histogram {fam}: occupied buckets sum to "
                f"{occupied}, count says {h.get('count')}")
    latency = hists.get("job_latency_seconds")
    if isinstance(latency, dict) and not chaos:
        # exact only on clean runs: under chaos a killed worker's final
        # snapshot never ships (undercount) while a reassigned job it had
        # already pushed metrics for is recounted by the survivor
        got = latency.get("count", 0)
        if got != metrics["jobs"]:
            errors.append(
                f"{path}: latency histogram counts {got} jobs, expected "
                f"exactly {metrics['jobs']}")

    spans = doc["spans"]
    if spans.get("total", 0) > 0:
        by_kind = spans.get("by_kind", {})
        if by_kind.get("job", 0) != metrics["jobs"]:
            errors.append(
                f"{path}: {by_kind.get('job', 0)} job spans for "
                f"{metrics['jobs']} executed jobs")
        job_evals = spans.get("job_evals", 0)
        if chaos:
            # a killed worker's job spans are synthesized with arg 0
            if job_evals > metrics["pair_evals"]:
                errors.append(
                    f"{path}: job span evals {job_evals} exceed pair_evals "
                    f"{metrics['pair_evals']}")
        elif job_evals != metrics["pair_evals"]:
            errors.append(
                f"{path}: job span evals {job_evals} != pair_evals "
                f"{metrics['pair_evals']}")
    return doc, errors


def check_trace(path, report, chaos):
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: not valid Chrome-trace JSON ({e})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents"]
    for ev in events:
        if not {"name", "ph", "pid", "tid"} <= ev.keys():
            errors.append(f"{path}: malformed event {ev!r}")
            break
    jobs = [e for e in events if e.get("name") == "job" and e.get("ph") == "X"]
    expect = report["metrics"]["jobs"] if report else None
    if expect is not None and len(jobs) != expect:
        errors.append(f"{path}: {len(jobs)} job slices for {expect} executed jobs")
    if not any(e.get("ph") == "M" and e.get("name") == "thread_name"
               for e in events):
        errors.append(f"{path}: no named tracks (thread_name metadata)")
    if chaos and not any(e.get("name") in ("stall", "failover", "admit")
                         and e.get("ph") == "i" for e in events):
        errors.append(f"{path}: chaos run but no stall/failover/admit instant")
    return errors


def main(argv):
    if not argv:
        print("usage: check_run_report.py RUN.json [--trace TRACE.json] "
              "[--chaos]", file=sys.stderr)
        return 2
    chaos = "--chaos" in argv
    argv = [a for a in argv if a != "--chaos"]
    trace_path = None
    if "--trace" in argv:
        i = argv.index("--trace")
        try:
            trace_path = argv[i + 1]
        except IndexError:
            print("--trace requires a path", file=sys.stderr)
            return 2
        del argv[i:i + 2]
    if len(argv) != 1:
        print("exactly one RUN.json expected", file=sys.stderr)
        return 2

    report, errors = check_report(argv[0], chaos)
    if trace_path:
        errors.extend(check_trace(trace_path, report, chaos))
    for err in errors:
        print(f"REPORT ERROR: {err}", file=sys.stderr)
    if not errors:
        checked = argv[0] if not trace_path else f"{argv[0]} + {trace_path}"
        print(f"run report OK: {checked}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
