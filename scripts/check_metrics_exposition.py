#!/usr/bin/env python3
"""Exposition guard: validate a scrape of ``demst run --metrics-listen``.

Run by the CI metrics-smoke job against text curl'ed from the leader's
``/metrics`` endpoint *mid-run*. It fails loudly when the hand-rolled
Prometheus text format 0.0.4 rendering goes wrong — a malformed sample
line, a histogram whose bucket series stops being cumulative, a missing
``+Inf`` bucket, or a family losing its ``# HELP``/``# TYPE`` header.

Checks:
- every non-comment line parses as ``name[{labels}] value`` with the
  ``demst_`` prefix and a numeric value;
- every ``# TYPE`` family also has a ``# HELP`` line;
- histogram families: ``le`` bounds strictly ascend, bucket counts are
  cumulative (non-decreasing), the series ends with ``le="+Inf"`` whose
  value equals ``_count``, and ``_sum`` is present;
- the fleet-merged pair-job latency histogram family is present;
  ``--min-job-count N`` additionally requires its ``_count`` >= N (how the
  smoke loop detects that a mid-run scrape has seen real pair jobs).

Usage: check_metrics_exposition.py SCRAPE.txt [--min-job-count N]
"""

import re
import sys

SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)(?P<labels>\{[^}]*\})? (?P<value>\S+)$')

REQUIRED_FAMILIES = {
    "demst_fleet_workers",
    "demst_jobs_completed_total",
    "demst_dist_evals_total",
    "demst_job_latency_seconds",
}


def parse(text):
    """Return (helps, types, samples, errors)."""
    helps, types, samples, errors = set(), {}, [], []
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split()
            if len(parts) < 4:
                errors.append(f"line {ln}: HELP without text: {line!r}")
            if len(parts) >= 3:
                helps.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                errors.append(f"line {ln}: malformed TYPE: {line!r}")
                continue
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE.match(line)
        if not m:
            errors.append(f"line {ln}: malformed sample: {line!r}")
            continue
        name = m.group("name")
        if not name.startswith("demst_"):
            errors.append(f"line {ln}: {name} lacks the demst_ prefix")
        labels = {}
        if m.group("labels"):
            for part in m.group("labels")[1:-1].split(","):
                if not part:
                    continue
                k, _, v = part.partition("=")
                labels[k] = v.strip('"')
        try:
            value = float(m.group("value"))
        except ValueError:
            errors.append(f"line {ln}: non-numeric value {m.group('value')!r}")
            continue
        samples.append((name, labels, value))
    return helps, types, samples, errors


def check_histogram(fam, samples, errors):
    buckets = [(l.get("le"), v) for n, l, v in samples if n == f"{fam}_bucket"]
    counts = [v for n, _, v in samples if n == f"{fam}_count"]
    sums = [v for n, _, v in samples if n == f"{fam}_sum"]
    if len(counts) != 1 or len(sums) != 1:
        errors.append(f"{fam}: expected exactly one _count and one _sum")
        return
    if not buckets or buckets[-1][0] != "+Inf":
        errors.append(f'{fam}: bucket series must end with le="+Inf"')
        return
    vals = [v for _, v in buckets]
    if any(vals[i] > vals[i + 1] for i in range(len(vals) - 1)):
        errors.append(f"{fam}: bucket counts are not cumulative: {vals}")
    if vals[-1] != counts[0]:
        errors.append(f"{fam}: +Inf bucket {vals[-1]} != _count {counts[0]}")
    try:
        les = [float(le) for le, _ in buckets[:-1]]
    except (TypeError, ValueError):
        errors.append(f"{fam}: non-numeric le bound in {buckets[:-1]}")
        return
    if any(les[i] >= les[i + 1] for i in range(len(les) - 1)):
        errors.append(f"{fam}: le bounds must strictly ascend: {les}")


def main(argv):
    min_jobs = 0
    if "--min-job-count" in argv:
        i = argv.index("--min-job-count")
        try:
            min_jobs = int(argv[i + 1])
        except (IndexError, ValueError):
            print("--min-job-count requires an integer", file=sys.stderr)
            return 2
        del argv[i:i + 2]
    if len(argv) != 1:
        print("usage: check_metrics_exposition.py SCRAPE.txt "
              "[--min-job-count N]", file=sys.stderr)
        return 2

    try:
        with open(argv[0]) as f:
            text = f.read()
    except OSError as e:
        print(f"EXPOSITION ERROR: {argv[0]}: unreadable ({e})", file=sys.stderr)
        return 1

    helps, types, samples, errors = parse(text)
    for fam in sorted(types):
        if fam not in helps:
            errors.append(f"{fam}: TYPE without HELP")
        if types[fam] == "histogram":
            check_histogram(fam, samples, errors)
    missing = REQUIRED_FAMILIES - types.keys()
    if missing:
        errors.append(f"required families missing: {sorted(missing)}")

    job_counts = [v for n, _, v in samples
                  if n == "demst_job_latency_seconds_count"]
    if min_jobs and (not job_counts or job_counts[0] < min_jobs):
        got = job_counts[0] if job_counts else "absent"
        errors.append(f"pair-job latency count {got} < required {min_jobs}")

    for err in errors:
        print(f"EXPOSITION ERROR: {err}", file=sys.stderr)
    if not errors:
        jobs = int(job_counts[0]) if job_counts else 0
        print(f"exposition OK: {argv[0]} ({len(samples)} samples, "
              f"{jobs} pair jobs in the latency histogram)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
