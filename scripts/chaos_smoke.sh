#!/usr/bin/env bash
# Chaos smoke: the failure-path matrix on real multi-process runs —
# every fault × every ⊕-reduction topology (leader / tree / ring):
#
#   kill-mid-job       worker dies abruptly (SIGKILL-style, no farewell)
#                      upon receiving a pair job past the halfway mark
#                      (DEMST_CHAOS_EXIT_AFTER_JOBS)
#   kill-mid-fold      worker dies at its FoldShip settle point — jobs
#                      acked, partial MSF shipped nowhere (tree/ring only:
#                      the leader topology has no fold directive)
#   stall              worker wedges forever mid-run (DEMST_CHAOS_PLAN
#                      tx-stall) — the process stays alive, only the
#                      leader's liveness deadline can see it
#   admit-replacement  same stall, plus a third `demst worker` started
#                      after the run began: it must be admitted mid-run
#                      (Join/AdmitAck) and the run must report it
#
# Every leg asserts (a) the leader exits 0, (b) the MST CSV is
# byte-identical to a `--transport sim` run of the same seed (checksum
# printed), (c) the leader log reports the expected recovery witness.
#
# Run by `make chaos-smoke` / `make bench` and the CI chaos-smoke job.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DEMST_BIN:-target/release/demst}
OUT=${TMPDIR:-/tmp}
# parts=6 -> 15 pair jobs (~7-8 per worker on the 2-worker legs)
ARGS=(--data blobs --n 180 --d 8 --clusters 4 --parts 6 --seed 13
      --pair-kernel bipartite)

if [ ! -x "$BIN" ]; then
    echo "chaos-smoke: $BIN not built (run: cargo build --release)" >&2
    exit 2
fi

"$BIN" run "${ARGS[@]}" --workers 2 --out-mst "$OUT/demst_chaos_sim.csv" > /dev/null

# run_leg <fault> <topology>
run_leg() {
    local FAULT=$1 TOPO=$2
    local LEG="$FAULT/$TOPO"
    local WORKERS=2
    # Mid-fold death at the very last rendezvous has no fleet left to
    # recover on by design — use 3 workers so survivors stay unsettled.
    [ "$FAULT" = kill-mid-fold ] && WORKERS=3

    local TARGS=("${ARGS[@]}" --workers "$WORKERS")
    if [ "$TOPO" != "leader" ]; then
        # tree/ring fold worker partials among the fleet (implies --reduce-tree)
        TARGS+=(--reduce-topology "$TOPO")
    fi
    case "$FAULT" in
        stall|admit-replacement)
            # Short deadline so the stall is detected fast; still far above
            # a single n=180 pair job's compute time.
            TARGS+=(--liveness-timeout 2) ;;
    esac

    local LOG="$OUT/demst_chaos_leader_${FAULT}_${TOPO}.log"
    local CSV="$OUT/demst_chaos_tcp_${FAULT}_${TOPO}.csv"
    local TRACE="$OUT/demst_chaos_trace_${FAULT}_${TOPO}.json"
    local REPORT="$OUT/demst_chaos_run_${FAULT}_${TOPO}.json"
    : > "$LOG"
    "$BIN" run "${TARGS[@]}" --transport tcp --listen 127.0.0.1:0 \
        --trace-out "$TRACE" --report-out "$REPORT" \
        --out-mst "$CSV" > "$LOG" 2>&1 &
    local LEADER=$!

    local ADDR=""
    for _ in $(seq 1 150); do
        ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n 1)
        [ -n "$ADDR" ] && break
        sleep 0.1
    done
    if [ -z "$ADDR" ]; then
        echo "chaos-smoke[$LEG]: leader never reported its bound address" >&2
        cat "$LOG" >&2
        exit 1
    fi

    # Worker 1 carries the fault; the rest of the fleet is healthy.
    local W1 EXPECT_W1=die WITNESS=reassigned
    case "$FAULT" in
        kill-mid-job)
            DEMST_CHAOS_EXIT_AFTER_JOBS=3 "$BIN" worker --connect "$ADDR" \
                --connect-timeout 15000 &
            W1=$! ;;
        kill-mid-fold)
            # Chaotic worker first: accept order assigns ids and folds
            # settle ascending — kill the first settler, not the last.
            DEMST_CHAOS_EXIT_ON_FOLD=1 "$BIN" worker --connect "$ADDR" \
                --connect-timeout 15000 &
            W1=$!
            sleep 0.5 ;;
        stall|admit-replacement)
            # tx frames: Hello(1) SetupAck(2) ShardAdvertise(3), 3 local
            # trees (4-6), then pair replies — tx8 wedges the worker on
            # its second pair reply, claimed jobs in flight.
            DEMST_CHAOS_PLAN=tx8:stall "$BIN" worker --connect "$ADDR" \
                --connect-timeout 15000 &
            W1=$!
            EXPECT_W1=wedged
            WITNESS="liveness stall" ;;
    esac
    local HEALTHY=()
    local i
    for i in $(seq 2 "$WORKERS"); do
        "$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
        HEALTHY+=($!)
    done
    if [ "$FAULT" = admit-replacement ]; then
        WITNESS=admitted
        # Late worker: by now the startup handshake has consumed exactly
        # $WORKERS accepts, and the leader is still waiting out the
        # stalled link's 2 s deadline — this one must be admitted.
        ( sleep 1; "$BIN" worker --connect "$ADDR" --connect-timeout 15000 ) &
        HEALTHY+=($!)
    fi

    wait "$LEADER" || { echo "chaos-smoke[$LEG]: leader failed" >&2; cat "$LOG" >&2; exit 1; }
    if [ "$EXPECT_W1" = die ]; then
        # the chaos worker must have died nonzero
        if wait "$W1"; then
            echo "chaos-smoke[$LEG]: chaos worker exited 0 — the failure was never injected" >&2
            exit 1
        fi
    else
        # the stall fault loops forever by design: the process must still
        # be alive after the run completed without it — then reap it.
        if ! kill -0 "$W1" 2>/dev/null; then
            echo "chaos-smoke[$LEG]: stalled worker is gone — the stall was never injected" >&2
            exit 1
        fi
        kill -9 "$W1" 2>/dev/null || true
        wait "$W1" 2>/dev/null || true
    fi
    local W
    for W in "${HEALTHY[@]}"; do
        wait "$W" || { echo "chaos-smoke[$LEG]: healthy worker failed" >&2; cat "$LOG" >&2; exit 1; }
    done
    cat "$LOG"

    grep -q "$WITNESS" "$LOG" \
        || { echo "chaos-smoke[$LEG]: leader log lacks the '$WITNESS' witness" >&2; exit 1; }

    # the run's telemetry must survive the fault: valid trace JSON, a job
    # span for every executed pair job, and a stall/failover/admit instant
    python3 scripts/check_run_report.py "$REPORT" --trace "$TRACE" --chaos \
        || { echo "chaos-smoke[$LEG]: run report / trace validation failed" >&2; exit 1; }

    cmp "$CSV" "$OUT/demst_chaos_sim.csv" \
        || { echo "chaos-smoke[$LEG]: post-recovery MST differs from sim" >&2; exit 1; }
    sha256sum "$CSV" \
        | awk -v l="$LEG" '{print "chaos-smoke[" l "]: OK, mst checksum " $1}'
}

FAULTS=${DEMST_CHAOS_FAULTS:-kill-mid-job kill-mid-fold stall admit-replacement}
for FAULT in $FAULTS; do
    for TOPO in leader tree ring; do
        if [ "$FAULT" = kill-mid-fold ] && [ "$TOPO" = leader ]; then
            # not a silent skip: the leader topology has no FoldShip to die at
            echo "chaos-smoke[kill-mid-fold/leader]: skipped (no fold directive in the gather topology)"
            continue
        fi
        run_leg "$FAULT" "$TOPO"
    done
done
echo "chaos-smoke: full fault x topology matrix passed"
