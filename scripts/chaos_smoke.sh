#!/usr/bin/env bash
# Chaos smoke: elastic-worker failover on a real multi-process run, once
# per ⊕-reduction topology (leader / tree / ring).
#
# One `demst run --transport tcp` leader plus two externally started
# `demst worker` processes on 127.0.0.1. Worker 1 is rigged through the
# DEMST_CHAOS_EXIT_AFTER_JOBS hook to die abruptly — no reply, no shutdown
# handshake, sockets torn down by the OS, exactly like a SIGKILL — upon
# receiving its pair job after the halfway mark. Under `tree`/`ring` the
# surviving fleet also re-routes the worker↔worker fold schedule around
# the corpse. Asserts, for every topology:
#   (a) the leader exits 0 (run completed on the surviving worker),
#   (b) the MST CSV is byte-identical to a `--transport sim` run of the
#       same seed (checksum printed) — and identical across topologies,
#   (c) the leader reports the failover (reassigned jobs > 0).
#
# Run by `make chaos-smoke` / `make bench` and the CI chaos-smoke job.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DEMST_BIN:-target/release/demst}
OUT=${TMPDIR:-/tmp}
# parts=6 -> 15 pair jobs across 2 workers (~7-8 each); the chaos worker
# dies on receiving its 4th job, i.e. around 50% of its deck.
ARGS=(--data blobs --n 180 --d 8 --clusters 4 --parts 6 --workers 2 --seed 13
      --pair-kernel bipartite)

if [ ! -x "$BIN" ]; then
    echo "chaos-smoke: $BIN not built (run: cargo build --release)" >&2
    exit 2
fi

"$BIN" run "${ARGS[@]}" --out-mst "$OUT/demst_chaos_sim.csv" > /dev/null

for TOPO in leader tree ring; do
    TARGS=("${ARGS[@]}")
    if [ "$TOPO" != "leader" ]; then
        # tree/ring fold worker partials among the fleet (implies --reduce-tree)
        TARGS+=(--reduce-topology "$TOPO")
    fi

    LOG="$OUT/demst_chaos_leader_$TOPO.log"
    : > "$LOG"
    "$BIN" run "${TARGS[@]}" --transport tcp --listen 127.0.0.1:0 \
        --out-mst "$OUT/demst_chaos_tcp_$TOPO.csv" > "$LOG" 2>&1 &
    LEADER=$!

    ADDR=""
    for _ in $(seq 1 150); do
        ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n 1)
        [ -n "$ADDR" ] && break
        sleep 0.1
    done
    if [ -z "$ADDR" ]; then
        echo "chaos-smoke[$TOPO]: leader never reported its bound address" >&2
        cat "$LOG" >&2
        exit 1
    fi

    DEMST_CHAOS_EXIT_AFTER_JOBS=3 "$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
    W1=$!
    "$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
    W2=$!

    wait "$LEADER" || { echo "chaos-smoke[$TOPO]: leader failed" >&2; cat "$LOG" >&2; exit 1; }
    # the chaos worker must have died nonzero; the survivor must exit 0
    if wait "$W1"; then
        echo "chaos-smoke[$TOPO]: chaos worker exited 0 — the failure was never injected" >&2
        exit 1
    fi
    wait "$W2" || { echo "chaos-smoke[$TOPO]: surviving worker failed" >&2; exit 1; }
    cat "$LOG"

    grep -q "reassigned" "$LOG" \
        || { echo "chaos-smoke[$TOPO]: leader log reports no reassignment" >&2; exit 1; }

    cmp "$OUT/demst_chaos_tcp_$TOPO.csv" "$OUT/demst_chaos_sim.csv" \
        || { echo "chaos-smoke[$TOPO]: post-failover MST differs from sim" >&2; exit 1; }
    sha256sum "$OUT/demst_chaos_tcp_$TOPO.csv" \
        | awk -v t="$TOPO" '{print "chaos-smoke[" t "]: OK, mst checksum " $1}'
done
