#!/usr/bin/env python3
"""Bench-schema guard: fail if a BENCH_e*.json lost a section or key.

Run after the bench binaries (``make bench`` or the CI bench-smoke job)
against the freshly written JSON files. A bench section silently
disappearing — e.g. a refactor dropping the ``stream_fold`` micro-bench —
is exactly the regression this catches: CI goes red instead of the
measurement quietly vanishing from the record.

Usage: check_bench_schema.py BENCH_e7.json BENCH_e8.json ...
"""

import json
import sys

# Required row sections per bench id. Keep in sync with the bench binaries
# (rust/benches/e7_kernel.rs, rust/benches/e8_end_to_end.rs); a new section
# should be added here in the same PR that starts recording it.
REQUIRED_SECTIONS = {
    "e7_kernel": {"cheapest_edge", "prim_dense", "panel_simd"},
    "e8_end_to_end": {"pair_kernel", "stream_fold", "transport", "reduction",
                      "elasticity"},
}
# Rows that must exist *within* a section. The transport section must keep
# both pipelined-dispatch ablation rows (window=1 rendezvous vs window=2
# overlap) next to the simulated baseline; the panel_simd section must keep
# all three kernel providers (canonical scalar, SIMD dispatch, threaded).
REQUIRED_PROVIDERS = {
    "e7_kernel": {"panel_simd": {"panel-scalar", "panel-simd", "panel-simd-mt"}},
    "e8_end_to_end": {
        "transport": {"sim", "tcp-win1", "tcp-win2"},
        # the reduction-topology ablation must keep all three fold schedules
        # (leader-gathered baseline vs worker<->worker binomial tree / ring)
        "reduction": {"leader", "tree", "ring"},
        # the elasticity section must keep the clean baseline next to both
        # recovery legs (abrupt kill failover, stall + mid-run admission)
        "elasticity": {"clean", "failover", "admission"},
    },
}
REQUIRED_TOP_KEYS = {"bench", "rows"}


def check(path):
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    for key in sorted(REQUIRED_TOP_KEYS):
        if key not in doc:
            errors.append(f"{path}: missing top-level key {key!r}")
    bench = doc.get("bench")
    required = REQUIRED_SECTIONS.get(bench)
    if required is None:
        errors.append(f"{path}: unknown bench id {bench!r} "
                      f"(known: {sorted(REQUIRED_SECTIONS)})")
        return errors
    rows = doc.get("rows") or []
    if not rows:
        errors.append(f"{path}: no recorded rows — did the bench run?")
        return errors
    got = {row.get("section") for row in rows}
    missing = required - got
    if missing:
        errors.append(f"{path}: bench sections disappeared: {sorted(missing)} "
                      f"(present: {sorted(s for s in got if s)})")
    for section, providers in REQUIRED_PROVIDERS.get(bench, {}).items():
        present = {row.get("provider") for row in rows
                   if row.get("section") == section}
        lost = providers - present
        if lost:
            errors.append(f"{path}: section {section!r} lost rows: "
                          f"{sorted(lost)} (present: "
                          f"{sorted(p for p in present if p)})")
    return errors


def main(argv):
    if not argv:
        print("usage: check_bench_schema.py BENCH_e7.json BENCH_e8.json ...",
              file=sys.stderr)
        return 2
    errors = []
    for path in argv:
        errors.extend(check(path))
    for err in errors:
        print(f"SCHEMA ERROR: {err}", file=sys.stderr)
    if not errors:
        print(f"bench schema OK: {', '.join(argv)}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
