#!/usr/bin/env python3
"""Cross-run regression diff over two ``demst run --report-out`` documents.

The Python mirror of ``demst report diff`` — same tracked quantities, same
default thresholds — for harnesses that gate on reports without a demst
binary at hand (e.g. comparing artifacts downloaded from two CI runs).
Exits non-zero when the candidate regresses beyond a threshold, so it can
sit directly in a CI job.

Tracked quantities (threshold = allowed relative regression, percent):
- ``wall_s``             (--max-wall-regress,       default 25; noisy on CI)
- ``dist_evals``         (--max-dist-evals-regress, default  1; deterministic)
- ``wire_bytes``         (--max-bytes-regress,      default  1; deterministic;
                          scatter + gather + control)
- ``p99 job latency``    (--max-p99-job-regress,    default 50; only when both
                          runs carry a pair-job latency histogram)

Usage: compare_reports.py BASELINE.json CANDIDATE.json [--max-*-regress PCT]
"""

import argparse
import json
import sys


def get(doc, path):
    cur = doc
    for key in path.split("."):
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def wire_bytes(doc):
    parts = [get(doc, f"metrics.{k}")
             for k in ("scatter_bytes", "gather_bytes", "control_bytes")]
    if any(p is None for p in parts):
        return None
    return sum(parts)


def delta_pct(base, cand):
    if base > 0:
        return (cand - base) / base * 100.0
    return float("inf") if cand > base else 0.0


def main():
    ap = argparse.ArgumentParser(
        description="diff two demst run reports; exit 1 on regression")
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-wall-regress", type=float, default=25.0)
    ap.add_argument("--max-dist-evals-regress", type=float, default=1.0)
    ap.add_argument("--max-bytes-regress", type=float, default=1.0)
    ap.add_argument("--max-p99-job-regress", type=float, default=50.0)
    args = ap.parse_args()

    docs = []
    for path in (args.baseline, args.candidate):
        try:
            with open(path) as f:
                docs.append(json.load(f))
        except (OSError, json.JSONDecodeError) as e:
            print(f"DIFF ERROR: {path}: unreadable ({e})", file=sys.stderr)
            return 2
    base, cand = docs

    rows = [
        ("wall_s", get(base, "metrics.wall_s"), get(cand, "metrics.wall_s"),
         args.max_wall_regress),
        ("dist_evals", get(base, "metrics.dist_evals"),
         get(cand, "metrics.dist_evals"), args.max_dist_evals_regress),
        ("wire_bytes", wire_bytes(base), wire_bytes(cand),
         args.max_bytes_regress),
    ]
    lat = "histograms.job_latency_seconds"
    if (get(base, f"{lat}.count") or 0) > 0 and (get(cand, f"{lat}.count") or 0) > 0:
        rows.append(("p99_job_latency_s", get(base, f"{lat}.p99"),
                     get(cand, f"{lat}.p99"), args.max_p99_job_regress))

    failed, broken = [], []
    print(f"{'metric':<20} {'baseline':>14} {'candidate':>14} "
          f"{'delta':>10} {'limit':>8}  verdict")
    for name, b, c, limit in rows:
        if b is None or c is None:
            broken.append(name)
            print(f"{name:<20} {'?':>14} {'?':>14} {'?':>10} "
                  f"{limit:>7.0f}%  MISSING")
            continue
        d = delta_pct(b, c)
        verdict = "REGRESSED" if d > limit else "ok"
        if d > limit:
            failed.append(name)
        print(f"{name:<20} {b:>14.6f} {c:>14.6f} {d:>+9.2f}% "
              f"{limit:>7.0f}%  {verdict}")

    if broken:
        print(f"DIFF ERROR: missing numeric fields for: {', '.join(broken)}",
              file=sys.stderr)
        return 2
    if failed:
        print(f"DIFF ERROR: regression beyond threshold in: "
              f"{', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"report diff OK: {len(rows)} metrics within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
