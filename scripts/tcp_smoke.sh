#!/usr/bin/env bash
# Loopback multi-process smoke: one `demst run --transport tcp` leader plus
# two externally started `demst worker` processes on 127.0.0.1, small
# dataset, asserting (a) every process exits 0 and (b) the MST CSV is
# byte-identical to a `--transport sim` run of the same seed (checksum
# printed). Run by `make tcp-smoke` / `make bench` and the CI tcp-smoke job.
#
# The leader binds port 0 (kernel-assigned, no fixed-port collisions); the
# workers read the actual address from the leader's "listening on" line.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DEMST_BIN:-target/release/demst}
OUT=${TMPDIR:-/tmp}
ARGS=(--data blobs --n 160 --d 8 --clusters 4 --parts 4 --workers 2 --seed 7
      --pair-kernel bipartite)

if [ ! -x "$BIN" ]; then
    echo "tcp-smoke: $BIN not built (run: cargo build --release)" >&2
    exit 2
fi

LOG="$OUT/demst_smoke_leader.log"
: > "$LOG"
"$BIN" run "${ARGS[@]}" --transport tcp --listen 127.0.0.1:0 \
    --trace-out "$OUT/demst_smoke_trace.json" \
    --report-out "$OUT/demst_smoke_run.json" \
    --out-mst "$OUT/demst_smoke_tcp.csv" > "$LOG" 2>&1 &
LEADER=$!

ADDR=""
for _ in $(seq 1 150); do
    ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "tcp-smoke: leader never reported its bound address" >&2
    cat "$LOG" >&2
    exit 1
fi

"$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
W1=$!
"$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
W2=$!

wait "$LEADER" || { echo "tcp-smoke: leader failed" >&2; cat "$LOG" >&2; exit 1; }
wait "$W1" || { echo "tcp-smoke: worker 1 failed" >&2; exit 1; }
wait "$W2" || { echo "tcp-smoke: worker 2 failed" >&2; exit 1; }
cat "$LOG"

"$BIN" run "${ARGS[@]}" --out-mst "$OUT/demst_smoke_sim.csv" > /dev/null

cmp "$OUT/demst_smoke_tcp.csv" "$OUT/demst_smoke_sim.csv" \
    || { echo "tcp-smoke: tcp and sim MSTs differ" >&2; exit 1; }

# the observability exports must validate and reconcile with the counters
python3 scripts/check_run_report.py "$OUT/demst_smoke_run.json" \
    --trace "$OUT/demst_smoke_trace.json" \
    || { echo "tcp-smoke: run report / trace validation failed" >&2; exit 1; }

sha256sum "$OUT/demst_smoke_tcp.csv" | awk '{print "tcp-smoke: OK, mst checksum " $1}'
