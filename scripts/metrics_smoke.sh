#!/usr/bin/env bash
# Fleet-metrics smoke: a `demst run --transport tcp --metrics-listen` leader
# plus two externally started `demst worker` processes, sized so the run
# takes a few seconds — long enough to scrape the leader's live /metrics
# endpoint MID-RUN with curl. Asserts:
#   (a) every mid-run scrape is valid Prometheus text (format 0.0.4) and
#       eventually shows the fleet-merged pair-job latency histogram filling
#       with real worker-pushed observations;
#   (b) the final --report-out document validates, histograms included;
#   (c) the cross-run regression gates agree: `demst report diff` and
#       scripts/compare_reports.py both pass a self-diff, both pass a
#       baseline-vs-rerun diff of two identical sim runs, and both exit
#       non-zero on an injected 2x wall-clock regression.
# Run by the CI metrics-smoke job.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${DEMST_BIN:-target/release/demst}
OUT=${TMPDIR:-/tmp}
# big enough that the pair phase alone spans many scrape intervals
ARGS=(--data blobs --n 30000 --d 32 --clusters 8 --parts 8 --workers 2
      --seed 11 --pair-kernel bipartite)

if [ ! -x "$BIN" ]; then
    echo "metrics-smoke: $BIN not built (run: cargo build --release)" >&2
    exit 2
fi

LOG="$OUT/demst_metrics_leader.log"
: > "$LOG"
"$BIN" run "${ARGS[@]}" --transport tcp --listen 127.0.0.1:0 \
    --metrics-listen 127.0.0.1:0 --metrics-push-ms 50 \
    --report-out "$OUT/demst_metrics_run.json" > "$LOG" 2>&1 &
LEADER=$!

ADDR=""
for _ in $(seq 1 300); do
    ADDR=$(sed -n 's/.*leader: listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "metrics-smoke: leader never reported its bound address" >&2
    cat "$LOG" >&2
    exit 1
fi

"$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
W1=$!
"$BIN" worker --connect "$ADDR" --connect-timeout 15000 &
W2=$!

# the exposition listener starts once the fleet is assembled
MADDR=""
for _ in $(seq 1 300); do
    MADDR=$(sed -n 's!.*metrics: listening on http://\([0-9.]*:[0-9]*\)/metrics.*!\1!p' "$LOG" | head -n 1)
    [ -n "$MADDR" ] && break
    sleep 0.1
done
if [ -z "$MADDR" ]; then
    echo "metrics-smoke: leader never announced its /metrics endpoint" >&2
    cat "$LOG" >&2
    exit 1
fi

# scrape mid-run until the latency histogram has counted at least one pair
# job shipped up from a worker (every successful scrape must validate)
SCRAPE="$OUT/demst_metrics_scrape.txt"
LIVE=""
for _ in $(seq 1 600); do
    if curl -fsS --max-time 2 "http://$MADDR/metrics" -o "$SCRAPE" 2>/dev/null; then
        python3 scripts/check_metrics_exposition.py "$SCRAPE" > /dev/null \
            || { echo "metrics-smoke: invalid exposition text mid-run" >&2
                 python3 scripts/check_metrics_exposition.py "$SCRAPE" || true
                 cat "$SCRAPE" >&2; exit 1; }
        if python3 scripts/check_metrics_exposition.py "$SCRAPE" \
                --min-job-count 1 > /dev/null 2>&1; then
            LIVE=yes
            break
        fi
    fi
    sleep 0.05
done
if [ -z "$LIVE" ]; then
    echo "metrics-smoke: never scraped a non-empty pair-job latency histogram mid-run" >&2
    cat "$LOG" >&2
    exit 1
fi
python3 scripts/check_metrics_exposition.py "$SCRAPE" --min-job-count 1

wait "$LEADER" || { echo "metrics-smoke: leader failed" >&2; cat "$LOG" >&2; exit 1; }
wait "$W1" || { echo "metrics-smoke: worker 1 failed" >&2; exit 1; }
wait "$W2" || { echo "metrics-smoke: worker 2 failed" >&2; exit 1; }
grep -E "^(latency|metrics):" "$LOG" || true

# the final report must reconcile, histogram section included
python3 scripts/check_run_report.py "$OUT/demst_metrics_run.json" \
    || { echo "metrics-smoke: run report validation failed" >&2; exit 1; }

# --- cross-run regression gates ---------------------------------------------
# two identical (smaller, sim-transport) runs: deterministic metrics are
# equal by construction, wall gets CI slack
GATE_ARGS=(--data blobs --n 2000 --d 16 --clusters 4 --parts 4 --workers 2
           --seed 23 --pair-kernel bipartite)
"$BIN" run "${GATE_ARGS[@]}" --report-out "$OUT/demst_metrics_base.json" > /dev/null
"$BIN" run "${GATE_ARGS[@]}" --report-out "$OUT/demst_metrics_cand.json" > /dev/null

"$BIN" report diff "$OUT/demst_metrics_run.json" "$OUT/demst_metrics_run.json" \
    || { echo "metrics-smoke: self-diff must pass" >&2; exit 1; }
"$BIN" report diff --max-wall-regress 400 --max-p99-job-regress 10000 \
    "$OUT/demst_metrics_base.json" "$OUT/demst_metrics_cand.json" \
    || { echo "metrics-smoke: identical-config rerun regressed deterministic metrics" >&2; exit 1; }
python3 scripts/compare_reports.py --max-wall-regress 400 --max-p99-job-regress 10000 \
    "$OUT/demst_metrics_base.json" "$OUT/demst_metrics_cand.json" \
    || { echo "metrics-smoke: compare_reports.py disagrees with demst report diff" >&2; exit 1; }

# inject a 2x wall regression; both gates must trip (exit non-zero)
python3 - "$OUT/demst_metrics_base.json" "$OUT/demst_metrics_regressed.json" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
doc["metrics"]["wall_s"] *= 2.0
with open(sys.argv[2], "w") as f:
    json.dump(doc, f)
EOF
if "$BIN" report diff "$OUT/demst_metrics_base.json" "$OUT/demst_metrics_regressed.json" > /dev/null 2>&1; then
    echo "metrics-smoke: demst report diff missed an injected 2x wall regression" >&2
    exit 1
fi
if python3 scripts/compare_reports.py "$OUT/demst_metrics_base.json" \
        "$OUT/demst_metrics_regressed.json" > /dev/null 2>&1; then
    echo "metrics-smoke: compare_reports.py missed an injected 2x wall regression" >&2
    exit 1
fi

echo "metrics-smoke: OK (live scrape validated, report reconciled, regression gates trip)"
