//! E8 — the end-to-end driver: the paper's motivating workload, full stack.
//!
//!     cargo run --release --example clustering_pipeline [--xla] [--n N] [--d D]
//!
//! Pipeline (all layers composing):
//!   1. synthesize "neural embeddings" (Gaussian mixture on a low-dim latent
//!      manifold, rotated into D dims + noise) — the paper's target data;
//!   2. distributed exact EMST via distance decomposition (Algorithm 1),
//!      thread-per-rank workers, simulated network with byte accounting —
//!      with `--xla`, each worker drives the AOT-compiled Pallas kernel
//!      through PJRT (the full three-layer stack);
//!   3. exactness verification against the independent SLINK O(n²) oracle;
//!   4. MST → single-linkage dendrogram → flat clusters vs ground truth;
//!   5. headline metrics: exactness, work overhead vs monolithic, comm
//!      bytes (gather vs reduce), wallclock + speedup vs single worker.
//!
//! The run recorded in EXPERIMENTS.md §E8 used:
//!     cargo run --release --example clustering_pipeline -- --xla

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, EmbeddingSpec};
use demst::dense::{DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::mst::total_weight;
use demst::report::Table;
use demst::slink::{mst_to_dendrogram, slink};
use demst::util::prng::Pcg64;
use demst::util::timer::Stopwatch;
use std::time::Duration;

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");
    let n = arg_usize("--n", 4096);
    let d = arg_usize("--d", 256);
    let parts = arg_usize("--parts", 8);
    let workers = arg_usize("--workers", 8);
    let k_true = 24;

    println!("=== E8 end-to-end clustering pipeline ===");
    let spec = EmbeddingSpec { n, d, latent: 8, k: k_true, cluster_std: 0.35, noise: 0.01 };
    let sw = Stopwatch::start();
    let (ds, truth) = embedding_like(&spec, Pcg64::seeded(2024));
    println!(
        "[1] embeddings: n={} d={} latent={} clusters={} ({:.1}ms)",
        ds.n, ds.d, spec.latent, k_true, sw.elapsed_ms()
    );

    let kernel_choice = if use_xla {
        if !demst::runtime::backend_xla_compiled() {
            anyhow::bail!("--xla requires a build with --features backend-xla");
        }
        let dir = std::path::PathBuf::from("artifacts");
        if !demst::runtime::artifacts_available(&dir) {
            anyhow::bail!("--xla requires artifacts/ — run `make artifacts` first");
        }
        KernelChoice::BoruvkaXla
    } else {
        KernelChoice::BoruvkaRust
    };

    // [2] distributed decomposed EMST
    let mut cfg = RunConfig {
        parts,
        workers,
        kernel: kernel_choice.clone(),
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg)?;
    println!(
        "[2] distributed EMST ({}, |P|={}, {} jobs, {} workers): weight {:.4}, wall {:?}",
        kernel_choice.name(),
        parts,
        out.metrics.jobs,
        out.workers,
        total_weight(&out.mst),
        out.metrics.wall
    );
    println!("    {}", out.metrics.summary());

    // Speedup: modeled LPT makespan from per-job times measured in a
    // sequential (workers=1) pass — multi-worker job times on a box with
    // fewer cores than workers are inflated by time-slicing; see
    // RunMetrics::modeled_makespan.
    cfg.workers = 1;
    let seq = run_distributed(&ds, &cfg)?;
    let total_compute = seq.metrics.total_compute();
    let makespan_w = seq.metrics.modeled_makespan(workers);
    let makespan_p = seq.metrics.modeled_makespan(seq.metrics.jobs as usize);
    let speedup = total_compute.as_secs_f64() / makespan_w.as_secs_f64();

    // Monolithic single-node d-MST work baseline (E2's denominator).
    let mono = PrimDense::sq_euclid();
    let (mono_tree, mono_wall) = demst::util::timer::timed(|| mono.mst(&ds));
    let work_ratio = out.metrics.dist_evals as f64 / mono.dist_evals() as f64;

    // Reduce-tree gather ablation.
    cfg.workers = workers;
    cfg.reduce_tree = true;
    let reduced = run_distributed(&ds, &cfg)?;

    // [3] exactness: against monolithic Prim AND slink
    let w_mono = total_weight(&mono_tree);
    let w_dist = total_weight(&out.mst);
    anyhow::ensure!(
        (w_mono - w_dist).abs() < 1e-4 * (1.0 + w_mono),
        "exactness violated: mono={w_mono} dist={w_dist}"
    );
    let sw3 = Stopwatch::start();
    let slink_dendro = slink(&ds, &PlainMetric(MetricKind::SqEuclid));
    let slink_wall = sw3.elapsed();
    println!("[3] exact: matches monolithic d-MST weight {:.4} (SLINK oracle built in {:?})", w_mono, slink_wall);

    // [4] dendrogram + flat clusters
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let labels = dendro.cut_to_k(k_true);
    let slink_labels = slink_dendro.cut_to_k(k_true);
    let vs_slink = agreement(&labels, &slink_labels);
    let vs_truth = agreement(&labels, &truth);
    println!(
        "[4] single-linkage k={}: agreement vs SLINK {:.2}%, vs ground truth {:.2}%",
        k_true,
        vs_slink * 100.0,
        vs_truth * 100.0
    );
    anyhow::ensure!(vs_slink > 0.999, "distributed dendrogram must match SLINK");

    // [5] headline table
    let mut t = Table::new("E8 headline metrics", &["metric", "value"]);
    let fmt_d = |d: Duration| format!("{:.3}s", d.as_secs_f64());
    t.push_row(&["points x dims".to_string(), format!("{} x {}", ds.n, ds.d)]);
    t.push_row(&["kernel".to_string(), kernel_choice.name().to_string()]);
    t.push_row(&["pair jobs (p)".to_string(), out.metrics.jobs.to_string()]);
    t.push_row(&["workers".to_string(), out.workers.to_string()]);
    t.push_row(&["wall (measured, this host)".to_string(), fmt_d(out.metrics.wall)]);
    t.push_row(&["total kernel compute".to_string(), fmt_d(total_compute)]);
    t.push_row(&[format!("modeled makespan ({workers} ranks)"), fmt_d(makespan_w)]);
    t.push_row(&[format!("modeled makespan (p={} ranks)", out.metrics.jobs), fmt_d(makespan_p)]);
    t.push_row(&[format!("modeled speedup ({workers} ranks)"), format!("{speedup:.2}x")]);
    t.push_row(&["wall (monolithic prim)".to_string(), fmt_d(mono_wall)]);
    t.push_row(&["work ratio vs monolithic".to_string(), format!("{work_ratio:.3} (paper: 2(|P|-1)/|P| = {:.3})", 2.0 * (parts as f64 - 1.0) / parts as f64)]);
    t.push_row(&["scatter bytes".to_string(), demst::util::human_bytes(out.metrics.scatter_bytes)]);
    t.push_row(&["gather bytes (gather mode)".to_string(), demst::util::human_bytes(out.metrics.gather_bytes)]);
    t.push_row(&["gather bytes (reduce mode)".to_string(), demst::util::human_bytes(reduced.metrics.gather_bytes)]);
    t.push_row(&["union edges gathered".to_string(), out.metrics.union_edges.to_string()]);
    t.push_row(&["parallel efficiency".to_string(), format!("{:.2}", out.metrics.busy_efficiency())]);
    t.push_row(&["dendrogram vs SLINK".to_string(), format!("{:.3}%", vs_slink * 100.0)]);
    t.print();
    println!("pipeline OK");
    Ok(())
}

/// Sampled Rand index between two labelings.
fn agreement(a: &[u32], b: &[u32]) -> f64 {
    let mut rng = Pcg64::seeded(99);
    let n = a.len();
    let samples = 50_000u64;
    let mut agree = 0u64;
    for _ in 0..samples {
        let i = rng.next_bounded(n as u64) as usize;
        let j = rng.next_bounded(n as u64) as usize;
        if (a[i] == a[j]) == (b[i] == b[j]) {
            agree += 1;
        }
    }
    agree as f64 / samples as f64
}
