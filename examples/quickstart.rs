//! Quickstart: exact distributed EMST + single-linkage clustering in ~40
//! lines of library calls.
//!
//!     cargo run --release --example quickstart
//!
//! Generates clustered synthetic embeddings, runs the paper's decomposed
//! EMST (Algorithm 1) distributed over worker threads, converts the tree to
//! a single-linkage dendrogram, cuts flat clusters, and verifies everything
//! against the independent SLINK oracle.

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{gaussian_blobs_labeled, BlobSpec};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::mst::total_weight;
use demst::slink::{mst_to_dendrogram, slink_mst};
use demst::util::prng::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. A dataset: 8 Gaussian blobs in 32 dimensions.
    let spec = BlobSpec { n: 1000, d: 32, k: 8, std: 0.4, spread: 10.0 };
    let (ds, truth) = gaussian_blobs_labeled(&spec, Pcg64::seeded(42));
    println!("dataset: {} points, {} dims, {} true clusters", ds.n, ds.d, spec.k);

    // 2. Distributed exact EMST: |P| = 5 subsets -> 10 pair jobs on 4 workers.
    let cfg = RunConfig {
        parts: 5,
        workers: 4,
        kernel: KernelChoice::BoruvkaRust,
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg)?;
    println!(
        "emst: {} edges, weight {:.4}",
        out.mst.len(),
        total_weight(&out.mst)
    );
    println!("metrics: {}", out.metrics.summary());

    // 3. Verify exactness against the independent SLINK oracle (Theorem 1).
    let oracle = slink_mst(&ds, &PlainMetric(MetricKind::SqEuclid));
    let (a, b) = (total_weight(&oracle), total_weight(&out.mst));
    assert!((a - b).abs() < 1e-5 * (1.0 + a), "oracle={a} got={b}");
    println!("verified: matches SLINK oracle weight {a:.4}");

    // 4. MST -> single-linkage dendrogram -> flat clusters.
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let labels = dendro.cut_to_k(8);
    let accuracy = cluster_agreement(&labels, &truth);
    println!("single-linkage k=8 vs ground truth agreement: {:.1}%", accuracy * 100.0);
    assert!(accuracy > 0.99, "well-separated blobs must be recovered");
    Ok(())
}

/// Fraction of pairs on which two labelings agree (Rand index, sampled).
fn cluster_agreement(a: &[u32], b: &[u32]) -> f64 {
    let mut rng = Pcg64::seeded(7);
    let n = a.len();
    let mut agree = 0u64;
    let samples = 20_000;
    for _ in 0..samples {
        let i = rng.next_bounded(n as u64) as usize;
        let j = rng.next_bounded(n as u64) as usize;
        if (a[i] == a[j]) == (b[i] == b[j]) {
            agree += 1;
        }
    }
    agree as f64 / samples as f64
}
