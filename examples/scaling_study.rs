//! Scaling study (E4): wallclock and efficiency vs worker count, plus the
//! partition-count trade-off (more parts = more parallelism but ≈2× work).
//!
//!     cargo run --release --example scaling_study [--n N] [--d D]

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, EmbeddingSpec};
use demst::decomp::pair_count;
use demst::report::Table;

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = arg_usize("--n", 2048);
    let d = arg_usize("--d", 64);
    let spec = EmbeddingSpec { n, d, latent: 8, k: 16, cluster_std: 0.3, noise: 0.02 };
    let (ds, _) = embedding_like(&spec, demst::util::prng::Pcg64::seeded(7));
    println!("scaling study on n={} d={}", ds.n, ds.d);

    // --- strong scaling: fixed |P|=8 (28 jobs), modeled makespan ---
    // One measured pass collects per-job kernel CPU times; LPT scheduling of
    // those times models the makespan for any rank count. (This testbed may
    // have fewer cores than ranks — see RunMetrics::modeled_makespan.)
    let cfg = RunConfig {
        parts: 8,
        workers: 1,
        kernel: KernelChoice::BoruvkaRust,
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg)?;
    let total = out.metrics.total_compute().as_secs_f64();
    let mut t = Table::new(
        format!(
            "E4 strong scaling (|P|=8, 28 pair jobs, modeled from measured per-job CPU; total compute {:.3}s)",
            total
        ),
        &["workers", "makespan_s", "speedup", "efficiency"],
    );
    for workers in [1usize, 2, 4, 8, 16, 28] {
        let mk = out.metrics.modeled_makespan(workers).as_secs_f64();
        t.push_row(&[
            workers.to_string(),
            format!("{mk:.3}"),
            format!("{:.2}x", total / mk),
            format!("{:.2}", total / mk / workers as f64),
        ]);
    }
    t.print();

    // --- partition sweep: workers = cores, sweep |P| ---
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let mut t2 = Table::new(
        format!("partition sweep ({cores} workers)"),
        &["|P|", "jobs", "wall_s", "dist_evals", "work_ratio", "gather_bytes"],
    );
    let mono_evals = (ds.n * (ds.n - 1) / 2) as f64;
    for parts in [2usize, 4, 8, 12, 16] {
        let cfg = RunConfig {
            parts,
            workers: cores,
            kernel: KernelChoice::BoruvkaRust,
            ..Default::default()
        };
        let out = run_distributed(&ds, &cfg)?;
        t2.push_row(&[
            parts.to_string(),
            pair_count(parts).to_string(),
            format!("{:.3}", out.metrics.wall.as_secs_f64()),
            demst::util::human_count(out.metrics.dist_evals),
            format!("{:.2}x", out.metrics.dist_evals as f64 / mono_evals),
            demst::util::human_bytes(out.metrics.gather_bytes),
        ]);
    }
    t2.print();
    println!("note: Borůvka evals are per-round n², so the work ratio differs from");
    println!("the Prim-kernel formula 2(|P|-1)/|P| by the round count; see bench e2");
    println!("for the exact-formula reproduction with the Prim kernel.");
    Ok(())
}
