//! Dendrogram explorer: the MST ↔ single-linkage duality on a dataset where
//! single linkage shines (concentric shells — non-convex clusters k-means
//! cannot separate).
//!
//!     cargo run --release --example dendrogram_explorer

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::concentric_shells;
use demst::report::Table;
use demst::slink::mst_to_dendrogram;
use demst::util::prng::Pcg64;

fn main() -> anyhow::Result<()> {
    // Two concentric shells in 3-D. (Deliberately low-dimensional: single
    // linkage separates the shells only while the within-shell
    // nearest-neighbor distance stays below the shell gap — on a
    // high-dimensional sphere a few hundred points are too sparse for that,
    // which is itself a nice illustration of the curse of dimensionality.)
    let (ds, truth) = concentric_shells(800, 3, 1.0, 4.0, 0.02, Pcg64::seeded(11));
    println!("concentric shells: n={} d={} (radii 1 and 4)", ds.n, ds.d);

    let cfg = RunConfig {
        parts: 4,
        kernel: KernelChoice::BoruvkaRust,
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg)?;
    let dendro = mst_to_dendrogram(ds.n, &out.mst);

    // The top merge height is the shell gap; everything below is intra-shell.
    let heights = dendro.heights();
    let top = *heights.last().unwrap();
    let p95 = heights[(heights.len() as f64 * 0.95) as usize];
    println!("merge heights: top={top:.3} p95={p95:.3} (gap ratio {:.1}x)", top / p95);

    // Cut profile: cluster count and largest-cluster share vs height.
    let mut t = Table::new("cut profile", &["height", "clusters", "largest_share"]);
    for frac in [0.25, 0.5, 0.75, 0.9, 0.99, 1.01] {
        let h = top * frac as f32;
        let labels = dendro.cut_at_height(h);
        let k = labels.iter().copied().max().unwrap() as usize + 1;
        let mut sizes = vec![0usize; k];
        for &l in &labels {
            sizes[l as usize] += 1;
        }
        let largest = *sizes.iter().max().unwrap();
        t.push_row(&[
            format!("{h:.3}"),
            k.to_string(),
            format!("{:.2}", largest as f64 / ds.n as f64),
        ]);
    }
    t.print();

    // k=2 must recover the two shells exactly (single linkage's specialty).
    let labels = dendro.cut_to_k(2);
    let mut agree = 0usize;
    // labels may be permuted; check both orientations
    let direct = labels.iter().zip(&truth).filter(|(a, b)| *a == *b).count();
    let flipped = labels.iter().zip(&truth).filter(|(a, b)| **a == 1 - **b).count();
    agree += direct.max(flipped);
    println!("k=2 shell recovery: {}/{} points", agree, ds.n);
    anyhow::ensure!(agree == ds.n, "single linkage must separate the shells");

    // Round-trip: dendrogram -> MST -> dendrogram preserves the hierarchy.
    let back = mst_to_dendrogram(ds.n, &dendro.to_mst());
    anyhow::ensure!(back.heights() == dendro.heights(), "round-trip heights");
    for k in [2usize, 5, 20] {
        anyhow::ensure!(back.cut_to_k(k) == dendro.cut_to_k(k), "round-trip cut k={k}");
    }
    println!("dendrogram -> MST -> dendrogram round-trip OK");
    Ok(())
}
