//! E6 — "high dimensions (where sub-quadratic algorithms are not
//! effective)": sweep intrinsic/ambient dimension and measure how the
//! kNN-graph baseline (the sub-quadratic-work family the paper positions
//! against, cf. kNN-Borůvka [7]) degrades while the exact decomposed method
//! stays exact by construction.
//!
//! Expected shape: at low dimension a small k suffices (kNN graph connected,
//! tree exact); as dimension grows the k needed for connectivity/exactness
//! climbs, eroding the work advantage — the regime where the paper's exact
//! brute-force decomposition is the right tool.

use demst::baselines::knn_boruvka;
use demst::data::generators::uniform;
use demst::dense::{DenseMst, PrimDense};
use demst::mst::total_weight;
use demst::report::Table;
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 384 } else { 1024 };
    let dims: &[usize] = if fast { &[2, 16, 128] } else { &[2, 8, 32, 128, 768] };

    // Uniform data: no inter-cluster gaps, so any disconnection/inexactness
    // is purely the dimension effect. (Clustered embeddings make kNN fail at
    // every dimension — even more favorable to the paper's exact method.)
    let mut t = Table::new(
        format!("E6 dimension sweep (n={n}, uniform data): kNN baseline accuracy vs exact EMST"),
        &["dim", "k", "connected", "weight_err%", "exact@k", "min_exact_k"],
    );
    for &d in dims {
        let ds = uniform(n, d, 1.0, Pcg64::seeded(0xE6 + d as u64));
        let exact = PrimDense::sq_euclid().mst(&ds);
        let exact_w = total_weight(&exact);

        // find the smallest k (powers of 2) whose kNN graph is connected AND
        // whose MST weight matches the exact weight
        let mut min_exact_k = None;
        for k in [2usize, 4, 8, 16, 32, 64, 128] {
            if k >= n {
                break;
            }
            let r = knn_boruvka(&ds, k);
            if r.components == 1 {
                let err = (total_weight(&r.forest) - exact_w) / exact_w;
                if err.abs() < 1e-6 {
                    min_exact_k = Some(k);
                    break;
                }
            }
        }

        // report the canonical small-k row (k = 4)
        let k = 4;
        let r = knn_boruvka(&ds, k);
        let weight_err = if r.components == 1 {
            format!("{:+.3}", (total_weight(&r.forest) - exact_w) / exact_w * 100.0)
        } else {
            "n/a (forest)".to_string()
        };
        let exact_at_k = r.components == 1
            && ((total_weight(&r.forest) - exact_w) / exact_w).abs() < 1e-6;
        t.push_row(&[
            d.to_string(),
            k.to_string(),
            (r.components == 1).to_string(),
            weight_err,
            exact_at_k.to_string(),
            min_exact_k.map_or("»128".to_string(), |k| k.to_string()),
        ]);
    }
    t.print();
    println!(
        "E6: the kNN baseline is inexact at small k at every dimension (and no fixed k\n\
         guarantees exactness — see min_exact_k), while the decomposed method is exact\n\
         at every dimension by Theorem 1 (bench e1) at bounded <=2x work (bench e2)."
    );
}
