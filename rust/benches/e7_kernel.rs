//! E7 — the d-MST kernel hot-spot, two levels:
//!
//! 1. The Borůvka cheapest-edge step across providers (naive Rust, blocked
//!    Rust, and — with `--features backend-xla` + artifacts — the AOT
//!    Pallas/XLA executable), shape sweep. Reports effective GFLOP/s
//!    (2·N²·D flops per step call).
//! 2. The dense-Prim kernel: blocked `DistanceBlock` rows vs the scalar
//!    `Metric::dist` formulation — the refactor's headline speedup, which
//!    must hold at d ≥ 64.
//!
//! Results are printed as tables and written to `BENCH_e7.json` (override
//! the path with `DEMST_BENCH_OUT`) so perf trajectories are diffable
//! across PRs.

use demst::bench_util::Bench;
use demst::data::Dataset;
use demst::dense::step::{CheapestEdgeStep, NaiveStep, RustStep};
use demst::dense::{DenseMst, PrimDense, PrimScalar};
use demst::geometry::simd::{self, PanelSettings};
use demst::geometry::{distance_block_with, Isa, MetricKind};
use demst::report::Table;
use demst::util::prng::Pcg64;

#[derive(Clone)]
struct JsonRow {
    section: &'static str,
    n: usize,
    d: usize,
    provider: String,
    ms: f64,
    gflops: f64,
    speedup: Option<f64>,
}

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let mut json_rows: Vec<JsonRow> = Vec::new();

    // ---------------------------------------------------- cheapest-edge step
    let shapes: &[(usize, usize)] = if fast {
        &[(256, 32), (512, 128)]
    } else {
        &[(256, 32), (512, 128), (1024, 128), (1024, 768), (2048, 256)]
    };

    let mut t = Table::new(
        "E7a cheapest-edge step: provider comparison (median of samples)",
        &["N", "D", "provider", "ms", "GFLOP/s", "vs rust-blocked"],
    );
    let mut bench = Bench::from_env();
    for &(n, d) in shapes {
        let mut rng = Pcg64::seeded(0xE7 ^ (n * d) as u64);
        let points: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let comps: Vec<i32> = (0..n).map(|i| (i % 17) as i32).collect();
        let flops = 2.0 * (n as f64) * (n as f64) * (d as f64);

        // naive only at small shapes (it's O(n²d) with poor constants)
        if n <= 512 {
            let m = bench.run(format!("naive {n}x{d}"), || {
                NaiveStep.step(&points, n, d, &comps)
            });
            let ms = m.median_secs() * 1e3;
            t.push_row(&row(n, d, "naive", ms, flops, None));
            json_rows.push(JsonRow {
                section: "cheapest_edge",
                n,
                d,
                provider: "naive".into(),
                ms,
                gflops: flops / (ms / 1e3) / 1e9,
                speedup: None,
            });
        }
        let rust_ms;
        {
            let step = RustStep::default();
            let m = bench.run(format!("rust-blocked {n}x{d}"), || {
                step.step(&points, n, d, &comps)
            });
            rust_ms = m.median_secs() * 1e3;
            t.push_row(&row(n, d, "rust-blocked", rust_ms, flops, None));
            json_rows.push(JsonRow {
                section: "cheapest_edge",
                n,
                d,
                provider: "rust-blocked".into(),
                ms: rust_ms,
                gflops: flops / (rust_ms / 1e3) / 1e9,
                speedup: None,
            });
        }
        #[cfg(feature = "backend-xla")]
        {
            let artifacts = std::path::PathBuf::from("artifacts");
            if demst::runtime::artifacts_available(&artifacts) {
                let engine = demst::runtime::Engine::load(&artifacts).unwrap();
                let step = demst::runtime::XlaStep::new(engine);
                // warm the executable cache outside the timed region
                let _ = step.step(&points, n, d, &comps);
                let m = bench.run(format!("pallas-xla {n}x{d}"), || {
                    step.step(&points, n, d, &comps)
                });
                let ms = m.median_secs() * 1e3;
                t.push_row(&row(n, d, "pallas-xla", ms, flops, Some(rust_ms / ms)));
                json_rows.push(JsonRow {
                    section: "cheapest_edge",
                    n,
                    d,
                    provider: "pallas-xla".into(),
                    ms,
                    gflops: flops / (ms / 1e3) / 1e9,
                    speedup: Some(rust_ms / ms),
                });
            } else {
                eprintln!("NOTE: artifacts/ missing — XLA rows skipped; run `make artifacts`");
            }
        }
    }
    t.print();

    // -------------------------------------------- dense Prim: blocked vs scalar
    // The refactor's acceptance bar: blocked rows beat the scalar path at
    // d >= 64 (norm precompute halves flops; no per-pair virtual dispatch).
    let prim_shapes: &[(usize, usize)] =
        if fast { &[(384, 64), (384, 256)] } else { &[(512, 64), (512, 256), (768, 768)] };
    let mut t2 = Table::new(
        "E7b dense Prim d-MST: blocked DistanceBlock rows vs scalar Metric::dist",
        &["N", "D", "kernel", "ms", "GFLOP/s", "blocked speedup"],
    );
    for &(n, d) in prim_shapes {
        let mut rng = Pcg64::seeded(0x9E7 ^ (n + d) as u64);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let ds = Dataset::new(n, d, data);
        // n(n-1)/2 distance evals, ~2d flops each in Gram form
        let flops = (n * (n - 1) / 2) as f64 * 2.0 * d as f64;

        let scalar = PrimScalar::sq_euclid();
        let m = bench.run(format!("prim-scalar {n}x{d}"), || scalar.mst(&ds));
        let scalar_ms = m.median_secs() * 1e3;
        t2.push_row(&row(n, d, "prim-scalar", scalar_ms, flops, None));
        json_rows.push(JsonRow {
            section: "prim_dense",
            n,
            d,
            provider: "prim-scalar".into(),
            ms: scalar_ms,
            gflops: flops / (scalar_ms / 1e3) / 1e9,
            speedup: None,
        });

        let blocked = PrimDense::sq_euclid();
        let m = bench.run(format!("prim-blocked {n}x{d}"), || blocked.mst(&ds));
        let blocked_ms = m.median_secs() * 1e3;
        t2.push_row(&row(n, d, "prim-blocked", blocked_ms, flops, Some(scalar_ms / blocked_ms)));
        json_rows.push(JsonRow {
            section: "prim_dense",
            n,
            d,
            provider: "prim-blocked".into(),
            ms: blocked_ms,
            gflops: flops / (blocked_ms / 1e3) / 1e9,
            speedup: Some(scalar_ms / blocked_ms),
        });
    }
    t2.print();

    // ------------------------------------- panel kernels: scalar vs SIMD vs MT
    // The register-tiled SIMD micro-kernels behind `DistanceBlock::panel_block`.
    // All three providers produce bit-identical outputs (shared canonical
    // accumulation order); the rows quantify what the dispatch buys.
    let panel_dims: &[usize] = if fast { &[64, 256] } else { &[16, 64, 256, 1024] };
    let (pm, pn) = (192usize, 192usize);
    let detected = PanelSettings::detect();
    let mt_threads = detected.threads.max(2);
    let mut t3 = Table::new(
        "E7c bipartite panel kernels (sqeuclid, 192x192 block): scalar vs SIMD dispatch",
        &["N", "D", "provider", "ms", "GFLOP/s", "vs panel-scalar"],
    );
    let mut simd_speedup_d256: Option<f64> = None;
    for &d in panel_dims {
        let mut rng = Pcg64::seeded(0xC7 ^ d as u64);
        let a: Vec<f32> = (0..pm * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let b: Vec<f32> = (0..pn * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let (pa, stride) = simd::pad_rows(&a, pm, d);
        let (pb, _) = simd::pad_rows(&b, pn, d);
        let kind = MetricKind::SqEuclid;
        let flops = simd::panel_flops(kind, pm, pn, d) as f64;
        let mut out = vec![0.0f32; pm * pn];

        let providers: [(&str, PanelSettings); 3] = [
            ("panel-scalar", PanelSettings::scalar()),
            ("panel-simd", PanelSettings { threads: 1, ..detected }),
            ("panel-simd-mt", PanelSettings { threads: mt_threads, ..detected }),
        ];
        let mut scalar_ms = 0.0f64;
        for (provider, settings) in providers {
            let block = distance_block_with(kind, settings);
            let aux_a = block.prepare(&a, pm, d);
            let aux_b = block.prepare(&b, pn, d);
            let m = bench.run(format!("{provider} {pm}x{d}"), || {
                block.panel_block(&pa, &aux_a, pm, &pb, &aux_b, pn, d, stride, &mut out);
                out[0]
            });
            let ms = m.median_secs() * 1e3;
            let speedup = if provider == "panel-scalar" {
                scalar_ms = ms;
                None
            } else {
                Some(scalar_ms / ms)
            };
            if provider == "panel-simd" && d == 256 {
                simd_speedup_d256 = Some(scalar_ms / ms);
            }
            t3.push_row(&row(pm, d, provider, ms, flops, speedup));
            json_rows.push(JsonRow {
                section: "panel_simd",
                n: pm,
                d,
                provider: provider.into(),
                ms,
                gflops: flops / (ms / 1e3) / 1e9,
                speedup,
            });
        }
    }
    t3.print();

    // Smoke-level perf gate: the SIMD dispatch must beat the canonical scalar
    // kernel by >= 1.5x at d = 256 whenever a vector ISA was detected. Opt-in
    // via env so `target-cpu=native` runs (where the autovectorized scalar
    // build can close the gap) and odd machines don't flake CI.
    let assert_simd = std::env::var("DEMST_BENCH_ASSERT_SIMD").as_deref() == Ok("1");
    match (assert_simd, detected.isa, simd_speedup_d256) {
        (true, Isa::Scalar, _) => {
            println!("E7c: no vector ISA detected — SIMD speedup assert skipped");
        }
        (true, _, Some(s)) => {
            assert!(
                s >= 1.5,
                "panel-simd speedup {s:.2}x at d=256 below the 1.5x floor (isa={})",
                detected.isa.label()
            );
            println!("E7c: panel-simd speedup {s:.2}x at d=256 (floor 1.5x) — OK");
        }
        _ => {}
    }

    let out_path = std::env::var("DEMST_BENCH_OUT").unwrap_or_else(|_| "BENCH_e7.json".into());
    match std::fs::write(&out_path, to_json(&json_rows, fast)) {
        Ok(()) => println!("E7: wrote {out_path}"),
        Err(e) => eprintln!("E7: could not write {out_path}: {e}"),
    }
    println!(
        "E7: the XLA executable is the vendor-kernel stand-in; on real TPU the same\n\
         HLO lowers to Mosaic (MXU matmul) — see DESIGN.md §Perf for the roofline estimate."
    );
}

fn row(n: usize, d: usize, provider: &str, ms: f64, flops: f64, speedup: Option<f64>) -> Vec<String> {
    vec![
        n.to_string(),
        d.to_string(),
        provider.to_string(),
        format!("{ms:.2}"),
        format!("{:.2}", flops / (ms / 1e3) / 1e9),
        speedup.map_or("-".to_string(), |s| format!("{s:.2}x")),
    ]
}

/// Hand-rolled JSON (no serde in the offline vendor set).
fn to_json(rows: &[JsonRow], fast: bool) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"e7_kernel\",\n");
    s.push_str(&format!("  \"fast_mode\": {fast},\n"));
    s.push_str(&format!(
        "  \"features\": {{\"backend_xla\": {}}},\n",
        demst::runtime::backend_xla_compiled()
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let speedup = r.speedup.map_or("null".to_string(), |v| format!("{v:.4}"));
        s.push_str(&format!(
            "    {{\"section\": \"{}\", \"n\": {}, \"d\": {}, \"provider\": \"{}\", \
             \"ms\": {:.4}, \"gflops\": {:.4}, \"speedup_vs_baseline\": {}}}{}\n",
            r.section,
            r.n,
            r.d,
            r.provider,
            r.ms,
            r.gflops,
            speedup,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}
