//! E7 — the d-MST kernel hot-spot: the cheapest-edge step across providers
//! (naive Rust, blocked Rust, AOT Pallas/XLA via PJRT), shape sweep.
//!
//! This regenerates the kernel-level table that backs the paper's "exploit
//! existing high performance kernels" claim: the XLA executable is the
//! stand-in for a vendor kernel, driven unmodified from the coordinator.
//! Reports effective GFLOP/s (2·N²·D flops per step call) and the XLA
//! speedup over the blocked Rust provider.

use demst::bench_util::Bench;
use demst::dense::step::{CheapestEdgeStep, NaiveStep, RustStep};
use demst::report::Table;
use demst::runtime::{Engine, XlaStep};
use demst::util::prng::Pcg64;
use std::path::PathBuf;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let have_xla = Engine::artifacts_available(&artifacts);
    if !have_xla {
        eprintln!("NOTE: artifacts/ missing — XLA rows skipped; run `make artifacts`");
    }
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");

    let shapes: &[(usize, usize)] = if fast {
        &[(256, 32), (512, 128)]
    } else {
        &[(256, 32), (512, 128), (1024, 128), (1024, 768), (2048, 256)]
    };

    let mut t = Table::new(
        "E7 cheapest-edge step: provider comparison (median of samples)",
        &["N", "D", "provider", "ms", "GFLOP/s", "vs rust-blocked"],
    );
    let mut bench = Bench::from_env();
    for &(n, d) in shapes {
        let mut rng = Pcg64::seeded(0xE7 ^ (n * d) as u64);
        let points: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let comps: Vec<i32> = (0..n).map(|i| (i % 17) as i32).collect();
        let flops = 2.0 * (n as f64) * (n as f64) * (d as f64);

        let mut rust_ms = f64::NAN;
        // naive only at small shapes (it's O(n²d) with poor constants)
        if n <= 512 {
            let m = bench.run(format!("naive {n}x{d}"), || {
                NaiveStep.step(&points, n, d, &comps)
            });
            let ms = m.median_secs() * 1e3;
            t.push_row(&row(n, d, "naive", ms, flops, None));
        }
        {
            let step = RustStep::default();
            let m = bench.run(format!("rust-blocked {n}x{d}"), || {
                step.step(&points, n, d, &comps)
            });
            rust_ms = m.median_secs() * 1e3;
            t.push_row(&row(n, d, "rust-blocked", rust_ms, flops, None));
        }
        if have_xla {
            let engine = Engine::load(&artifacts).unwrap();
            let step = XlaStep::new(engine);
            // warm the executable cache outside the timed region
            let _ = step.step(&points, n, d, &comps);
            let m = bench.run(format!("pallas-xla {n}x{d}"), || {
                step.step(&points, n, d, &comps)
            });
            let ms = m.median_secs() * 1e3;
            t.push_row(&row(n, d, "pallas-xla", ms, flops, Some(rust_ms / ms)));
        }
    }
    t.print();
    println!(
        "E7: the XLA executable is the vendor-kernel stand-in; on real TPU the same\n\
         HLO lowers to Mosaic (MXU matmul) — see DESIGN.md §Perf for the roofline estimate."
    );
}

fn row(n: usize, d: usize, provider: &str, ms: f64, flops: f64, speedup: Option<f64>) -> Vec<String> {
    vec![
        n.to_string(),
        d.to_string(),
        provider.to_string(),
        format!("{ms:.2}"),
        format!("{:.2}", flops / (ms / 1e3) / 1e9),
        speedup.map_or("-".to_string(), |s| format!("{s:.2}x")),
    ]
}
