//! E4 — "trivially admits parallelization to |P|(|P|-1)/2 processes":
//! strong scaling of the pair-job schedule.
//!
//! Per-job kernel CPU times are measured once (gather mode), then the
//! makespan for any rank count is modeled with LPT scheduling — this testbed
//! has fewer cores than the paper's p ranks, so thread wallclock cannot
//! exhibit the speedup directly (see RunMetrics::modeled_makespan). The
//! expected shape: near-linear until ranks ≈ jobs, then flat at
//! total/max_job.

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::uniform;
use demst::decomp::pair_count;
use demst::report::Table;
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 768 } else { 3072 };
    let ds = uniform(n, 32, 1.0, Pcg64::seeded(0xE4));

    for parts in [4usize, 8] {
        let jobs = pair_count(parts);
        let cfg = RunConfig {
            parts,
            workers: 1,
            kernel: KernelChoice::BoruvkaRust,
            ..Default::default()
        };
        let out = run_distributed(&ds, &cfg).unwrap();
        let total = out.metrics.total_compute().as_secs_f64();
        let mut t = Table::new(
            format!(
                "E4 strong scaling (n={n}, |P|={parts}, {jobs} jobs; modeled LPT makespan from measured per-job CPU, total {total:.3}s)"
            ),
            &["ranks", "makespan_s", "speedup", "efficiency"],
        );
        let mut last_speedup = 0.0;
        for ranks in [1usize, 2, 4, 8, 16, jobs.max(1)] {
            if ranks > jobs.max(1) {
                continue;
            }
            let mk = out.metrics.modeled_makespan(ranks).as_secs_f64();
            let speedup = total / mk;
            t.push_row(&[
                ranks.to_string(),
                format!("{mk:.4}"),
                format!("{speedup:.2}x"),
                format!("{:.2}", speedup / ranks as f64),
            ]);
            if ranks <= jobs {
                last_speedup = speedup;
            }
        }
        t.print();
        // Shape check: at ranks == jobs the speedup must be a large fraction
        // of jobs (jobs are near-equal-sized for even partitions).
        assert!(
            last_speedup > 0.5 * jobs as f64,
            "speedup at p ranks should approach p (got {last_speedup:.2} of {jobs})"
        );
    }
    println!("E4: near-linear scaling to p = |P|(|P|-1)/2 ranks reproduced");
}
