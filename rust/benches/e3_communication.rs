//! E3 — communication: the gather is `O(|V|·|P|)` tree-edge bytes
//! (= `O(|V|·√p)` in processors), reducible to `O(|V|)` per link with the
//! `⊕(T1,T2) = MST(T1∪T2)` tree reduction the paper sketches.
//!
//! Regenerates the bytes-vs-|P| series for both gather modes with *measured*
//! netsim byte counters, plus the modeled transfer times under a 25 GbE-ish
//! link, and fits the scaling exponent of gather bytes in |P|.

use demst::config::{KernelChoice, NetConfig, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::uniform;
use demst::report::Table;
use demst::util::human_bytes;
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 512 } else { 2048 };
    let ds = uniform(n, 32, 1.0, Pcg64::seeded(0xE3));
    let link = NetConfig { simulate_delays: false, latency_us: 20, bandwidth: 3.0e9 };

    let mut t = Table::new(
        format!("E3 communication vs |P| (n={n}, d=32; measured netsim bytes)"),
        &[
            "|P|",
            "scatter",
            "gather(all)",
            "gather/|V|edges",
            "reduce(⊕)",
            "reduce/|V|edges",
            "modeled_gather_ms",
        ],
    );
    let mut gather_bytes = Vec::new();
    let parts_list: &[usize] = if fast { &[2, 4, 8] } else { &[2, 4, 8, 12, 16] };
    for &parts in parts_list {
        let mut cfg = RunConfig {
            parts,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            net: link.clone(),
            ..Default::default()
        };
        let gather = run_distributed(&ds, &cfg).unwrap();
        cfg.reduce_tree = true;
        let reduce = run_distributed(&ds, &cfg).unwrap();
        // per-edge bytes normalized by |V| (the paper's unit)
        let edge_bytes_per_v = gather.metrics.gather_bytes as f64 / n as f64;
        let reduce_per_v = reduce.metrics.gather_bytes as f64 / n as f64;
        gather_bytes.push((parts as f64, gather.metrics.gather_bytes as f64));
        let netsim = demst::coordinator::NetSim::new(link.clone());
        let modeled_ms =
            netsim.model_delay(gather.metrics.gather_bytes).as_secs_f64() * 1e3;
        t.push_row(&[
            parts.to_string(),
            human_bytes(gather.metrics.scatter_bytes),
            human_bytes(gather.metrics.gather_bytes),
            format!("{edge_bytes_per_v:.1}"),
            human_bytes(reduce.metrics.gather_bytes),
            format!("{reduce_per_v:.1}"),
            format!("{modeled_ms:.3}"),
        ]);
    }
    t.print();

    // Gathered edges are exactly Σ_pairs(|S_i|+|S_j|−1) = |V|(|P|−1) − p, so
    // the honest linear fit is against (|P|−1): bytes / (|V|·(|P|−1)) must be
    // a constant ≈ (12 + header overhead) bytes.
    let per_unit: Vec<f64> =
        gather_bytes.iter().map(|(p, b)| b / (n as f64 * (p - 1.0))).collect();
    let (lo, hi) = per_unit
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    println!(
        "gather bytes per vertex per extra part: {:?} (constant => O(|V||P|); edge wire size 12B)",
        per_unit.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>()
    );
    assert!(hi / lo < 1.15, "bytes/(|V|(|P|-1)) must be constant: {lo:.2}..{hi:.2}");
    // And against |P|−1 the log-log exponent is 1 by construction:
    let alpha = fit_exponent(
        &gather_bytes.iter().map(|(p, b)| (p - 1.0, *b)).collect::<Vec<_>>(),
    );
    println!("scaling exponent vs (|P|-1): {alpha:.3} (paper: 1.0, i.e. O(|V||P|))");
    assert!((alpha - 1.0).abs() < 0.05);

    // Reduce mode: final per-worker trees are each <= |V|-1 edges, so bytes
    // stay O(|V|) per link as workers grow (total grows only with worker
    // count, not with |P|^2 job count).
    println!("E3: gather O(|V||P|) vs reduce O(|V|)-per-link reproduced");
}

fn fit_exponent(pts: &[(f64, f64)]) -> f64 {
    let logs: Vec<(f64, f64)> = pts.iter().map(|(x, y)| (x.ln(), y.ln())).collect();
    let n = logs.len() as f64;
    let sx: f64 = logs.iter().map(|p| p.0).sum();
    let sy: f64 = logs.iter().map(|p| p.1).sum();
    let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}
