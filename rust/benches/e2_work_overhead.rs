//! E2 — the paper's cost analysis: total d-MST kernel work vs the
//! undecomposed baseline follows
//!
//!     (|P|(|P|-1)/2) · f(2|V|/|P|) / f(|V|)  →  2(|P|-1)/|P|  →  2
//!
//! for f ∈ Ω(|V|²) (here f(m) = m(m-1)/2 exactly, with the Prim kernel).
//! Regenerates the ratio-vs-|P| series, measured by counting actual distance
//! evaluations, against the paper's closed-form prediction.

use demst::data::generators::uniform;
use demst::decomp::{decomposed_mst, pair_count, DecompConfig, PartitionStrategy};
use demst::dense::{DenseMst, PrimDense};
use demst::report::Table;
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 480 } else { 1920 };
    let ds = uniform(n, 8, 1.0, Pcg64::seeded(0xE2));

    let baseline = PrimDense::sq_euclid();
    baseline.mst(&ds);
    let base = baseline.dist_evals() as f64;

    let mut t = Table::new(
        format!("E2 work overhead vs |P| (n={n}, measured distance evals; baseline {base})"),
        &["|P|", "jobs", "dist_evals", "measured_ratio", "paper_2(|P|-1)/|P|", "delta"],
    );
    let mut max_excess = 0.0f64;
    for parts in [2usize, 3, 4, 6, 8, 12, 16] {
        let cfg = DecompConfig {
            parts,
            strategy: PartitionStrategy::Block,
            seed: 0,
            keep_pair_trees: false,
        };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let measured = out.dist_evals as f64 / base;
        let paper = 2.0 * (parts as f64 - 1.0) / parts as f64;
        let delta = measured - paper;
        // exact finite-size correction: measured − paper = −(p−1)(1−2/p)/(n−1)
        let finite_size = (parts as f64 - 1.0) * (1.0 - 2.0 / parts as f64) / (n as f64 - 1.0);
        max_excess = max_excess.max((delta.abs() - finite_size).abs());
        t.push_row(&[
            parts.to_string(),
            pair_count(parts).to_string(),
            out.dist_evals.to_string(),
            format!("{measured:.4}"),
            format!("{paper:.4}"),
            format!("{delta:+.4}"),
        ]);
    }
    t.print();
    println!(
        "limit as |P|→∞: 2.0000 (paper); measured deviates from the formula by exactly\n\
         the finite-size term (p−1)(1−2/p)/(n−1); residual after correction: {max_excess:.2e}"
    );
    // After the exact finite-size correction the match must be essentially
    // perfect (counting is deterministic; only uneven-split rounding remains).
    assert!(max_excess < 2e-3, "work-overhead curve deviates from the paper's formula");
    println!("E2: work-overhead curve reproduces the paper's cost analysis");
}
