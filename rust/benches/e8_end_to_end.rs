//! E8 — end-to-end: embeddings → distributed exact EMST → single-linkage
//! dendrogram, with the headline metrics (exactness, work ratio, comm bytes,
//! modeled speedup). Bench-sized twin of examples/clustering_pipeline.rs
//! (which is the full-size driver recorded in EXPERIMENTS.md).
//!
//! Also records the dense-pair-kernel vs bipartite-merge-kernel ablation
//! (wall, distance evals, per-phase split) and the stream-reduce fold
//! micro-bench (re-sorting Kruskal folds vs the incremental merge-join
//! reducer, folds/sec + fold cost), and writes `BENCH_e8.json` (override
//! the path with `DEMST_BENCH_OUT`).

use demst::config::{KernelChoice, PairKernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, EmbeddingSpec};
use demst::decomp::reduction::{tree_merge, StreamReducer};
use demst::decomp::{decomposed_mst, DecompConfig};
use demst::dense::{DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::mst::total_weight;
use demst::report::Table;
use demst::slink::{mst_to_dendrogram, slink};
use demst::util::prng::Pcg64;
use std::time::Instant;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let (n, d) = if fast { (512, 64) } else { (2048, 256) };
    let parts = 8;
    let spec = EmbeddingSpec { n, d, latent: 8, k: 16, cluster_std: 0.35, noise: 0.01 };
    let (ds, _) = embedding_like(&spec, Pcg64::seeded(0xE8));

    let use_xla = demst::runtime::backend_xla_compiled()
        && demst::runtime::artifacts_available(std::path::Path::new("artifacts"));
    let kernel = if use_xla { KernelChoice::BoruvkaXla } else { KernelChoice::BoruvkaRust };
    // workers = 1 so per-job times are oversubscription-free for the
    // makespan model (this testbed may expose a single core).
    let mut cfg = RunConfig { parts, workers: 1, kernel: kernel.clone(), ..Default::default() };
    let out = run_distributed(&ds, &cfg).unwrap();

    // exactness
    let mono = PrimDense::sq_euclid();
    let exact = mono.mst(&ds);
    let (we, wg) = (total_weight(&exact), total_weight(&out.mst));
    assert!((we - wg).abs() < 1e-4 * (1.0 + we), "exactness: {we} vs {wg}");

    // dendrogram equivalence
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let oracle = slink(&ds, &PlainMetric(MetricKind::SqEuclid));
    let dh = dendro
        .heights()
        .iter()
        .zip(oracle.heights())
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    assert!(dh < 1e-3, "dendrogram heights match SLINK (max diff {dh})");

    // reduce-mode comm ablation
    cfg.reduce_tree = true;
    let reduced = run_distributed(&ds, &cfg).unwrap();

    let mut t = Table::new(
        format!("E8 end-to-end (n={n}, d={d}, |P|={parts}, kernel={})", kernel.name()),
        &["metric", "value"],
    );
    t.push_row(&["exact (weight match)".to_string(), "yes".to_string()]);
    t.push_row(&["dendrogram max height diff".to_string(), format!("{dh:.2e}")]);
    t.push_row(&["pair jobs".to_string(), out.metrics.jobs.to_string()]);
    t.push_row(&["dist evals".to_string(), demst::util::human_count(out.metrics.dist_evals)]);
    t.push_row(&[
        "work ratio vs monolithic prim".to_string(),
        format!("{:.2}x", out.metrics.dist_evals as f64 / mono.dist_evals() as f64),
    ]);
    t.push_row(&["scatter".to_string(), demst::util::human_bytes(out.metrics.scatter_bytes)]);
    t.push_row(&["gather".to_string(), demst::util::human_bytes(out.metrics.gather_bytes)]);
    t.push_row(&["gather (reduce mode)".to_string(), demst::util::human_bytes(reduced.metrics.gather_bytes)]);
    t.push_row(&[
        "modeled speedup (p ranks)".to_string(),
        format!(
            "{:.2}x",
            out.metrics.total_compute().as_secs_f64()
                / out.metrics.modeled_makespan(out.metrics.jobs as usize).as_secs_f64()
        ),
    ]);
    t.push_row(&["wall (this host)".to_string(), format!("{:?}", out.metrics.wall)]);
    t.print();

    // ------------------------- pair-kernel ablation: dense vs bipartite-merge
    cfg.reduce_tree = false;
    cfg.kernel = KernelChoice::PrimDense;
    let mut t2 = Table::new(
        format!("E8b pair kernels (n={n}, d={d}, |P|={parts}, workers=1)"),
        &["pair kernel", "wall ms", "dist evals", "local-mst", "pairs", "reduce", "vs dense"],
    );
    let mut rows: Vec<JsonRow> = Vec::new();
    let mut dense_ms = 0.0f64;
    for (pair_kernel, stream) in [
        (PairKernelChoice::Dense, false),
        (PairKernelChoice::BipartiteMerge, false),
        (PairKernelChoice::BipartiteMerge, true),
    ] {
        cfg.pair_kernel = pair_kernel;
        cfg.stream_reduce = stream;
        let run = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(
            demst::mst::normalize_tree(&exact),
            demst::mst::normalize_tree(&run.mst),
            "pair kernel {} must stay exact",
            pair_kernel.name()
        );
        let ms = run.metrics.wall.as_secs_f64() * 1e3;
        let name = if stream {
            format!("{} + stream-reduce", pair_kernel.name())
        } else {
            pair_kernel.name().to_string()
        };
        let speedup = if pair_kernel == PairKernelChoice::Dense && !stream {
            dense_ms = ms;
            None
        } else {
            Some(dense_ms / ms)
        };
        t2.push_row(&[
            name.clone(),
            format!("{ms:.1}"),
            demst::util::human_count(run.metrics.dist_evals),
            format!("{:?}", run.metrics.phase_local_mst),
            format!("{:?}", run.metrics.phase_pair),
            format!("{:?}", run.metrics.phase_reduce),
            speedup.map_or("-".to_string(), |s| format!("{s:.2}x")),
        ]);
        rows.push(JsonRow {
            section: "pair_kernel",
            provider: name,
            ms,
            dist_evals: run.metrics.dist_evals,
            local_mst_ms: run.metrics.phase_local_mst.as_secs_f64() * 1e3,
            pair_ms: run.metrics.phase_pair.as_secs_f64() * 1e3,
            reduce_ms: run.metrics.phase_reduce.as_secs_f64() * 1e3,
            scatter_saved_bytes: run.metrics.scatter_saved_bytes,
            panel_hit_rate: run.metrics.panel_hit_rate(),
            speedup,
        });
    }
    t2.print();

    // --------- transport ablation: simulated fabric vs loopback TCP.
    // Same seed/shape/kernel, 2 workers; the tcp run drives real `net::worker`
    // endpoints over loopback sockets, so its byte counters are actual
    // encoded frame sizes — and must reconcile with the simulated charges
    // through the resident-set invariant (charged + saved is schedule-
    // independent).
    use demst::config::TransportChoice;
    use std::net::TcpListener;

    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    cfg.stream_reduce = false;
    cfg.workers = 2;
    let sim2 = run_distributed(&ds, &cfg).unwrap();
    let sim2_ms = sim2.metrics.wall.as_secs_f64() * 1e3;

    // Two loopback-TCP ablations: window=1 (strict rendezvous) vs window=2
    // (pipelined dispatch — the next PairAssign leaves before the previous
    // reply is read). Bytes must be identical; only wall time may move.
    let mut tcp_runs = Vec::new();
    for window in [1usize, 2] {
        let mut tcfg = cfg.clone();
        tcfg.transport = TransportChoice::Tcp;
        tcfg.listen = Some("127.0.0.1:0".into());
        tcfg.pipeline_window = window;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let endpoints: Vec<_> = (0..2)
            .map(|_| {
                std::thread::spawn(move || {
                    demst::net::worker::run(&addr.to_string(), std::time::Duration::from_secs(30))
                })
            })
            .collect();
        let tcp = demst::net::launch::serve(&ds, &tcfg, &listener).unwrap();
        for h in endpoints {
            h.join().unwrap().unwrap();
        }
        assert_eq!(
            demst::mst::normalize_tree(&exact),
            demst::mst::normalize_tree(&tcp.mst),
            "loopback tcp (window={window}) must stay exact"
        );
        assert_eq!(
            tcp.metrics.scatter_bytes + tcp.metrics.scatter_saved_bytes,
            sim2.metrics.scatter_bytes + sim2.metrics.scatter_saved_bytes,
            "tcp frame bytes + savings must reconcile with the simulated model (window={window})"
        );
        tcp_runs.push(tcp);
    }
    assert_eq!(
        tcp_runs[0].metrics.scatter_bytes, tcp_runs[1].metrics.scatter_bytes,
        "the window moves frames earlier, never changes them"
    );
    let win1_ms = tcp_runs[0].metrics.wall.as_secs_f64() * 1e3;
    let win2_ms = tcp_runs[1].metrics.wall.as_secs_f64() * 1e3;
    let mut t4 = Table::new(
        format!("E8d transport (n={n}, d={d}, |P|={parts}, workers=2, bipartite-merge)"),
        &["transport", "wall ms", "scatter", "gather", "msgs", "vs sim"],
    );
    let transport_rows = [
        ("sim", &sim2.metrics, sim2_ms, None),
        ("tcp-win1", &tcp_runs[0].metrics, win1_ms, Some(sim2_ms / win1_ms.max(1e-9))),
        ("tcp-win2", &tcp_runs[1].metrics, win2_ms, Some(sim2_ms / win2_ms.max(1e-9))),
    ];
    for (name, m, ms, speedup) in &transport_rows {
        t4.push_row(&[
            name.to_string(),
            format!("{ms:.1}"),
            demst::util::human_bytes(m.scatter_bytes),
            demst::util::human_bytes(m.gather_bytes),
            m.messages.to_string(),
            speedup.map_or("-".to_string(), |s| format!("{s:.2}x")),
        ]);
    }
    t4.print();
    let transport_json: Vec<TransportRow> = transport_rows
        .iter()
        .map(|&(name, m, ms, speedup)| TransportRow {
            provider: name,
            ms,
            scatter_bytes: m.scatter_bytes,
            gather_bytes: m.gather_bytes,
            messages: m.messages,
            speedup,
        })
        .collect();

    // --------- reduction-topology ablation: where the partial MSFs ⊕-fold.
    // Three loopback-TCP runs, 3 workers each, reduce mode. `leader` gathers
    // every worker's folded partial over the leader link; `tree` and `ring`
    // fold worker↔worker along the peer data plane so only the final
    // ≤|V|-1-edge forest (plus bare 96-byte stats frames) reaches the
    // leader — strictly fewer leader-link bytes, witnessed below.
    use demst::config::ReduceTopology;
    let mut reduction_rows: Vec<ReductionRow> = Vec::new();
    let mut leader_link_baseline = 0u64;
    for topology in [ReduceTopology::Leader, ReduceTopology::Tree, ReduceTopology::Ring] {
        let mut rcfg = cfg.clone();
        rcfg.reduce_tree = true;
        rcfg.reduce_topology = topology;
        rcfg.workers = 3;
        rcfg.transport = TransportChoice::Tcp;
        rcfg.listen = Some("127.0.0.1:0".into());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let endpoints: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    demst::net::worker::run(&addr.to_string(), std::time::Duration::from_secs(30))
                })
            })
            .collect();
        let run = demst::net::launch::serve(&ds, &rcfg, &listener).unwrap();
        for h in endpoints {
            h.join().unwrap().unwrap();
        }
        assert_eq!(
            demst::mst::normalize_tree(&exact),
            demst::mst::normalize_tree(&run.mst),
            "reduce topology {} must stay exact",
            topology.name()
        );
        let leader_bytes =
            run.metrics.scatter_bytes + run.metrics.gather_bytes + run.metrics.control_bytes;
        if topology == ReduceTopology::Leader {
            leader_link_baseline = leader_bytes;
            assert_eq!(run.metrics.peer_bytes, 0, "leader topology uses no peer links");
        } else {
            assert!(
                leader_bytes < leader_link_baseline,
                "{} topology must move strictly fewer leader-link bytes ({} vs {})",
                topology.name(),
                leader_bytes,
                leader_link_baseline
            );
            assert!(run.metrics.peer_bytes > 0, "{} folds travel peer links", topology.name());
        }
        reduction_rows.push(ReductionRow {
            provider: topology.name(),
            ms: run.metrics.wall.as_secs_f64() * 1e3,
            leader_bytes,
            gather_bytes: run.metrics.gather_bytes,
            peer_bytes: run.metrics.peer_bytes,
        });
    }
    let mut t5 = Table::new(
        format!("E8e reduction topologies (n={n}, d={d}, |P|={parts}, workers=3, reduce mode)"),
        &["topology", "wall ms", "leader bytes", "gather", "peer bytes", "vs leader"],
    );
    for r in &reduction_rows {
        t5.push_row(&[
            r.provider.to_string(),
            format!("{:.1}", r.ms),
            demst::util::human_bytes(r.leader_bytes),
            demst::util::human_bytes(r.gather_bytes),
            demst::util::human_bytes(r.peer_bytes),
            if r.leader_bytes == leader_link_baseline && r.provider == "leader" {
                "-".to_string()
            } else {
                format!("{:.2}x", leader_link_baseline as f64 / r.leader_bytes.max(1) as f64)
            },
        ]);
    }
    t5.print();

    // --------- elasticity: failover and mid-run admission recovery cost.
    // Three loopback-TCP runs against real `demst worker` subprocesses (the
    // chaos hooks are per-process env vars, so in-thread endpoints won't
    // do): a clean two-worker baseline; one worker killed abruptly mid-run
    // (DEMST_CHAOS_EXIT_AFTER_JOBS); one worker stalled forever mid-run
    // (DEMST_CHAOS_PLAN tx-stall) under a short liveness deadline, with a
    // replacement admitted via Join/AdmitAck while the run is in flight.
    // Recovery overhead is the wall ratio vs the clean leg; the tree is
    // bit-identical in all three by the exactly-once return lane.
    let worker_bin = env!("CARGO_BIN_EXE_demst");
    let mut elastic_rows: Vec<ElasticRow> = Vec::new();
    let mut clean_ms = 0.0f64;
    for leg in ["clean", "failover", "admission"] {
        let mut ecfg = cfg.clone();
        ecfg.transport = TransportChoice::Tcp;
        ecfg.listen = Some("127.0.0.1:0".into());
        if leg == "admission" {
            // short deadline so the stall is detected well inside the leg;
            // still far above a single pair job's compute time
            ecfg.net.liveness_timeout_ms = 1_200;
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let spawn_worker = |envs: &[(&str, &str)]| {
            let mut c = std::process::Command::new(worker_bin);
            c.args(["worker", "--connect", &addr]);
            for (k, v) in envs {
                c.env(k, v);
            }
            c.spawn().unwrap()
        };
        let mut rigged = match leg {
            // dies on receiving its 4th pair job — no reply, no farewell
            "failover" => Some(spawn_worker(&[("DEMST_CHAOS_EXIT_AFTER_JOBS", "3")])),
            // tx: Hello(1) SetupAck(2) ShardAdvertise(3), 4 local trees
            // (4-7), then pair replies — tx8 wedges the worker on its
            // first pair reply; only the liveness deadline can see it
            "admission" => Some(spawn_worker(&[("DEMST_CHAOS_PLAN", "tx8:stall")])),
            _ => None,
        };
        let mut healthy = vec![spawn_worker(&[])];
        if rigged.is_none() {
            healthy.push(spawn_worker(&[]));
        }
        let late = (leg == "admission").then(|| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // past the two startup accepts, inside the stalled link's
                // deadline window — must be admitted mid-run
                std::thread::sleep(std::time::Duration::from_millis(300));
                std::process::Command::new(worker_bin)
                    .args(["worker", "--connect", &addr])
                    .spawn()
                    .unwrap()
            })
        });
        let run = demst::net::launch::serve(&ds, &ecfg, &listener).unwrap();
        if let Some(t) = late {
            let mut child = t.join().unwrap();
            assert!(child.wait().unwrap().success(), "admitted worker must exit 0");
        }
        for mut child in healthy {
            assert!(child.wait().unwrap().success(), "healthy worker must exit 0");
        }
        match leg {
            "failover" => {
                let status = rigged.take().unwrap().wait().unwrap();
                assert_eq!(status.code(), Some(113), "chaos exit code");
                assert!(run.metrics.worker_failures >= 1, "failover leg saw no failure");
                assert!(run.metrics.jobs_reassigned > 0, "failover leg reassigned nothing");
            }
            "admission" => {
                // the stall fault loops forever by design — reap it ourselves
                let mut child = rigged.take().unwrap();
                child.kill().unwrap();
                child.wait().unwrap();
                assert!(run.metrics.stalls_detected >= 1, "admission leg saw no stall");
                assert!(run.metrics.workers_admitted >= 1, "late worker was not admitted");
            }
            _ => assert_eq!(run.metrics.worker_failures, 0, "clean leg must stay clean"),
        }
        assert_eq!(
            demst::mst::normalize_tree(&exact),
            demst::mst::normalize_tree(&run.mst),
            "elasticity leg {leg} must stay exact"
        );
        let ms = run.metrics.wall.as_secs_f64() * 1e3;
        let overhead = if leg == "clean" {
            clean_ms = ms;
            None
        } else {
            Some(ms / clean_ms.max(1e-9))
        };
        elastic_rows.push(ElasticRow {
            provider: leg,
            ms,
            worker_failures: run.metrics.worker_failures,
            stalls_detected: run.metrics.stalls_detected,
            workers_admitted: run.metrics.workers_admitted,
            jobs_reassigned: run.metrics.jobs_reassigned,
            overhead,
        });
    }
    let mut t6 = Table::new(
        format!("E8f elasticity (n={n}, d={d}, |P|={parts}, workers=2, loopback tcp)"),
        &["leg", "wall ms", "failures", "stalls", "admitted", "reassigned", "vs clean"],
    );
    for r in &elastic_rows {
        t6.push_row(&[
            r.provider.to_string(),
            format!("{:.1}", r.ms),
            r.worker_failures.to_string(),
            r.stalls_detected.to_string(),
            r.workers_admitted.to_string(),
            r.jobs_reassigned.to_string(),
            r.overhead.map_or("-".to_string(), |v| format!("{v:.2}x")),
        ]);
    }
    t6.print();

    // ------------- stream-reduce fold micro-bench: re-sort vs merge-join.
    // Folding the same |P|(|P|-1)/2 pair trees repeatedly; the baseline is
    // the pre-incremental reducer (a full Kruskal — i.e. a re-sort of
    // forest ∪ tree — per push), the contender the presorted merge-join
    // StreamReducer.
    let trees = decomposed_mst(
        &ds,
        &DecompConfig { parts, keep_pair_trees: true, ..Default::default() },
        &PrimDense::sq_euclid(),
    )
    .pair_trees;
    let rounds = if fast { 15usize } else { 40 };
    let folds_per_round = trees.len();

    let t0 = Instant::now();
    let mut resort_forest = Vec::new();
    for _ in 0..rounds {
        resort_forest = Vec::new();
        for t in &trees {
            resort_forest = tree_merge(ds.n, &resort_forest, t);
        }
    }
    let resort_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t1 = Instant::now();
    let mut merge_forest = Vec::new();
    let mut fold_edges = 0u64;
    for _ in 0..rounds {
        let mut r = StreamReducer::new(ds.n);
        for t in &trees {
            r.push(t);
        }
        fold_edges = r.fold_edges;
        merge_forest = r.finish();
    }
    let merge_ms = t1.elapsed().as_secs_f64() * 1e3;

    assert_eq!(
        demst::mst::normalize_tree(&exact),
        demst::mst::normalize_tree(&merge_forest),
        "merge-join reducer must stay exact"
    );
    assert_eq!(
        demst::mst::normalize_tree(&resort_forest),
        demst::mst::normalize_tree(&merge_forest),
        "both fold strategies agree"
    );
    // Acceptance witness: the incremental reducer performs no full re-sort —
    // every fold scans at most |forest| + |tree| ≤ 2(|V|-1) edges.
    assert!(
        fold_edges <= folds_per_round as u64 * 2 * (ds.n as u64 - 1),
        "fold cost {fold_edges} exceeds the O(|V|)-per-fold bound"
    );

    let total_folds = (rounds * folds_per_round) as f64;
    let resort_fps = total_folds / (resort_ms / 1e3).max(1e-9);
    let merge_fps = total_folds / (merge_ms / 1e3).max(1e-9);
    let mut t3 = Table::new(
        format!("E8c stream-reduce folds ({} trees x {rounds} rounds)", folds_per_round),
        &["fold strategy", "ms", "folds/s", "fold edges/round", "vs resort"],
    );
    t3.push_row(&[
        "resort-kruskal".into(),
        format!("{resort_ms:.1}"),
        format!("{resort_fps:.0}"),
        "-".into(),
        "-".into(),
    ]);
    t3.push_row(&[
        "merge-join".into(),
        format!("{merge_ms:.1}"),
        format!("{merge_fps:.0}"),
        fold_edges.to_string(),
        format!("{:.2}x", resort_ms / merge_ms.max(1e-9)),
    ]);
    t3.print();
    let stream_rows = vec![
        StreamRow {
            provider: "resort-kruskal",
            ms: resort_ms,
            folds_per_sec: resort_fps,
            fold_edges: None,
            speedup: None,
        },
        StreamRow {
            provider: "merge-join",
            ms: merge_ms,
            folds_per_sec: merge_fps,
            fold_edges: Some(fold_edges),
            speedup: Some(resort_ms / merge_ms.max(1e-9)),
        },
    ];

    let out_path = std::env::var("DEMST_BENCH_OUT").unwrap_or_else(|_| "BENCH_e8.json".into());
    match std::fs::write(
        &out_path,
        to_json(
            &rows,
            &stream_rows,
            &transport_json,
            &reduction_rows,
            &elastic_rows,
            n,
            d,
            parts,
            fast,
        ),
    ) {
        Ok(()) => println!("E8: wrote {out_path}"),
        Err(e) => eprintln!("E8: could not write {out_path}: {e}"),
    }
    println!("E8: full pipeline exact end-to-end");
}

struct JsonRow {
    section: &'static str,
    provider: String,
    ms: f64,
    dist_evals: u64,
    local_mst_ms: f64,
    pair_ms: f64,
    reduce_ms: f64,
    scatter_saved_bytes: u64,
    panel_hit_rate: f64,
    speedup: Option<f64>,
}

struct StreamRow {
    provider: &'static str,
    ms: f64,
    folds_per_sec: f64,
    fold_edges: Option<u64>,
    speedup: Option<f64>,
}

struct TransportRow {
    provider: &'static str,
    ms: f64,
    scatter_bytes: u64,
    gather_bytes: u64,
    messages: u64,
    speedup: Option<f64>,
}

struct ReductionRow {
    provider: &'static str,
    ms: f64,
    /// Every byte the leader link carried: scatter + gather + control.
    leader_bytes: u64,
    gather_bytes: u64,
    /// Worker↔worker fold traffic (zero under the leader topology).
    peer_bytes: u64,
}

struct ElasticRow {
    provider: &'static str,
    ms: f64,
    worker_failures: u32,
    stalls_detected: u32,
    workers_admitted: u32,
    jobs_reassigned: u32,
    /// Wall ratio vs the clean two-worker leg (None for the clean leg).
    overhead: Option<f64>,
}

/// Hand-rolled JSON (no serde in the offline vendor set).
#[allow(clippy::too_many_arguments)]
fn to_json(
    rows: &[JsonRow],
    stream_rows: &[StreamRow],
    transport_rows: &[TransportRow],
    reduction_rows: &[ReductionRow],
    elastic_rows: &[ElasticRow],
    n: usize,
    d: usize,
    parts: usize,
    fast: bool,
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"e8_end_to_end\",\n");
    s.push_str(&format!("  \"fast_mode\": {fast},\n"));
    s.push_str(&format!("  \"shape\": {{\"n\": {n}, \"d\": {d}, \"parts\": {parts}}},\n"));
    s.push_str("  \"rows\": [\n");
    // collect-then-join so the separator stays correct no matter which
    // sections a future edit drops or reorders
    let mut row_strs: Vec<String> = Vec::new();
    for r in rows {
        let speedup = r.speedup.map_or("null".to_string(), |v| format!("{v:.4}"));
        row_strs.push(format!(
            "    {{\"section\": \"{}\", \"provider\": \"{}\", \"ms\": {:.4}, \
             \"dist_evals\": {}, \"local_mst_ms\": {:.4}, \"pair_ms\": {:.4}, \
             \"reduce_ms\": {:.4}, \"scatter_saved_bytes\": {}, \
             \"panel_hit_rate\": {:.4}, \"speedup_vs_dense\": {}}}",
            r.section, r.provider, r.ms, r.dist_evals, r.local_mst_ms, r.pair_ms, r.reduce_ms,
            r.scatter_saved_bytes, r.panel_hit_rate, speedup,
        ));
    }
    for r in stream_rows {
        let speedup = r.speedup.map_or("null".to_string(), |v| format!("{v:.4}"));
        let fold_edges = r.fold_edges.map_or("null".to_string(), |v| v.to_string());
        row_strs.push(format!(
            "    {{\"section\": \"stream_fold\", \"provider\": \"{}\", \"ms\": {:.4}, \
             \"folds_per_sec\": {:.2}, \"fold_edges\": {}, \"speedup_vs_resort\": {}}}",
            r.provider, r.ms, r.folds_per_sec, fold_edges, speedup,
        ));
    }
    for r in transport_rows {
        let speedup = r.speedup.map_or("null".to_string(), |v| format!("{v:.4}"));
        row_strs.push(format!(
            "    {{\"section\": \"transport\", \"provider\": \"{}\", \"ms\": {:.4}, \
             \"scatter_bytes\": {}, \"gather_bytes\": {}, \"messages\": {}, \
             \"speedup_vs_sim\": {}}}",
            r.provider, r.ms, r.scatter_bytes, r.gather_bytes, r.messages, speedup,
        ));
    }
    for r in reduction_rows {
        row_strs.push(format!(
            "    {{\"section\": \"reduction\", \"provider\": \"{}\", \"ms\": {:.4}, \
             \"leader_bytes\": {}, \"gather_bytes\": {}, \"peer_bytes\": {}}}",
            r.provider, r.ms, r.leader_bytes, r.gather_bytes, r.peer_bytes,
        ));
    }
    for r in elastic_rows {
        let overhead = r.overhead.map_or("null".to_string(), |v| format!("{v:.4}"));
        row_strs.push(format!(
            "    {{\"section\": \"elasticity\", \"provider\": \"{}\", \"ms\": {:.4}, \
             \"worker_failures\": {}, \"stalls_detected\": {}, \"workers_admitted\": {}, \
             \"jobs_reassigned\": {}, \"overhead_vs_clean\": {}}}",
            r.provider, r.ms, r.worker_failures, r.stalls_detected, r.workers_admitted,
            r.jobs_reassigned, overhead,
        ));
    }
    s.push_str(&row_strs.join(",\n"));
    s.push_str("\n  ]\n}\n");
    s
}
