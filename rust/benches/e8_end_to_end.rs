//! E8 — end-to-end: embeddings → distributed exact EMST → single-linkage
//! dendrogram, with the headline metrics (exactness, work ratio, comm bytes,
//! modeled speedup). Bench-sized twin of examples/clustering_pipeline.rs
//! (which is the full-size driver recorded in EXPERIMENTS.md).

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, EmbeddingSpec};
use demst::dense::{DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::mst::total_weight;
use demst::report::Table;
use demst::slink::{mst_to_dendrogram, slink};
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let (n, d) = if fast { (512, 64) } else { (2048, 256) };
    let parts = 8;
    let spec = EmbeddingSpec { n, d, latent: 8, k: 16, cluster_std: 0.35, noise: 0.01 };
    let (ds, _) = embedding_like(&spec, Pcg64::seeded(0xE8));

    let use_xla = demst::runtime::backend_xla_compiled()
        && demst::runtime::artifacts_available(std::path::Path::new("artifacts"));
    let kernel = if use_xla { KernelChoice::BoruvkaXla } else { KernelChoice::BoruvkaRust };
    // workers = 1 so per-job times are oversubscription-free for the
    // makespan model (this testbed may expose a single core).
    let mut cfg = RunConfig { parts, workers: 1, kernel: kernel.clone(), ..Default::default() };
    let out = run_distributed(&ds, &cfg).unwrap();

    // exactness
    let mono = PrimDense::sq_euclid();
    let exact = mono.mst(&ds);
    let (we, wg) = (total_weight(&exact), total_weight(&out.mst));
    assert!((we - wg).abs() < 1e-4 * (1.0 + we), "exactness: {we} vs {wg}");

    // dendrogram equivalence
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let oracle = slink(&ds, &PlainMetric(MetricKind::SqEuclid));
    let dh = dendro
        .heights()
        .iter()
        .zip(oracle.heights())
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    assert!(dh < 1e-3, "dendrogram heights match SLINK (max diff {dh})");

    // reduce-mode comm ablation
    cfg.reduce_tree = true;
    let reduced = run_distributed(&ds, &cfg).unwrap();

    let mut t = Table::new(
        format!("E8 end-to-end (n={n}, d={d}, |P|={parts}, kernel={})", kernel.name()),
        &["metric", "value"],
    );
    t.push_row(&["exact (weight match)".to_string(), "yes".to_string()]);
    t.push_row(&["dendrogram max height diff".to_string(), format!("{dh:.2e}")]);
    t.push_row(&["pair jobs".to_string(), out.metrics.jobs.to_string()]);
    t.push_row(&["dist evals".to_string(), demst::util::human_count(out.metrics.dist_evals)]);
    t.push_row(&[
        "work ratio vs monolithic prim".to_string(),
        format!("{:.2}x", out.metrics.dist_evals as f64 / mono.dist_evals() as f64),
    ]);
    t.push_row(&["scatter".to_string(), demst::util::human_bytes(out.metrics.scatter_bytes)]);
    t.push_row(&["gather".to_string(), demst::util::human_bytes(out.metrics.gather_bytes)]);
    t.push_row(&["gather (reduce mode)".to_string(), demst::util::human_bytes(reduced.metrics.gather_bytes)]);
    t.push_row(&[
        "modeled speedup (p ranks)".to_string(),
        format!(
            "{:.2}x",
            out.metrics.total_compute().as_secs_f64()
                / out.metrics.modeled_makespan(out.metrics.jobs as usize).as_secs_f64()
        ),
    ]);
    t.push_row(&["wall (this host)".to_string(), format!("{:?}", out.metrics.wall)]);
    t.print();
    println!("E8: full pipeline exact end-to-end");
}
