//! E5 — single-linkage dendrograms: the distributed MST's dendrogram equals
//! SLINK's exact output, conversions round-trip, and the MST→dendrogram step
//! is cheap relative to the MST itself ("can be converted between each other
//! efficiently").

use demst::bench_util::Bench;
use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, EmbeddingSpec};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::report::Table;
use demst::slink::{mst_to_dendrogram, slink};
use demst::util::prng::Pcg64;

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let n: usize = if fast { 512 } else { 2048 };
    let spec = EmbeddingSpec { n, d: 128, latent: 8, k: 16, cluster_std: 0.3, noise: 0.02 };
    let (ds, _) = embedding_like(&spec, Pcg64::seeded(0xE5));

    let cfg = RunConfig { parts: 6, workers: 2, kernel: KernelChoice::BoruvkaRust, ..Default::default() };
    let out = run_distributed(&ds, &cfg).unwrap();

    let mut bench = Bench::from_env();
    let m_convert = bench.run("mst -> dendrogram", || mst_to_dendrogram(ds.n, &out.mst)).median_secs();
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let m_back = bench.run("dendrogram -> mst", || dendro.to_mst()).median_secs();
    let m_slink =
        bench.run("SLINK exact O(n^2)", || slink(&ds, &PlainMetric(MetricKind::SqEuclid))).median_secs();
    let slink_dendro = slink(&ds, &PlainMetric(MetricKind::SqEuclid));

    // Equality of hierarchies: heights + flat cuts at many k.
    let (ha, hb) = (dendro.heights(), slink_dendro.heights());
    assert_eq!(ha.len(), hb.len());
    let max_dh = ha
        .iter()
        .zip(&hb)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    let mut cuts_equal = true;
    for k in [2usize, 4, 8, 16, 64, 256] {
        cuts_equal &= same_partition(&dendro.cut_to_k(k), &slink_dendro.cut_to_k(k));
    }
    // Round-trip preserves the hierarchy exactly.
    let back = mst_to_dendrogram(ds.n, &dendro.to_mst());
    let roundtrip = back.heights() == dendro.heights();

    let mut t = Table::new(
        format!("E5 dendrogram equivalence + conversion cost (n={n}, d=128)"),
        &["quantity", "value"],
    );
    t.push_row(&["max |height diff| vs SLINK".to_string(), format!("{max_dh:.2e}")]);
    t.push_row(&["flat cuts equal (k∈{2..256})".to_string(), cuts_equal.to_string()]);
    t.push_row(&["round-trip heights equal".to_string(), roundtrip.to_string()]);
    t.push_row(&["mst→dendrogram (s)".to_string(), format!("{m_convert:.6}")]);
    t.push_row(&["dendrogram→mst (s)".to_string(), format!("{m_back:.6}")]);
    t.push_row(&["SLINK from scratch (s)".to_string(), format!("{m_slink:.6}")]);
    t.push_row(&[
        "conversion speedup vs recompute".to_string(),
        format!("{:.0}x", m_slink / m_convert.max(1e-9)),
    ]);
    t.print();
    assert!(max_dh < 1e-3, "heights must match SLINK");
    assert!(cuts_equal && roundtrip);
    assert!(m_convert < m_slink / 10.0, "conversion must be much cheaper than recompute");
    println!("E5: dendrogram equivalence and cheap conversion reproduced");
}

fn same_partition(a: &[u32], b: &[u32]) -> bool {
    use std::collections::HashMap;
    if a.len() != b.len() {
        return false;
    }
    let (mut f, mut g) = (HashMap::new(), HashMap::new());
    a.iter().zip(b).all(|(&x, &y)| *f.entry(x).or_insert(y) == y && *g.entry(y).or_insert(x) == x)
}
