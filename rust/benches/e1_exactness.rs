//! E1 — Theorem 1 exactness: the decomposed MST equals the exact MST across
//! dataset kinds, sizes, dimensions, partition counts, and strategies, and
//! passes the independent cut/cycle-property verifiers.
//!
//! Regenerates the exactness table: one row per configuration with the
//! weight difference (must be 0 within float tolerance) and verifier status.

use demst::data::generators::{embedding_like, gaussian_blobs, uniform, BlobSpec, EmbeddingSpec};
use demst::data::Dataset;
use demst::decomp::{decomposed_mst, DecompConfig, PartitionStrategy};
use demst::dense::{DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::{Metric, MetricKind};
use demst::graph::Edge;
use demst::mst::{kruskal, normalize_tree, total_weight, verify_cycle_property};
use demst::report::Table;
use demst::util::prng::Pcg64;

fn complete_edges(ds: &Dataset) -> Vec<Edge> {
    let m = PlainMetric(MetricKind::SqEuclid);
    let mut edges = Vec::with_capacity(ds.n * (ds.n - 1) / 2);
    for i in 0..ds.n {
        for j in (i + 1)..ds.n {
            edges.push(Edge::new(i as u32, j as u32, m.dist(ds.row(i), ds.row(j))));
        }
    }
    edges
}

fn dataset(kind: &str, n: usize, d: usize, seed: u64) -> Dataset {
    match kind {
        "uniform" => uniform(n, d, 1.0, Pcg64::seeded(seed)),
        "blobs" => gaussian_blobs(
            &BlobSpec { n, d, k: 8.min(n / 4).max(1), std: 0.3, spread: 8.0 },
            Pcg64::seeded(seed),
        ),
        "embedding" => {
            embedding_like(
                &EmbeddingSpec {
                    n,
                    d,
                    latent: 8.min(d),
                    k: 8.min(n / 4).max(1),
                    cluster_std: 0.3,
                    noise: 0.02,
                },
                Pcg64::seeded(seed),
            )
            .0
        }
        _ => unreachable!(),
    }
}

fn main() {
    let fast = std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1");
    let mut table = Table::new(
        "E1 exactness: decomposed vs exact MST (identical edge sets + verifiers)",
        &["dataset", "n", "d", "|P|", "strategy", "weight", "Δweight", "tree==", "cycle-prop"],
    );
    let configs: Vec<(&str, usize, usize)> = if fast {
        vec![("uniform", 96, 8), ("blobs", 128, 32), ("embedding", 128, 64)]
    } else {
        vec![
            ("uniform", 64, 4),
            ("uniform", 256, 16),
            ("blobs", 256, 64),
            ("blobs", 512, 128),
            ("embedding", 256, 256),
            ("embedding", 512, 768),
        ]
    };
    let mut all_ok = true;
    for (kind, n, d) in configs {
        let ds = dataset(kind, n, d, 0xE1);
        let exact = kruskal(ds.n, &complete_edges(&ds));
        let exact_w = total_weight(&exact);
        let parts_list: &[usize] = if fast { &[2, 4] } else { &[2, 4, 8, 12] };
        for &parts in parts_list {
            for strategy in PartitionStrategy::ALL {
                let cfg = DecompConfig { parts, strategy, seed: 7, keep_pair_trees: false };
                let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
                let w = total_weight(&out.mst);
                let same = normalize_tree(&exact) == normalize_tree(&out.mst);
                // the O(m·n) cycle verifier is belt-and-braces on top of the
                // identical-edge-set check; cap it to small n for bench time
                let cyc = ds.n > 256
                    || verify_cycle_property(ds.n, &out.mst, &complete_edges(&ds)).is_ok();
                all_ok &= same && cyc;
                table.push_row(&[
                    kind.to_string(),
                    n.to_string(),
                    d.to_string(),
                    parts.to_string(),
                    strategy.name().to_string(),
                    format!("{w:.4}"),
                    format!("{:.2e}", (w - exact_w).abs()),
                    if same { "yes".into() } else { "NO".to_string() },
                    if ds.n > 256 {
                        "(skipped)".to_string()
                    } else if cyc {
                        "ok".into()
                    } else {
                        "FAIL".to_string()
                    },
                ]);
            }
        }
    }
    table.print();
    assert!(all_ok, "E1 exactness violated");
    println!("E1: all configurations exact (paper Theorem 1 reproduced)");
}
