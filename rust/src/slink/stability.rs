//! Stability-based flat-cluster extraction (HDBSCAN-style "excess of mass")
//! from a single-linkage dendrogram.
//!
//! Extension feature: the paper motivates EMST/SL dendrograms for clustering
//! neural embeddings, where a single global cut height (`cut_at_height`) is
//! often wrong — clusters live at different density scales. This module
//! condenses the dendrogram (dropping micro-splits below `min_cluster_size`)
//! and selects the set of clusters maximizing total *stability*
//!
//! ```text
//! stability(C) = Σ_{x ∈ C} (λ_leave(x) − λ_birth(C)),   λ = 1 / height
//! ```
//!
//! subject to selected clusters being disjoint — exactly the HDBSCAN "eom"
//! rule (Campello et al. 2013), computable in one bottom-up pass.
//! Noise points (those split off below the size threshold) get label
//! `NOISE`.

use super::dendrogram::Dendrogram;

/// Label for points not assigned to any stable cluster.
pub const NOISE: u32 = u32::MAX;

/// A node of the condensed tree.
#[derive(Clone, Debug)]
struct CNode {
    /// λ at which this cluster was born (parent split)
    birth_lambda: f64,
    /// accumulated stability Σ (λ_leave − λ_birth)
    stability: f64,
    /// child condensed clusters (post-split survivors)
    children: Vec<usize>,
    /// leaves directly owned (fell out below min size or at split points)
    points: Vec<u32>,
}

/// Result of stability extraction.
#[derive(Clone, Debug)]
pub struct StableClusters {
    /// per-leaf labels, dense `0..k`, or [`NOISE`]
    pub labels: Vec<u32>,
    /// stability score per returned cluster
    pub stabilities: Vec<f64>,
}

/// Extract stable flat clusters from a single-linkage dendrogram.
///
/// `min_cluster_size >= 2`. Heights must be non-negative (distances);
/// `λ = 1 / height` with `height = 0` treated as `λ = +big`.
pub fn extract_stable_clusters(d: &Dendrogram, min_cluster_size: usize) -> StableClusters {
    assert!(min_cluster_size >= 2, "min_cluster_size must be >= 2");
    let n = d.n;
    if n == 0 {
        return StableClusters { labels: vec![], stabilities: vec![] };
    }
    // Build children lists of the raw dendrogram (cluster ids 0..n+m).
    let m = d.merges.len();
    let total = n + m;
    let mut kids: Vec<[u32; 2]> = vec![[u32::MAX; 2]; total];
    let mut sizes: Vec<u32> = vec![1; total];
    for (i, mg) in d.merges.iter().enumerate() {
        kids[n + i] = [mg.a, mg.b];
        sizes[n + i] = mg.size;
    }
    let lambda_of = |height: f32| -> f64 {
        if height <= 0.0 {
            1e12
        } else {
            1.0 / height as f64
        }
    };
    // Roots of the raw forest.
    let parent = d.parents();
    let roots: Vec<u32> =
        (0..total as u32).filter(|&c| parent[c as usize] == u32::MAX).collect();

    // Condense: walk down from each root. A split into two children both of
    // size >= min_cluster_size creates two new condensed clusters; otherwise
    // the undersized side's points "fall out" of the current cluster at
    // that λ and the run continues into the surviving side.
    let mut nodes: Vec<CNode> = Vec::new();
    let mut leaf_owner: Vec<(usize, f64)> = vec![(usize::MAX, 0.0); n]; // (condensed node, λ_leave)
    // stack: (raw cluster id, condensed node idx)
    let mut stack: Vec<(u32, usize)> = Vec::new();
    for &root in &roots {
        let idx = nodes.len();
        nodes.push(CNode {
            birth_lambda: 0.0,
            stability: 0.0,
            children: vec![],
            points: vec![],
        });
        stack.push((root, idx));
    }
    while let Some((raw, cnode)) = stack.pop() {
        if (raw as usize) < n {
            // single leaf cluster: the point leaves at its own death... a
            // lone leaf reaching here means it owns the whole condensed node
            let lam = nodes[cnode].birth_lambda;
            nodes[cnode].points.push(raw);
            leaf_owner[raw as usize] = (cnode, lam);
            continue;
        }
        let merge = &d.merges[raw as usize - n];
        let lam = lambda_of(merge.height);
        let [a, b] = kids[raw as usize];
        let (sa, sb) = (sizes[a as usize] as usize, sizes[b as usize] as usize);
        let both_big = sa >= min_cluster_size && sb >= min_cluster_size;
        if both_big {
            // true split: two new condensed children born at λ
            for &child in &[a, b] {
                let idx = nodes.len();
                nodes.push(CNode {
                    birth_lambda: lam,
                    stability: 0.0,
                    children: vec![],
                    points: vec![],
                });
                nodes[cnode].children.push(idx);
                stack.push((child, idx));
            }
        } else {
            // the smaller side(s) fall out of cnode at λ; recurse into the
            // bigger side within the same condensed cluster
            for &child in &[a, b] {
                let cs = sizes[child as usize] as usize;
                if cs >= min_cluster_size {
                    stack.push((child, cnode));
                } else {
                    // all leaves under `child` leave cnode at λ
                    drop_out_leaves(child, n, &kids, cnode, lam, &mut leaf_owner, &mut nodes);
                }
            }
        }
    }
    // Accumulate stability: each leaf contributes (λ_leave − λ_birth(owner)).
    for (pt, &(owner, lam_leave)) in leaf_owner.iter().enumerate() {
        debug_assert!(owner != usize::MAX, "leaf {pt} unassigned");
        let birth = nodes[owner].birth_lambda;
        nodes[owner].stability += (lam_leave - birth).max(0.0);
    }
    // Points in internal condensed nodes also bound children's lifetimes:
    // standard eom adds, for each selected cluster, its own stability vs sum
    // of children's. Bottom-up selection:
    let order = topo_bottom_up(&nodes);
    let mut selected = vec![false; nodes.len()];
    let mut subtree_stability = vec![0.0f64; nodes.len()];
    for &i in &order {
        let child_sum: f64 = nodes[i].children.iter().map(|&c| subtree_stability[c]).sum();
        if nodes[i].children.is_empty() || nodes[i].stability >= child_sum {
            subtree_stability[i] = nodes[i].stability;
            selected[i] = true;
            // deselect descendants
            let mut st = nodes[i].children.clone();
            while let Some(c) = st.pop() {
                selected[c] = false;
                st.extend_from_slice(&nodes[c].children);
            }
        } else {
            subtree_stability[i] = child_sum;
        }
    }
    // Roots that are "everything in one cluster" with no competition stay
    // selected — that's correct eom behaviour for unclustered data.

    // Label points by their owning selected ancestor (walking up through the
    // condensed node of their owner); noise if none.
    // Build condensed parent pointers.
    let mut cparent = vec![usize::MAX; nodes.len()];
    for (i, node) in nodes.iter().enumerate() {
        for &c in &node.children {
            cparent[c] = i;
        }
    }
    let mut cluster_label = vec![u32::MAX; nodes.len()];
    let mut stabilities = Vec::new();
    let mut next = 0u32;
    for (i, sel) in selected.iter().enumerate() {
        if *sel {
            cluster_label[i] = next;
            stabilities.push(nodes[i].stability);
            next += 1;
        }
    }
    let mut labels = vec![NOISE; n];
    for (pt, &(owner, _)) in leaf_owner.iter().enumerate() {
        let mut cur = owner;
        let mut lab = NOISE;
        loop {
            if cluster_label[cur] != u32::MAX {
                lab = cluster_label[cur];
                break;
            }
            if cparent[cur] == usize::MAX {
                break;
            }
            cur = cparent[cur];
        }
        labels[pt] = lab;
    }
    StableClusters { labels, stabilities }
}

/// All leaves under raw cluster `raw` leave condensed node `cnode` at `lam`.
fn drop_out_leaves(
    raw: u32,
    n: usize,
    kids: &[[u32; 2]],
    cnode: usize,
    lam: f64,
    leaf_owner: &mut [(usize, f64)],
    nodes: &mut [CNode],
) {
    let mut st = vec![raw];
    while let Some(c) = st.pop() {
        if (c as usize) < n {
            nodes[cnode].points.push(c);
            leaf_owner[c as usize] = (cnode, lam);
        } else {
            st.extend_from_slice(&kids[c as usize]);
        }
    }
}

/// Children-before-parents order.
fn topo_bottom_up(nodes: &[CNode]) -> Vec<usize> {
    let mut order = Vec::with_capacity(nodes.len());
    let mut cparent = vec![usize::MAX; nodes.len()];
    for (i, node) in nodes.iter().enumerate() {
        for &c in &node.children {
            cparent[c] = i;
        }
    }
    // depth sort: deeper first
    let mut depth = vec![0usize; nodes.len()];
    for i in 0..nodes.len() {
        let mut d = 0;
        let mut cur = i;
        while cparent[cur] != usize::MAX {
            cur = cparent[cur];
            d += 1;
        }
        depth[i] = d;
    }
    let mut idx: Vec<usize> = (0..nodes.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(depth[i]));
    order.extend(idx);
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs_labeled, BlobSpec};
    use crate::dense::{DenseMst, PrimDense};
    use crate::slink::mst_to_dendrogram;
    use crate::util::prng::Pcg64;

    fn labels_match(a: &[u32], b: &[u32], ignore_noise: bool) -> f64 {
        // sampled pair agreement, optionally skipping noise
        let mut rng = Pcg64::seeded(1);
        let n = a.len();
        let (mut agree, mut tot) = (0u64, 0u64);
        for _ in 0..20_000 {
            let i = rng.next_bounded(n as u64) as usize;
            let j = rng.next_bounded(n as u64) as usize;
            if ignore_noise && (a[i] == NOISE || a[j] == NOISE) {
                continue;
            }
            tot += 1;
            if (a[i] == a[j]) == (b[i] == b[j]) {
                agree += 1;
            }
        }
        agree as f64 / tot.max(1) as f64
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let spec = BlobSpec { n: 300, d: 12, k: 5, std: 0.25, spread: 12.0 };
        let (ds, truth) = gaussian_blobs_labeled(&spec, Pcg64::seeded(10));
        let mst = PrimDense::sq_euclid().mst(&ds);
        let dendro = mst_to_dendrogram(ds.n, &mst);
        let out = extract_stable_clusters(&dendro, 10);
        let k = out.stabilities.len();
        assert_eq!(k, 5, "five stable clusters, got {k}");
        let agreement = labels_match(&out.labels, &truth, true);
        assert!(agreement > 0.99, "agreement {agreement}");
        // few noise points for tight blobs
        let noise = out.labels.iter().filter(|&&l| l == NOISE).count();
        assert!(noise < ds.n / 10, "noise {noise}");
    }

    #[test]
    fn variable_density_clusters_found_without_global_cut() {
        // One tight blob + one diffuse blob + scatter: no single height
        // separates both, but stability extraction finds both.
        let mut rng = Pcg64::seeded(11);
        let mut data = Vec::new();
        let n_tight = 80;
        let n_loose = 80;
        let n_noise = 20;
        for _ in 0..n_tight {
            data.push(0.0 + 0.05 * rng.next_gaussian() as f32);
            data.push(0.0 + 0.05 * rng.next_gaussian() as f32);
        }
        for _ in 0..n_loose {
            data.push(20.0 + 1.5 * rng.next_gaussian() as f32);
            data.push(0.0 + 1.5 * rng.next_gaussian() as f32);
        }
        for _ in 0..n_noise {
            data.push((rng.next_f32() - 0.5) * 80.0);
            data.push((rng.next_f32() - 0.5) * 80.0);
        }
        let n = n_tight + n_loose + n_noise;
        let ds = crate::data::Dataset::new(n, 2, data);
        let dendro = mst_to_dendrogram(n, &PrimDense::sq_euclid().mst(&ds));
        let out = extract_stable_clusters(&dendro, 15);
        assert!(out.stabilities.len() >= 2, "found {} clusters", out.stabilities.len());
        // tight blob points share a label; loose blob points share another
        let tight_label = out.labels[0];
        assert_ne!(tight_label, NOISE);
        let tight_frac = out.labels[..n_tight].iter().filter(|&&l| l == tight_label).count();
        assert!(tight_frac > n_tight * 9 / 10);
        let loose_label = out.labels[n_tight + n_loose / 2];
        assert_ne!(loose_label, NOISE);
        assert_ne!(tight_label, loose_label);
    }

    #[test]
    fn uniform_data_output_is_well_formed() {
        // Uniform noise has random density fluctuations, so eom may return a
        // handful of weak clusters (as real HDBSCAN does); assert structure,
        // not a specific count.
        let ds = crate::data::generators::uniform(150, 3, 1.0, Pcg64::seeded(12));
        let dendro = mst_to_dendrogram(ds.n, &PrimDense::sq_euclid().mst(&ds));
        let out = extract_stable_clusters(&dendro, 8);
        let k = out.stabilities.len();
        assert!(k >= 1 && k <= 15, "got {k}");
        // labels dense or NOISE; every non-noise cluster has >= min size
        let mut counts = vec![0usize; k];
        for &l in &out.labels {
            if l != NOISE {
                assert!((l as usize) < k);
                counts[l as usize] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c >= 8), "cluster sizes {counts:?}");
        assert!(out.stabilities.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn degenerate_inputs() {
        let d0 = mst_to_dendrogram(0, &[]);
        assert!(extract_stable_clusters(&d0, 2).labels.is_empty());
        let d1 = mst_to_dendrogram(1, &[]);
        let out = extract_stable_clusters(&d1, 2);
        assert_eq!(out.labels.len(), 1);
    }

    #[test]
    #[should_panic(expected = "min_cluster_size")]
    fn rejects_min_size_one() {
        let d = mst_to_dendrogram(2, &[crate::graph::Edge::new(0, 1, 1.0)]);
        extract_stable_clusters(&d, 1);
    }
}
