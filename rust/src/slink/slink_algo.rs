//! SLINK (Sibson 1973): optimal `O(n²)` time / `O(n)` space single-linkage.
//!
//! Independent of every MST code path in this crate, which makes it the
//! gold-standard oracle for experiment E5: the dendrogram built from the
//! *decomposed distributed* MST must equal SLINK's output.
//!
//! The pointer representation `(π, λ)` — `λ(i)` is the height at which `i`
//! last joins a cluster containing a higher-indexed object, `π(i)` that
//! object — reads as a spanning tree: edges `{i, π(i)}` with weight `λ(i)`.
//! That tree is **weight-equivalent** to the MST (its weight multiset equals
//! the MST edge weights — both equal the single-linkage merge heights) and
//! induces the identical dendrogram, but its *edge set* generally differs
//! from the MST's (π points at a cluster representative, not necessarily the
//! nearest point). `slink_mst` exposes that tree; `mst_to_dendrogram` of it
//! equals `mst_to_dendrogram` of the true MST.

use crate::data::Dataset;
use crate::geometry::Metric;
use crate::graph::Edge;
use crate::slink::dendrogram::{mst_to_dendrogram, Dendrogram};

/// Pointer representation of the single-linkage hierarchy.
pub struct SlinkPointers {
    /// π: for each i, the "parent" object it points to
    pub pi: Vec<u32>,
    /// λ: the height at which i joins π(i)'s cluster (λ(n-1) = +inf)
    pub lambda: Vec<f32>,
}

/// Run SLINK over the dataset with the given metric.
pub fn slink_pointers(ds: &Dataset, metric: &dyn Metric) -> SlinkPointers {
    let n = ds.n;
    let mut pi = vec![0u32; n];
    let mut lambda = vec![f32::INFINITY; n];
    let mut m = vec![0.0f32; n];
    for i in 0..n {
        pi[i] = i as u32;
        lambda[i] = f32::INFINITY;
        for j in 0..i {
            m[j] = metric.dist(ds.row(j), ds.row(i));
        }
        for j in 0..i {
            let pj = pi[j] as usize;
            if lambda[j] >= m[j] {
                if lambda[j] < m[pj] {
                    m[pj] = lambda[j];
                }
                lambda[j] = m[j];
                pi[j] = i as u32;
            } else if m[j] < m[pj] {
                m[pj] = m[j];
            }
        }
        for j in 0..i {
            if lambda[j] >= lambda[pi[j] as usize] {
                pi[j] = i as u32;
            }
        }
    }
    SlinkPointers { pi, lambda }
}

/// The spanning tree hidden in SLINK's pointer representation: edges
/// `{i, π(i), λ(i)}` for all `i` with finite λ. Weight-equivalent to the MST
/// (identical weight multiset and dendrogram; edge set may differ) — this is
/// the dendrogram → tree direction of the paper's "converted between each
/// other efficiently".
pub fn slink_mst(ds: &Dataset, metric: &dyn Metric) -> Vec<Edge> {
    let p = slink_pointers(ds, metric);
    (0..ds.n)
        .filter(|&i| p.lambda[i].is_finite())
        .map(|i| Edge::new(i as u32, p.pi[i], p.lambda[i]))
        .collect()
}

/// Exact single-linkage dendrogram via SLINK.
pub fn slink(ds: &Dataset, metric: &dyn Metric) -> Dendrogram {
    mst_to_dendrogram(ds.n, &slink_mst(ds, metric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs_labeled, uniform, BlobSpec};
    use crate::dense::{DenseMst, PrimDense};
    use crate::geometry::metric::PlainMetric;
    use crate::geometry::MetricKind;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::total_weight;
    use crate::util::prng::Pcg64;

    fn metric() -> PlainMetric {
        PlainMetric(MetricKind::SqEuclid)
    }

    #[test]
    fn slink_tree_is_an_mst() {
        let ds = uniform(50, 6, 1.0, Pcg64::seeded(100));
        let t = slink_mst(&ds, &metric());
        assert!(is_spanning_tree(ds.n, &t));
        let prim = PrimDense::sq_euclid().mst(&ds);
        let (a, b) = (total_weight(&t), total_weight(&prim));
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "slink={a} prim={b}");
    }

    #[test]
    fn slink_heights_equal_mst_weights() {
        let ds = uniform(40, 4, 1.0, Pcg64::seeded(101));
        let d = slink(&ds, &metric());
        let mut heights = d.heights();
        heights.sort_by(f32::total_cmp);
        let mut weights: Vec<f32> =
            PrimDense::sq_euclid().mst(&ds).iter().map(|e| e.w).collect();
        weights.sort_by(f32::total_cmp);
        assert_eq!(heights.len(), weights.len());
        for (h, w) in heights.iter().zip(&weights) {
            assert!((h - w).abs() < 1e-5 * (1.0 + w.abs()), "h={h} w={w}");
        }
    }

    #[test]
    fn dendrogram_from_mst_matches_slink_clusters() {
        let spec = BlobSpec { n: 64, d: 8, k: 4, std: 0.2, spread: 8.0 };
        let (ds, truth) = gaussian_blobs_labeled(&spec, Pcg64::seeded(102));
        let via_slink = slink(&ds, &metric());
        let via_mst = mst_to_dendrogram(ds.n, &PrimDense::sq_euclid().mst(&ds));
        let a = via_slink.cut_to_k(4);
        let b = via_mst.cut_to_k(4);
        // identical partitions (up to label permutation)
        assert!(same_partition(&a, &b), "slink vs mst cut disagree");
        // and with well-separated blobs, both recover ground truth
        assert!(same_partition(&a, &truth), "4 tight blobs should be exactly recovered");
    }

    #[test]
    fn two_points() {
        let ds = Dataset::new(2, 1, vec![0.0, 2.0]);
        let d = slink(&ds, &metric());
        assert_eq!(d.merges.len(), 1);
        assert_eq!(d.merges[0].height, 4.0);
    }

    /// Same partition up to label renaming.
    pub(crate) fn same_partition(a: &[u32], b: &[u32]) -> bool {
        if a.len() != b.len() {
            return false;
        }
        use std::collections::HashMap;
        let mut fwd: HashMap<u32, u32> = HashMap::new();
        let mut bwd: HashMap<u32, u32> = HashMap::new();
        for (&x, &y) in a.iter().zip(b) {
            if *fwd.entry(x).or_insert(y) != y {
                return false;
            }
            if *bwd.entry(y).or_insert(x) != x {
                return false;
            }
        }
        true
    }
}
