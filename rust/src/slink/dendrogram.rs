//! Single-linkage dendrograms (scipy `linkage`-style merge lists) and the
//! MST → dendrogram conversion.

use crate::graph::{Edge, UnionFind};

/// One agglomerative merge. Cluster ids: leaves are `0..n`; the i-th merge
/// creates cluster `n + i` (scipy convention).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Merge {
    pub a: u32,
    pub b: u32,
    /// linkage distance at which `a` and `b` merge
    pub height: f32,
    /// size of the merged cluster
    pub size: u32,
}

/// A single-linkage dendrogram over `n` leaves. For disconnected inputs the
/// merge list is shorter than `n-1` (a forest of dendrograms).
#[derive(Clone, Debug, PartialEq)]
pub struct Dendrogram {
    pub n: usize,
    pub merges: Vec<Merge>,
}

impl Dendrogram {
    /// Merge heights in merge order (non-decreasing for single linkage).
    pub fn heights(&self) -> Vec<f32> {
        self.merges.iter().map(|m| m.height).collect()
    }

    /// Parent cluster id of every cluster id (`u32::MAX` for roots).
    pub fn parents(&self) -> Vec<u32> {
        let total = self.n + self.merges.len();
        let mut parent = vec![u32::MAX; total];
        for (i, m) in self.merges.iter().enumerate() {
            let id = (self.n + i) as u32;
            parent[m.a as usize] = id;
            parent[m.b as usize] = id;
        }
        parent
    }

    /// Cophenetic distance: the height at which leaves `i` and `j` first
    /// share a cluster (`+inf` if they never merge). `O(depth)` per query.
    pub fn cophenetic(&self, i: u32, j: u32) -> f32 {
        assert!((i as usize) < self.n && (j as usize) < self.n);
        if i == j {
            return 0.0;
        }
        let parent = self.parents();
        // Collect i's ancestor set with the height each ancestor was made at.
        let total = self.n + self.merges.len();
        let mut anc = vec![false; total];
        let mut cur = i;
        loop {
            anc[cur as usize] = true;
            let p = parent[cur as usize];
            if p == u32::MAX {
                break;
            }
            cur = p;
        }
        let mut cur = j;
        loop {
            if anc[cur as usize] {
                // cur is a cluster created by merge (cur - n), unless leaf j==i
                if (cur as usize) < self.n {
                    return 0.0; // unreachable: i != j leaves
                }
                return self.merges[cur as usize - self.n].height;
            }
            let p = parent[cur as usize];
            if p == u32::MAX {
                return f32::INFINITY;
            }
            cur = p;
        }
    }

    /// Flat clusters cutting at `height` (merges with `height <= h` applied).
    pub fn cut_at_height(&self, h: f32) -> Vec<u32> {
        cut_at_height(self, h)
    }

    /// Flat clusters with exactly `k` clusters (or the max possible for a
    /// forest with more than `k` roots).
    pub fn cut_to_k(&self, k: usize) -> Vec<u32> {
        cut_to_k(self, k)
    }

    /// Convert back to a spanning tree of the ultrametric: for each merge,
    /// connect representative leaves of its two children at the merge height.
    /// The result is a valid MST of the single-linkage ultrametric, i.e.
    /// `mst_to_dendrogram(to_mst())` reproduces the same merge heights —
    /// the paper's "can be converted between each other efficiently".
    pub fn to_mst(&self) -> Vec<Edge> {
        let total = self.n + self.merges.len();
        // representative leaf of every cluster id
        let mut rep: Vec<u32> = (0..total as u32).collect();
        for (i, m) in self.merges.iter().enumerate() {
            let id = self.n + i;
            rep[id] = rep[m.a as usize].min(rep[m.b as usize]);
        }
        self.merges
            .iter()
            .map(|m| Edge::new(rep[m.a as usize], rep[m.b as usize], m.height))
            .collect()
    }
}

/// Build the single-linkage dendrogram from an MST/MSF: sort edges ascending
/// (strict order) and merge with a union-find. `O(n log n)` beyond the MST.
pub fn mst_to_dendrogram(n: usize, mst: &[Edge]) -> Dendrogram {
    let mut edges: Vec<Edge> = mst.to_vec();
    edges.sort_unstable();
    let mut uf = UnionFind::new(n);
    // cluster id and size currently associated with each union-find root
    let mut cluster: Vec<u32> = (0..n as u32).collect();
    let mut size: Vec<u32> = vec![1; n];
    let mut merges = Vec::with_capacity(edges.len());
    for e in &edges {
        let (ru, rv) = (uf.find(e.u), uf.find(e.v));
        assert_ne!(ru, rv, "input contains a cycle: not a forest");
        let (ca, cb) = (cluster[ru as usize], cluster[rv as usize]);
        let sz = size[ru as usize] + size[rv as usize];
        let id = (n + merges.len()) as u32;
        merges.push(Merge { a: ca.min(cb), b: ca.max(cb), height: e.w, size: sz });
        uf.union(ru, rv);
        let r = uf.find(ru);
        cluster[r as usize] = id;
        size[r as usize] = sz;
    }
    Dendrogram { n, merges }
}

/// Flat clusters cutting at `height`: dense labels `0..k`.
pub fn cut_at_height(d: &Dendrogram, h: f32) -> Vec<u32> {
    let mut uf = UnionFind::new(d.n + d.merges.len());
    for (i, m) in d.merges.iter().enumerate() {
        if m.height <= h {
            let id = (d.n + i) as u32;
            uf.union(m.a, id);
            uf.union(m.b, id);
        }
    }
    dense_leaf_labels(d.n, &mut uf)
}

/// Flat clusters with exactly `k` clusters by applying merges ascending until
/// `k` remain. (Single-linkage heights are non-decreasing in merge order, so
/// this equals cutting between the `(n-k)`-th and `(n-k+1)`-th heights.)
pub fn cut_to_k(d: &Dendrogram, k: usize) -> Vec<u32> {
    assert!(k >= 1);
    let mut uf = UnionFind::new(d.n + d.merges.len());
    // Applying t merges leaves n - t clusters, so t = n - k (clamped to the
    // number of available merges — a forest may not reach k=1).
    let take = d.n.saturating_sub(k).min(d.merges.len());
    for (i, m) in d.merges.iter().take(take).enumerate() {
        let id = (d.n + i) as u32;
        uf.union(m.a, id);
        uf.union(m.b, id);
    }
    dense_leaf_labels(d.n, &mut uf)
}

fn dense_leaf_labels(n: usize, uf: &mut UnionFind) -> Vec<u32> {
    let mut map: Vec<u32> = vec![u32::MAX; uf.len()];
    let mut next = 0u32;
    let mut out = Vec::with_capacity(n);
    for i in 0..n as u32 {
        let r = uf.find(i);
        if map[r as usize] == u32::MAX {
            map[r as usize] = next;
            next += 1;
        }
        out.push(map[r as usize]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// chain 0-1 (w=1), 1-2 (w=2), plus far pair 3-4 (w=0.5) and bridge 2-3 (w=10)
    fn sample_tree() -> (usize, Vec<Edge>) {
        (
            5,
            vec![
                Edge::new(0, 1, 1.0),
                Edge::new(1, 2, 2.0),
                Edge::new(3, 4, 0.5),
                Edge::new(2, 3, 10.0),
            ],
        )
    }

    #[test]
    fn heights_sorted_and_match_weights() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        assert_eq!(d.heights(), vec![0.5, 1.0, 2.0, 10.0]);
        assert_eq!(d.merges.len(), n - 1);
        assert_eq!(d.merges.last().unwrap().size, 5);
    }

    #[test]
    fn merge_structure_correct() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        // first merge: leaves 3,4 at 0.5 -> cluster 5
        assert_eq!(d.merges[0], Merge { a: 3, b: 4, height: 0.5, size: 2 });
        // second: leaves 0,1 at 1.0 -> cluster 6
        assert_eq!(d.merges[1], Merge { a: 0, b: 1, height: 1.0, size: 2 });
        // third: cluster 6 with leaf 2 at 2.0 -> cluster 7
        assert_eq!(d.merges[2], Merge { a: 2, b: 6, height: 2.0, size: 3 });
        // fourth: clusters 5 and 7 at 10.0
        assert_eq!(d.merges[3], Merge { a: 5, b: 7, height: 10.0, size: 5 });
    }

    #[test]
    fn cut_at_height_levels() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        assert_eq!(cut_at_height(&d, 0.0), vec![0, 1, 2, 3, 4]);
        let at1 = cut_at_height(&d, 1.0);
        assert_eq!(at1[0], at1[1]);
        assert_ne!(at1[1], at1[2]);
        assert_eq!(at1[3], at1[4]);
        let all = cut_at_height(&d, 100.0);
        assert!(all.iter().all(|&l| l == 0));
    }

    #[test]
    fn cut_to_k_counts() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        for k in 1..=5 {
            let labels = cut_to_k(&d, k);
            let mut u = labels.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), k, "k={k}");
        }
        // k=2 must split at the big bridge: {0,1,2} vs {3,4}
        let l2 = cut_to_k(&d, 2);
        assert_eq!(l2[0], l2[1]);
        assert_eq!(l2[1], l2[2]);
        assert_eq!(l2[3], l2[4]);
        assert_ne!(l2[0], l2[3]);
    }

    #[test]
    fn cophenetic_heights() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        assert_eq!(d.cophenetic(0, 1), 1.0);
        assert_eq!(d.cophenetic(0, 2), 2.0);
        assert_eq!(d.cophenetic(3, 4), 0.5);
        assert_eq!(d.cophenetic(0, 4), 10.0);
        assert_eq!(d.cophenetic(2, 2), 0.0);
    }

    #[test]
    fn mst_roundtrip_preserves_heights_and_clusters() {
        let (n, t) = sample_tree();
        let d = mst_to_dendrogram(n, &t);
        let back = d.to_mst();
        assert_eq!(back.len(), t.len());
        let d2 = mst_to_dendrogram(n, &back);
        assert_eq!(d.heights(), d2.heights());
        // flat clusterings agree at every height
        for h in [0.4, 0.6, 1.5, 5.0, 11.0] {
            assert_eq!(cut_at_height(&d, h), cut_at_height(&d2, h), "h={h}");
        }
    }

    #[test]
    fn forest_input_gives_partial_dendrogram() {
        let t = vec![Edge::new(0, 1, 1.0)]; // 3 leaves, one edge
        let d = mst_to_dendrogram(3, &t);
        assert_eq!(d.merges.len(), 1);
        assert_eq!(d.cophenetic(0, 2), f32::INFINITY);
        let labels = cut_at_height(&d, 100.0);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[0], labels[2]);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_input_panics() {
        let t = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 1.0), Edge::new(0, 2, 1.0)];
        mst_to_dendrogram(3, &t);
    }

    #[test]
    fn empty_and_singleton() {
        let d = mst_to_dendrogram(0, &[]);
        assert!(d.merges.is_empty());
        let d1 = mst_to_dendrogram(1, &[]);
        assert_eq!(cut_to_k(&d1, 1), vec![0]);
    }
}
