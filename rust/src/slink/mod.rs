//! Single-linkage clustering: dendrograms, the MST ↔ dendrogram conversions
//! the paper motivates, and the SLINK exact baseline.
//!
//! Key classical facts exercised here (and verified in tests):
//! - The single-linkage dendrogram's merge heights are exactly the MST edge
//!   weights; building the dendrogram from the MST is a sort + union-find
//!   (`mst_to_dendrogram`, `O(n log n)`).
//! - SLINK's pointer representation `(π, λ)` *is* a minimum spanning tree
//!   (edges `{i, π(i)}` with weight `λ(i)`), giving the reverse conversion
//!   and an independent `O(n²)` exact baseline.

pub mod dendrogram;
pub mod slink_algo;
pub mod stability;

pub use dendrogram::{cut_at_height, cut_to_k, mst_to_dendrogram, Dendrogram, Merge};
pub use slink_algo::{slink, slink_mst};
pub use stability::{extract_stable_clusters, StableClusters, NOISE};
