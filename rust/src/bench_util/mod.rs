//! Micro/macro benchmark harness for the `harness = false` bench targets
//! (criterion is not in the offline vendor set).
//!
//! Methodology: warmup runs, then `samples` timed runs; report median with
//! p10/p90 spread. Deterministic workloads + median keep noise manageable in
//! shared-CPU environments.

use crate::util::timer::fmt_duration;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        percentile(&self.samples, 0.5)
    }

    pub fn p10(&self) -> Duration {
        percentile(&self.samples, 0.1)
    }

    pub fn p90(&self) -> Duration {
        percentile(&self.samples, 0.9)
    }

    pub fn median_secs(&self) -> f64 {
        self.median().as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} median {:>10}  p10 {:>10}  p90 {:>10}  ({} samples)",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.p10()),
            fmt_duration(self.p90()),
            self.samples.len()
        )
    }
}

fn percentile(samples: &[Duration], q: f64) -> Duration {
    assert!(!samples.is_empty());
    let mut s = samples.to_vec();
    s.sort();
    let idx = ((s.len() - 1) as f64 * q).round() as usize;
    s[idx]
}

/// Benchmark runner with warmup.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 1, samples: 5, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        assert!(samples >= 1);
        Self { warmup, samples, results: Vec::new() }
    }

    /// Quick-mode constructor honoring `DEMST_BENCH_FAST=1` (used by CI and
    /// `make bench-fast` to keep runtimes short).
    pub fn from_env() -> Self {
        if std::env::var("DEMST_BENCH_FAST").as_deref() == Ok("1") {
            Self::new(0, 2)
        } else {
            Self::default()
        }
    }

    /// Time `f`, which must return something observable to prevent DCE; the
    /// value is black-boxed.
    pub fn run<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &Measurement {
        let name = name.into();
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        let m = Measurement { name, samples };
        eprintln!("{}", m.summary());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Opaque value sink (std::hint::black_box wrapper; keeps call sites tidy).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_records() {
        let mut b = Bench::new(0, 3);
        let m = b.run("noop-ish", || (0..1000).sum::<u64>());
        assert_eq!(m.samples.len(), 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn percentiles_ordered() {
        let m = Measurement {
            name: "x".into(),
            samples: (1..=9).map(|i| Duration::from_millis(i * 10)).collect(),
        };
        assert!(m.p10() <= m.median());
        assert!(m.median() <= m.p90());
        assert_eq!(m.median(), Duration::from_millis(50));
    }

    #[test]
    fn fast_env_small_samples() {
        std::env::set_var("DEMST_BENCH_FAST", "1");
        let b = Bench::from_env();
        assert_eq!(b.samples, 2);
        std::env::remove_var("DEMST_BENCH_FAST");
    }
}
