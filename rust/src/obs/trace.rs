//! Chrome-trace / Perfetto JSON exporter (`demst run --trace-out`).
//!
//! Emits the JSON Array Format that `chrome://tracing`, Perfetto UI, and
//! `speedscope` all ingest: one named track per worker (plus one for the
//! leader's own fold/reduce work), a `ph:"X"` duration slice per recorded
//! interval span, and a `ph:"i"` instant per point event (stall, admit,
//! chaos fault, failover). Timestamps are microseconds on the leader's
//! clock — worker spans were already re-based when they came off the wire.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::Path;

use super::{json, Span};
use crate::coordinator::RunMetrics;

/// Track id the leader records its own spans under. Worker ranks are
/// u8-sized on the wire, so the top of the u16 range can never collide.
pub const LEADER_TRACK: u16 = u16::MAX;

fn track_name(worker: u16) -> String {
    if worker == LEADER_TRACK {
        "leader".to_string()
    } else {
        format!("worker {worker}")
    }
}

fn event(span: &Span) -> String {
    let name = span.kind().map_or("unknown", |k| k.name());
    let ts = json::num(span.start_ns as f64 / 1000.0);
    let args = format!(
        "{{{}, {}}}",
        json::field("id", &span.id.to_string()),
        json::field("arg", &span.arg.to_string())
    );
    let instant = span.kind().is_none_or(|k| k.is_instant());
    if instant {
        format!(
            "{{{}, \"ph\": \"i\", \"s\": \"t\", \"ts\": {ts}, \"pid\": 0, \"tid\": {}, \"cat\": \"demst\", \"args\": {args}}}",
            json::field("name", &json::string(name)),
            span.worker
        )
    } else {
        let dur = json::num(span.end_ns.saturating_sub(span.start_ns) as f64 / 1000.0);
        format!(
            "{{{}, \"ph\": \"X\", \"ts\": {ts}, \"dur\": {dur}, \"pid\": 0, \"tid\": {}, \"cat\": \"demst\", \"args\": {args}}}",
            json::field("name", &json::string(name)),
            span.worker
        )
    }
}

/// Render the full trace document from the run's reassembled spans.
pub fn render_chrome_trace(metrics: &RunMetrics) -> String {
    let tracks: BTreeSet<u16> = metrics.spans.iter().map(|s| s.worker).collect();
    let mut events: Vec<String> = Vec::with_capacity(metrics.spans.len() + tracks.len());
    for &t in &tracks {
        events.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": {t}, \"args\": {{{}}}}}",
            json::field("name", &json::string(&track_name(t)))
        ));
    }
    for span in &metrics.spans {
        events.push(event(span));
    }
    let mut out = String::from("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [\n");
    out.push_str(&events.join(",\n"));
    out.push_str("\n]}\n");
    out
}

pub fn write_chrome_trace(path: &Path, metrics: &RunMetrics) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_chrome_trace(metrics).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;

    fn metrics_with(spans: Vec<Span>) -> RunMetrics {
        RunMetrics { spans, ..Default::default() }
    }

    #[test]
    fn duration_and_instant_events_render_with_tracks() {
        let m = metrics_with(vec![
            Span {
                kind_code: SpanKind::Job.code(),
                worker: 1,
                id: 7,
                arg: 1234,
                start_ns: 2_000,
                end_ns: 5_500,
            },
            Span {
                kind_code: SpanKind::Admit.code(),
                worker: LEADER_TRACK,
                id: 2,
                arg: 2,
                start_ns: 9_000,
                end_ns: 9_000,
            },
        ]);
        let doc = render_chrome_trace(&m);
        assert!(doc.contains("\"traceEvents\""), "{doc}");
        assert!(doc.contains("\"name\": \"job\""), "{doc}");
        assert!(doc.contains("\"ph\": \"X\""), "{doc}");
        assert!(doc.contains("\"ts\": 2, \"dur\": 3.5"), "µs with fraction: {doc}");
        assert!(doc.contains("\"name\": \"admit\""), "{doc}");
        assert!(doc.contains("\"ph\": \"i\""), "{doc}");
        assert!(doc.contains("\"worker 1\""), "{doc}");
        assert!(doc.contains("\"leader\""), "{doc}");
        assert!(doc.contains("\"id\": 7"), "{doc}");
        assert!(doc.contains("\"arg\": 1234"), "{doc}");
    }

    #[test]
    fn empty_timeline_is_still_a_valid_document() {
        let doc = render_chrome_trace(&RunMetrics::default());
        assert!(doc.starts_with('{') && doc.trim_end().ends_with('}'));
        assert!(doc.contains("\"traceEvents\": [\n\n]"), "{doc}");
    }

    #[test]
    fn unknown_kind_codes_degrade_to_instants_not_panics() {
        // A newer worker could ship a kind this leader doesn't know.
        let m = metrics_with(vec![Span {
            kind_code: 200,
            worker: 0,
            id: 1,
            arg: 0,
            start_ns: 10,
            end_ns: 20,
        }]);
        let doc = render_chrome_trace(&m);
        assert!(doc.contains("\"unknown\""), "{doc}");
    }
}
