//! Prometheus text exposition (format 0.0.4, hand-rolled — the offline
//! vendor set has no HTTP or metrics crates) over a tiny blocking HTTP
//! listener.
//!
//! The listener polls a non-blocking accept loop so `stop()` takes effect
//! within one poll interval; each request gets the fleet-merged snapshot
//! rendered fresh, so a mid-run scrape sees live worker pushes. This is
//! the per-request metrics surface `demst serve` will mount.

use super::metrics::{bucket_bounds, Ctr, Gauge, Hist, MetricsHub, Snapshot};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const POLL: Duration = Duration::from_millis(25);

/// Render a merged snapshot as Prometheus text format 0.0.4.
///
/// Histograms ship their occupied buckets as cumulative `_bucket{le=...}`
/// series plus the mandatory `+Inf` bucket, `_sum`, and `_count`; recorded
/// nanoseconds scale to seconds (and milli-GFLOP/s to GFLOP/s) so the `le`
/// bounds are in base units.
pub fn render(snap: &Snapshot, workers_reporting: usize) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str("# HELP demst_fleet_workers Remote workers that have pushed metrics\n");
    out.push_str("# TYPE demst_fleet_workers gauge\n");
    out.push_str(&format!("demst_fleet_workers {workers_reporting}\n"));
    for c in Ctr::ALL {
        out.push_str(&format!("# HELP demst_{} {}\n", c.name(), c.help()));
        out.push_str(&format!("# TYPE demst_{} counter\n", c.name()));
        out.push_str(&format!("demst_{} {}\n", c.name(), snap.counter(c)));
    }
    for g in Gauge::ALL {
        out.push_str(&format!("# HELP demst_{} {}\n", g.name(), g.help()));
        out.push_str(&format!("# TYPE demst_{} gauge\n", g.name()));
        out.push_str(&format!("demst_{} {}\n", g.name(), snap.gauge(g)));
    }
    for h in Hist::ALL {
        let hs = snap.hist(h);
        let scale = h.unit_scale();
        out.push_str(&format!("# HELP demst_{} {}\n", h.name(), h.help()));
        out.push_str(&format!("# TYPE demst_{} histogram\n", h.name()));
        let mut cum = 0u64;
        for (idx, &c) in hs.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            let (_, hi) = bucket_bounds(idx);
            out.push_str(&format!(
                "demst_{}_bucket{{le=\"{}\"}} {cum}\n",
                h.name(),
                num(hi as f64 / scale)
            ));
        }
        out.push_str(&format!("demst_{}_bucket{{le=\"+Inf\"}} {}\n", h.name(), hs.count));
        out.push_str(&format!("demst_{}_sum {}\n", h.name(), num(hs.sum as f64 / scale)));
        out.push_str(&format!("demst_{}_count {}\n", h.name(), hs.count));
    }
    if let Some(slow) = snap.slowest {
        out.push_str("# HELP demst_slowest_job_seconds Latency of the slowest pair job\n");
        out.push_str("# TYPE demst_slowest_job_seconds gauge\n");
        out.push_str(&format!(
            "demst_slowest_job_seconds{{i=\"{}\",j=\"{}\"}} {}\n",
            slow.i,
            slow.j,
            num(slow.ns as f64 / 1e9)
        ));
    }
    out
}

/// Prometheus floats: plain decimal, no exponent for the magnitudes we
/// emit; integral values still print a fraction-free form.
fn num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Handle to a running exposition listener; dropping or calling
/// [`MetricsServer::stop`] shuts the accept loop down.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `listen` (e.g. `127.0.0.1:9399`, port 0 for ephemeral) and
    /// serve `GET /metrics` from `hub.merged()` until stopped.
    pub fn start(listen: &str, hub: Arc<MetricsHub>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding metrics listener on {listen}"))?;
        let addr = listener.local_addr().context("metrics listener local addr")?;
        listener.set_nonblocking(true).context("metrics listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("demst-metrics".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let body = render(&hub.merged(), hub.workers_reporting());
                            let _ = respond(stream, &body);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL);
                        }
                        Err(_) => std::thread::sleep(POLL),
                    }
                }
            })
            .context("spawning metrics listener thread")?;
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address — the real port when started with port 0.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Minimal HTTP/1.1: drain the request head, answer every path with the
/// exposition body (a scraper that asks for `/` gets metrics too — there
/// is nothing else to serve).
fn respond(mut stream: TcpStream, body: &str) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    let mut head = [0u8; 1024];
    let mut got = 0;
    while got < head.len() {
        match stream.read(&mut head[got..]) {
            Ok(0) => break,
            Ok(n) => {
                got += n;
                if head[..got].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => break, // timeout or reset: answer anyway, then close
        }
    }
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(resp.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    fn scrape(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        buf
    }

    #[test]
    fn render_emits_valid_exposition_lines() {
        let r = Registry::new();
        r.observe_job(2_000_000_000, 3, 7); // 2s
        r.add(Ctr::DistEvals, 50);
        let text = render(&r.snapshot(), 2);
        assert!(text.contains("demst_fleet_workers 2"));
        assert!(text.contains("# TYPE demst_jobs_completed_total counter"));
        assert!(text.contains("demst_dist_evals_total 50"));
        assert!(text.contains("# TYPE demst_job_latency_seconds histogram"));
        assert!(text.contains("demst_job_latency_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("demst_job_latency_seconds_sum 2\n"));
        assert!(text.contains("demst_job_latency_seconds_count 1"));
        assert!(text.contains("demst_slowest_job_seconds{i=\"3\",j=\"7\"} 2"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(parts.next().is_some(), "malformed line: {line}");
            assert!(value.parse::<f64>().is_ok(), "non-numeric value in: {line}");
        }
    }

    #[test]
    fn listener_serves_merged_hub_and_stops() {
        let hub = Arc::new(MetricsHub::new());
        hub.local.observe_job(1_000, 0, 1);
        let remote = Registry::new();
        remote.observe_job(9_000, 1, 2);
        hub.absorb(7, remote.snapshot());
        let srv = MetricsServer::start("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let addr = srv.addr();
        let resp = scrape(addr);
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("demst_job_latency_seconds_count 2"), "fleet-merged count");
        assert!(resp.contains("demst_fleet_workers 1"));
        srv.stop();
        // a fresh connect after stop fails once the listener is gone
        std::thread::sleep(Duration::from_millis(50));
        assert!(TcpStream::connect(addr).is_err(), "listener still accepting after stop");
    }
}
