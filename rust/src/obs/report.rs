//! Versioned machine-readable run report (`demst run --report-out`).
//!
//! Serializes the full [`RunMetrics`], the per-worker breakdown, a span
//! digest, and a config fingerprint as one JSON document, so experiment
//! harnesses consume a run programmatically instead of scraping the
//! printed summary lines. `scripts/check_run_report.py` validates the
//! schema and the reconciliation invariants (e.g.
//! `dist_evals == local_mst_evals + pair_evals`) in CI.

use std::io::Write;
use std::path::Path;

use super::{json, SpanKind};
use crate::config::RunConfig;
use crate::coordinator::RunMetrics;

/// Bump on any field rename/removal; additions are compatible.
pub const REPORT_VERSION: u32 = 1;

/// FNV-1a over the config's debug representation: a stable-within-a-build
/// identity for "same knobs" comparisons across runs, mirroring the shard
/// manifest's fingerprint idiom.
pub fn config_fingerprint(cfg: &RunConfig) -> u64 {
    let repr = format!("{cfg:?}");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in repr.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn span_digest(m: &RunMetrics) -> String {
    let mut by_kind: Vec<(SpanKind, u64)> = Vec::new();
    let mut job_evals: u64 = 0;
    let mut local_mst_evals: u64 = 0;
    for s in &m.spans {
        let Some(kind) = s.kind() else { continue };
        match by_kind.iter_mut().find(|(k, _)| *k == kind) {
            Some((_, n)) => *n += 1,
            None => by_kind.push((kind, 1)),
        }
        match kind {
            SpanKind::Job => job_evals += s.arg,
            SpanKind::LocalMst => local_mst_evals += s.arg,
            _ => {}
        }
    }
    by_kind.sort_by_key(|(k, _)| k.code());
    let kinds = by_kind
        .iter()
        .map(|(k, n)| json::field(k.name(), &n.to_string()))
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "{{{}, {}, {}, {}}}",
        json::field("total", &m.spans.len().to_string()),
        json::field("by_kind", &format!("{{{kinds}}}")),
        json::field("job_evals", &job_evals.to_string()),
        json::field("local_mst_evals", &local_mst_evals.to_string()),
    )
}

/// The fleet-merged histogram section: per histogram, exact count/sum and
/// bucket-bound quantile estimates, plus the occupied buckets themselves
/// so downstream tooling can re-derive any quantile. All values are scaled
/// to the unit the histogram's name declares (seconds, GFLOP/s); `{}` when
/// the run was not metrics-armed.
fn histograms_digest(m: &RunMetrics) -> String {
    use crate::obs::metrics::{bucket_bounds, Hist};
    let Some(fleet) = &m.fleet_metrics else {
        return "{}".to_string();
    };
    let mut out = Vec::new();
    for h in Hist::ALL {
        let snap = fleet.hist(h);
        let scale = h.unit_scale();
        let q = |p: f64| json::num(snap.quantile(p).map_or(0.0, |v| v as f64 / scale));
        let buckets = snap
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(idx, n)| {
                let (lo, hi) = bucket_bounds(idx);
                format!(
                    "{{{}, {}, {}}}",
                    json::field("lo", &json::num(lo as f64 / scale)),
                    json::field("hi", &json::num(hi as f64 / scale)),
                    json::field("count", &n.to_string()),
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        let min = if snap.count == 0 { 0.0 } else { snap.min as f64 / scale };
        let body = [
            json::field("count", &snap.count.to_string()),
            json::field("sum", &json::num(snap.sum as f64 / scale)),
            json::field("min", &json::num(min)),
            json::field("max", &json::num(snap.max as f64 / scale)),
            json::field("p50", &q(0.50)),
            json::field("p90", &q(0.90)),
            json::field("p99", &q(0.99)),
            json::field("buckets", &format!("[{buckets}]")),
        ]
        .join(", ");
        out.push(json::field(h.name(), &format!("{{{body}}}")));
    }
    out.push(json::field(
        "workers_reporting",
        &m.metrics_workers_reporting.to_string(),
    ));
    format!("{{\n    {}\n  }}", out.join(",\n    "))
}

/// Render the report document.
pub fn render_run_report(cfg: &RunConfig, m: &RunMetrics) -> String {
    let config = [
        json::field("fingerprint", &json::string(&format!("{:#018x}", config_fingerprint(cfg)))),
        json::field("name", &json::string(&cfg.name)),
        json::field("parts", &cfg.parts.to_string()),
        json::field("workers", &cfg.workers.to_string()),
        json::field("seed", &cfg.seed.to_string()),
        json::field("kernel", &json::string(cfg.kernel.name())),
        json::field("pair_kernel", &json::string(cfg.pair_kernel.name())),
        json::field("transport", &json::string(cfg.transport.name())),
        json::field("reduce_topology", &json::string(cfg.reduce_topology.name())),
        json::field("pipeline_window", &cfg.pipeline_window.to_string()),
    ]
    .join(", ");

    let metrics = [
        json::field("wall_s", &json::num(m.wall.as_secs_f64())),
        json::field("jobs", &m.jobs.to_string()),
        json::field("dist_evals", &m.dist_evals.to_string()),
        json::field("local_mst_evals", &m.local_mst_evals.to_string()),
        json::field("pair_evals", &m.pair_evals.to_string()),
        json::field("scatter_bytes", &m.scatter_bytes.to_string()),
        json::field("gather_bytes", &m.gather_bytes.to_string()),
        json::field("control_bytes", &m.control_bytes.to_string()),
        json::field("messages", &m.messages.to_string()),
        json::field("union_edges", &m.union_edges.to_string()),
        json::field("jobs_stolen", &m.jobs_stolen.to_string()),
        json::field("scatter_saved_bytes", &m.scatter_saved_bytes.to_string()),
        json::field("panel_hits", &m.panel_hits.to_string()),
        json::field("panel_misses", &m.panel_misses.to_string()),
        json::field("panel_flops", &m.panel_flops.to_string()),
        json::field("panel_time_s", &json::num(m.panel_time.as_secs_f64())),
        json::field("panel_isa", &json::string(&m.panel_isa)),
        json::field("panel_lanes", &m.panel_lanes.to_string()),
        json::field("panel_threads_used", &m.panel_threads_used.to_string()),
        json::field("reduce_folds", &m.reduce_folds.to_string()),
        json::field("reduce_fold_edges", &m.reduce_fold_edges.to_string()),
        json::field("pipeline_window", &m.pipeline_window.to_string()),
        json::field("sharded", if m.sharded { "true" } else { "false" }),
        json::field("leader_ingest_bytes", &m.leader_ingest_bytes.to_string()),
        json::field("shard_local_bytes", &m.shard_local_bytes.to_string()),
        json::field("leader_control_bytes", &m.leader_control_bytes.to_string()),
        json::field("leader_data_bytes", &m.leader_data_bytes.to_string()),
        json::field("peer_bytes", &m.peer_bytes.to_string()),
        json::field("peer_ships", &m.peer_ships.to_string()),
        json::field("worker_failures", &m.worker_failures.to_string()),
        json::field("jobs_reassigned", &m.jobs_reassigned.to_string()),
        json::field("stalls_detected", &m.stalls_detected.to_string()),
        json::field("heartbeats_sent", &m.heartbeats_sent.to_string()),
        json::field("workers_admitted", &m.workers_admitted.to_string()),
        json::field("chaos_faults_injected", &m.chaos_faults_injected.to_string()),
        json::field("kernel", &json::string(&m.kernel)),
        json::field("pair_kernel", &json::string(&m.pair_kernel)),
        json::field("transport", &json::string(&m.transport)),
        json::field("reduce_topology", &json::string(&m.reduce_topology)),
        json::field("peer_route", if m.peer_route { "true" } else { "false" }),
        json::field("stream_reduce", if m.stream_reduce { "true" } else { "false" }),
        json::field("busy_efficiency", &json::num(m.busy_efficiency())),
        json::field("imbalance", &json::num(m.imbalance())),
        json::field("phase_local_mst_s", &json::num(m.phase_local_mst.as_secs_f64())),
        json::field("phase_pair_s", &json::num(m.phase_pair.as_secs_f64())),
        json::field("phase_reduce_s", &json::num(m.phase_reduce.as_secs_f64())),
    ]
    .join(",\n    ");

    let workers = m
        .worker_busy
        .iter()
        .enumerate()
        .map(|(w, b)| {
            format!(
                "{{{}, {}}}",
                json::field("worker", &w.to_string()),
                json::field("busy_s", &json::num(b.as_secs_f64()))
            )
        })
        .collect::<Vec<_>>()
        .join(", ");

    format!(
        "{{\n  {},\n  {},\n  {},\n  {},\n  {},\n  {},\n  {}\n}}\n",
        json::field("report_version", &REPORT_VERSION.to_string()),
        json::field("tool", &json::string("demst")),
        json::field("config", &format!("{{{config}}}")),
        json::field("metrics", &format!("{{\n    {metrics}\n  }}")),
        json::field("workers", &format!("[{workers}]")),
        json::field("histograms", &histograms_digest(m)),
        json::field("spans", &span_digest(m)),
    )
}

pub fn write_run_report(path: &Path, cfg: &RunConfig, m: &RunMetrics) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_run_report(cfg, m).as_bytes())
}

// ---------------------------------------------------------------------------
// Cross-run regression diffing (`demst report diff`)
// ---------------------------------------------------------------------------

/// Allowed relative regression per tracked quantity, in percent
/// (candidate may exceed baseline by at most this much). Defaults are
/// deliberately loose on wall/latency — CI machines are noisy — and tight
/// on the deterministic quantities (distance evaluations, wire bytes),
/// which should not move at all without an intentional change.
#[derive(Clone, Copy, Debug)]
pub struct DiffThresholds {
    pub wall_pct: f64,
    pub dist_evals_pct: f64,
    pub bytes_pct: f64,
    pub p99_job_pct: f64,
}

impl Default for DiffThresholds {
    fn default() -> Self {
        Self { wall_pct: 25.0, dist_evals_pct: 1.0, bytes_pct: 1.0, p99_job_pct: 50.0 }
    }
}

/// One compared quantity: baseline vs candidate with its allowance.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub name: &'static str,
    pub baseline: f64,
    pub candidate: f64,
    pub limit_pct: f64,
}

impl DiffRow {
    /// Relative change in percent; a zero baseline regresses to +∞ the
    /// moment the candidate is nonzero (there is no sane ratio to allow).
    pub fn delta_pct(&self) -> f64 {
        if self.baseline > 0.0 {
            (self.candidate - self.baseline) / self.baseline * 100.0
        } else if self.candidate > self.baseline {
            f64::INFINITY
        } else {
            0.0
        }
    }

    pub fn regressed(&self) -> bool {
        self.delta_pct() > self.limit_pct
    }
}

fn metric_f64(doc: &json::Value, path: &str) -> Result<f64, String> {
    doc.path(path)
        .and_then(json::Value::as_f64)
        .ok_or_else(|| format!("report is missing numeric field {path:?}"))
}

/// Compare two parsed run reports. Every row is returned — regressed or
/// not — so callers can print the full table; the p99 job-latency row is
/// only present when **both** runs recorded pair-job latency (older
/// baselines and non-metrics-armed runs have none).
pub fn diff_reports(
    baseline: &json::Value,
    candidate: &json::Value,
    th: &DiffThresholds,
) -> Result<Vec<DiffRow>, String> {
    let bytes_of = |doc: &json::Value| -> Result<f64, String> {
        Ok(metric_f64(doc, "metrics.scatter_bytes")?
            + metric_f64(doc, "metrics.gather_bytes")?
            + metric_f64(doc, "metrics.control_bytes")?)
    };
    let mut rows = vec![
        DiffRow {
            name: "wall_s",
            baseline: metric_f64(baseline, "metrics.wall_s")?,
            candidate: metric_f64(candidate, "metrics.wall_s")?,
            limit_pct: th.wall_pct,
        },
        DiffRow {
            name: "dist_evals",
            baseline: metric_f64(baseline, "metrics.dist_evals")?,
            candidate: metric_f64(candidate, "metrics.dist_evals")?,
            limit_pct: th.dist_evals_pct,
        },
        DiffRow {
            name: "wire_bytes",
            baseline: bytes_of(baseline)?,
            candidate: bytes_of(candidate)?,
            limit_pct: th.bytes_pct,
        },
    ];
    let p99 = "histograms.job_latency_seconds.p99";
    let count = "histograms.job_latency_seconds.count";
    let has_latency = |doc: &json::Value| {
        doc.path(count).and_then(json::Value::as_f64).is_some_and(|c| c > 0.0)
    };
    if has_latency(baseline) && has_latency(candidate) {
        rows.push(DiffRow {
            name: "p99_job_latency_s",
            baseline: metric_f64(baseline, p99)?,
            candidate: metric_f64(candidate, p99)?,
            limit_pct: th.p99_job_pct,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;
    use std::time::Duration;

    #[test]
    fn fingerprint_is_stable_and_knob_sensitive() {
        let a = RunConfig::default();
        let mut b = RunConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.parts = 9;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
    }

    #[test]
    fn report_carries_version_metrics_workers_and_span_digest() {
        let cfg = RunConfig::default();
        let m = RunMetrics {
            jobs: 6,
            dist_evals: 100,
            local_mst_evals: 40,
            pair_evals: 60,
            worker_busy: vec![Duration::from_millis(250), Duration::from_millis(750)],
            spans: vec![
                Span {
                    kind_code: SpanKind::Job.code(),
                    worker: 0,
                    id: 1,
                    arg: 35,
                    start_ns: 0,
                    end_ns: 10,
                },
                Span {
                    kind_code: SpanKind::Job.code(),
                    worker: 1,
                    id: 2,
                    arg: 25,
                    start_ns: 0,
                    end_ns: 10,
                },
                Span {
                    kind_code: SpanKind::LocalMst.code(),
                    worker: 0,
                    id: 0,
                    arg: 40,
                    start_ns: 0,
                    end_ns: 5,
                },
            ],
            ..Default::default()
        };
        let doc = render_run_report(&cfg, &m);
        assert!(doc.contains("\"report_version\": 1"), "{doc}");
        assert!(doc.contains("\"fingerprint\": \"0x"), "{doc}");
        assert!(doc.contains("\"jobs\": 6"), "{doc}");
        assert!(doc.contains("\"dist_evals\": 100"), "{doc}");
        assert!(doc.contains("\"local_mst_evals\": 40"), "{doc}");
        assert!(doc.contains("\"pair_evals\": 60"), "{doc}");
        assert!(doc.contains("\"busy_s\": 0.25"), "{doc}");
        assert!(doc.contains("\"busy_s\": 0.75"), "{doc}");
        // span digest reconciles with the metrics by construction here
        assert!(doc.contains("\"total\": 3"), "{doc}");
        assert!(doc.contains("\"job\": 2"), "{doc}");
        assert!(doc.contains("\"local_mst\": 1"), "{doc}");
        assert!(doc.contains("\"job_evals\": 60"), "{doc}");
        assert!(doc.contains("\"local_mst_evals\": 40"), "{doc}");
    }

    #[test]
    fn report_without_spans_has_an_empty_digest() {
        let doc = render_run_report(&RunConfig::default(), &RunMetrics::default());
        assert!(doc.contains("\"total\": 0"), "{doc}");
        assert!(doc.contains("\"by_kind\": {}"), "{doc}");
        // not metrics-armed ⇒ no fleet snapshot ⇒ empty histogram section
        assert!(doc.contains("\"histograms\": {}"), "{doc}");
    }

    #[test]
    fn report_parses_with_own_parser_and_carries_histograms() {
        use crate::obs::json::Value;
        use crate::obs::metrics::{Hist, Registry};
        let reg = Registry::new();
        reg.observe_job(1_500_000, 3, 7); // 1.5 ms
        reg.observe_job(2_500_000, 0, 1); // 2.5 ms
        reg.observe(Hist::Fold, 10_000);
        let m = RunMetrics {
            jobs: 2,
            fleet_metrics: Some(reg.snapshot()),
            metrics_workers_reporting: 1,
            ..Default::default()
        };
        let doc = render_run_report(&RunConfig::default(), &m);
        let v = json::parse(&doc).expect("the report must parse with our own reader");
        let jl = v.path("histograms.job_latency_seconds").expect("job latency section");
        assert_eq!(jl.get("count").and_then(Value::as_f64), Some(2.0));
        // sum is exact: 4 ms in seconds
        assert_eq!(jl.get("sum").and_then(Value::as_f64), Some(0.004));
        let p99 = jl.get("p99").and_then(Value::as_f64).unwrap();
        assert!(p99 > 0.002 && p99 < 0.003, "p99 {p99} should bracket the 2.5ms sample");
        let buckets = jl.get("buckets").and_then(Value::as_arr).unwrap();
        let total: f64 =
            buckets.iter().map(|b| b.get("count").and_then(Value::as_f64).unwrap()).sum();
        assert_eq!(total, 2.0, "occupied buckets must account for every sample");
        assert_eq!(
            v.path("histograms.fold_seconds.count").and_then(Value::as_f64),
            Some(1.0)
        );
        assert_eq!(
            v.path("histograms.workers_reporting").and_then(Value::as_f64),
            Some(1.0)
        );
    }

    fn rendered(m: &RunMetrics) -> json::Value {
        json::parse(&render_run_report(&RunConfig::default(), m)).unwrap()
    }

    #[test]
    fn diff_flags_only_regressions_beyond_their_threshold() {
        use crate::obs::metrics::Registry;
        let base_reg = Registry::new();
        base_reg.observe_job(1_000_000, 0, 1);
        let mut base = RunMetrics {
            wall: Duration::from_millis(100),
            dist_evals: 1_000,
            scatter_bytes: 500,
            gather_bytes: 400,
            control_bytes: 100,
            fleet_metrics: Some(base_reg.snapshot()),
            ..Default::default()
        };
        let baseline = rendered(&base);

        // identical run: nothing regresses
        let rows =
            diff_reports(&baseline, &baseline, &DiffThresholds::default()).unwrap();
        assert_eq!(rows.len(), 4, "wall, evals, bytes, p99");
        assert!(rows.iter().all(|r| !r.regressed()), "{rows:?}");

        // wall doubles (over the 25% allowance), bytes creep 0.5% (under 1%)
        base.wall = Duration::from_millis(200);
        base.scatter_bytes = 505;
        let candidate = rendered(&base);
        let rows =
            diff_reports(&baseline, &candidate, &DiffThresholds::default()).unwrap();
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert!(by_name("wall_s").regressed());
        assert!((by_name("wall_s").delta_pct() - 100.0).abs() < 1e-9);
        assert!(!by_name("wire_bytes").regressed());
        assert!(!by_name("dist_evals").regressed());

        // improvements never flag
        let rows =
            diff_reports(&candidate, &baseline, &DiffThresholds::default()).unwrap();
        assert!(rows.iter().all(|r| !r.regressed()), "{rows:?}");
    }

    #[test]
    fn diff_omits_latency_row_when_a_side_recorded_no_jobs() {
        let base = RunMetrics {
            wall: Duration::from_millis(100),
            dist_evals: 10,
            ..Default::default()
        };
        let doc = rendered(&base);
        let rows = diff_reports(&doc, &doc, &DiffThresholds::default()).unwrap();
        assert_eq!(rows.len(), 3, "no fleet snapshot ⇒ no p99 row: {rows:?}");
    }

    #[test]
    fn diff_zero_baseline_regresses_on_any_growth() {
        let row = DiffRow { name: "x", baseline: 0.0, candidate: 1.0, limit_pct: 50.0 };
        assert!(row.regressed());
        let row = DiffRow { name: "x", baseline: 0.0, candidate: 0.0, limit_pct: 50.0 };
        assert!(!row.regressed());
    }

    #[test]
    fn diff_errors_on_a_non_report_document() {
        let junk = json::parse("{\"hello\": 1}").unwrap();
        let good = rendered(&RunMetrics::default());
        assert!(diff_reports(&junk, &good, &DiffThresholds::default()).is_err());
    }
}
