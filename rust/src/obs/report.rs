//! Versioned machine-readable run report (`demst run --report-out`).
//!
//! Serializes the full [`RunMetrics`], the per-worker breakdown, a span
//! digest, and a config fingerprint as one JSON document, so experiment
//! harnesses consume a run programmatically instead of scraping the
//! printed summary lines. `scripts/check_run_report.py` validates the
//! schema and the reconciliation invariants (e.g.
//! `dist_evals == local_mst_evals + pair_evals`) in CI.

use std::io::Write;
use std::path::Path;

use super::{json, SpanKind};
use crate::config::RunConfig;
use crate::coordinator::RunMetrics;

/// Bump on any field rename/removal; additions are compatible.
pub const REPORT_VERSION: u32 = 1;

/// FNV-1a over the config's debug representation: a stable-within-a-build
/// identity for "same knobs" comparisons across runs, mirroring the shard
/// manifest's fingerprint idiom.
pub fn config_fingerprint(cfg: &RunConfig) -> u64 {
    let repr = format!("{cfg:?}");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in repr.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn span_digest(m: &RunMetrics) -> String {
    let mut by_kind: Vec<(SpanKind, u64)> = Vec::new();
    let mut job_evals: u64 = 0;
    let mut local_mst_evals: u64 = 0;
    for s in &m.spans {
        let Some(kind) = s.kind() else { continue };
        match by_kind.iter_mut().find(|(k, _)| *k == kind) {
            Some((_, n)) => *n += 1,
            None => by_kind.push((kind, 1)),
        }
        match kind {
            SpanKind::Job => job_evals += s.arg,
            SpanKind::LocalMst => local_mst_evals += s.arg,
            _ => {}
        }
    }
    by_kind.sort_by_key(|(k, _)| k.code());
    let kinds = by_kind
        .iter()
        .map(|(k, n)| json::field(k.name(), &n.to_string()))
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "{{{}, {}, {}, {}}}",
        json::field("total", &m.spans.len().to_string()),
        json::field("by_kind", &format!("{{{kinds}}}")),
        json::field("job_evals", &job_evals.to_string()),
        json::field("local_mst_evals", &local_mst_evals.to_string()),
    )
}

/// Render the report document.
pub fn render_run_report(cfg: &RunConfig, m: &RunMetrics) -> String {
    let config = [
        json::field("fingerprint", &json::string(&format!("{:#018x}", config_fingerprint(cfg)))),
        json::field("name", &json::string(&cfg.name)),
        json::field("parts", &cfg.parts.to_string()),
        json::field("workers", &cfg.workers.to_string()),
        json::field("seed", &cfg.seed.to_string()),
        json::field("kernel", &json::string(cfg.kernel.name())),
        json::field("pair_kernel", &json::string(cfg.pair_kernel.name())),
        json::field("transport", &json::string(cfg.transport.name())),
        json::field("reduce_topology", &json::string(cfg.reduce_topology.name())),
        json::field("pipeline_window", &cfg.pipeline_window.to_string()),
    ]
    .join(", ");

    let metrics = [
        json::field("wall_s", &json::num(m.wall.as_secs_f64())),
        json::field("jobs", &m.jobs.to_string()),
        json::field("dist_evals", &m.dist_evals.to_string()),
        json::field("local_mst_evals", &m.local_mst_evals.to_string()),
        json::field("pair_evals", &m.pair_evals.to_string()),
        json::field("scatter_bytes", &m.scatter_bytes.to_string()),
        json::field("gather_bytes", &m.gather_bytes.to_string()),
        json::field("control_bytes", &m.control_bytes.to_string()),
        json::field("messages", &m.messages.to_string()),
        json::field("union_edges", &m.union_edges.to_string()),
        json::field("jobs_stolen", &m.jobs_stolen.to_string()),
        json::field("scatter_saved_bytes", &m.scatter_saved_bytes.to_string()),
        json::field("panel_hits", &m.panel_hits.to_string()),
        json::field("panel_misses", &m.panel_misses.to_string()),
        json::field("panel_flops", &m.panel_flops.to_string()),
        json::field("panel_time_s", &json::num(m.panel_time.as_secs_f64())),
        json::field("panel_isa", &json::string(&m.panel_isa)),
        json::field("panel_lanes", &m.panel_lanes.to_string()),
        json::field("panel_threads_used", &m.panel_threads_used.to_string()),
        json::field("reduce_folds", &m.reduce_folds.to_string()),
        json::field("reduce_fold_edges", &m.reduce_fold_edges.to_string()),
        json::field("pipeline_window", &m.pipeline_window.to_string()),
        json::field("sharded", if m.sharded { "true" } else { "false" }),
        json::field("leader_ingest_bytes", &m.leader_ingest_bytes.to_string()),
        json::field("shard_local_bytes", &m.shard_local_bytes.to_string()),
        json::field("leader_control_bytes", &m.leader_control_bytes.to_string()),
        json::field("leader_data_bytes", &m.leader_data_bytes.to_string()),
        json::field("peer_bytes", &m.peer_bytes.to_string()),
        json::field("peer_ships", &m.peer_ships.to_string()),
        json::field("worker_failures", &m.worker_failures.to_string()),
        json::field("jobs_reassigned", &m.jobs_reassigned.to_string()),
        json::field("stalls_detected", &m.stalls_detected.to_string()),
        json::field("heartbeats_sent", &m.heartbeats_sent.to_string()),
        json::field("workers_admitted", &m.workers_admitted.to_string()),
        json::field("chaos_faults_injected", &m.chaos_faults_injected.to_string()),
        json::field("kernel", &json::string(&m.kernel)),
        json::field("pair_kernel", &json::string(&m.pair_kernel)),
        json::field("transport", &json::string(&m.transport)),
        json::field("reduce_topology", &json::string(&m.reduce_topology)),
        json::field("peer_route", if m.peer_route { "true" } else { "false" }),
        json::field("stream_reduce", if m.stream_reduce { "true" } else { "false" }),
        json::field("busy_efficiency", &json::num(m.busy_efficiency())),
        json::field("imbalance", &json::num(m.imbalance())),
        json::field("phase_local_mst_s", &json::num(m.phase_local_mst.as_secs_f64())),
        json::field("phase_pair_s", &json::num(m.phase_pair.as_secs_f64())),
        json::field("phase_reduce_s", &json::num(m.phase_reduce.as_secs_f64())),
    ]
    .join(",\n    ");

    let workers = m
        .worker_busy
        .iter()
        .enumerate()
        .map(|(w, b)| {
            format!(
                "{{{}, {}}}",
                json::field("worker", &w.to_string()),
                json::field("busy_s", &json::num(b.as_secs_f64()))
            )
        })
        .collect::<Vec<_>>()
        .join(", ");

    format!(
        "{{\n  {},\n  {},\n  {},\n  {},\n  {},\n  {}\n}}\n",
        json::field("report_version", &REPORT_VERSION.to_string()),
        json::field("tool", &json::string("demst")),
        json::field("config", &format!("{{{config}}}")),
        json::field("metrics", &format!("{{\n    {metrics}\n  }}")),
        json::field("workers", &format!("[{workers}]")),
        json::field("spans", &span_digest(m)),
    )
}

pub fn write_run_report(path: &Path, cfg: &RunConfig, m: &RunMetrics) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_run_report(cfg, m).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;
    use std::time::Duration;

    #[test]
    fn fingerprint_is_stable_and_knob_sensitive() {
        let a = RunConfig::default();
        let mut b = RunConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.parts = 9;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
    }

    #[test]
    fn report_carries_version_metrics_workers_and_span_digest() {
        let cfg = RunConfig::default();
        let m = RunMetrics {
            jobs: 6,
            dist_evals: 100,
            local_mst_evals: 40,
            pair_evals: 60,
            worker_busy: vec![Duration::from_millis(250), Duration::from_millis(750)],
            spans: vec![
                Span {
                    kind_code: SpanKind::Job.code(),
                    worker: 0,
                    id: 1,
                    arg: 35,
                    start_ns: 0,
                    end_ns: 10,
                },
                Span {
                    kind_code: SpanKind::Job.code(),
                    worker: 1,
                    id: 2,
                    arg: 25,
                    start_ns: 0,
                    end_ns: 10,
                },
                Span {
                    kind_code: SpanKind::LocalMst.code(),
                    worker: 0,
                    id: 0,
                    arg: 40,
                    start_ns: 0,
                    end_ns: 5,
                },
            ],
            ..Default::default()
        };
        let doc = render_run_report(&cfg, &m);
        assert!(doc.contains("\"report_version\": 1"), "{doc}");
        assert!(doc.contains("\"fingerprint\": \"0x"), "{doc}");
        assert!(doc.contains("\"jobs\": 6"), "{doc}");
        assert!(doc.contains("\"dist_evals\": 100"), "{doc}");
        assert!(doc.contains("\"local_mst_evals\": 40"), "{doc}");
        assert!(doc.contains("\"pair_evals\": 60"), "{doc}");
        assert!(doc.contains("\"busy_s\": 0.25"), "{doc}");
        assert!(doc.contains("\"busy_s\": 0.75"), "{doc}");
        // span digest reconciles with the metrics by construction here
        assert!(doc.contains("\"total\": 3"), "{doc}");
        assert!(doc.contains("\"job\": 2"), "{doc}");
        assert!(doc.contains("\"local_mst\": 1"), "{doc}");
        assert!(doc.contains("\"job_evals\": 60"), "{doc}");
        assert!(doc.contains("\"local_mst_evals\": 40"), "{doc}");
    }

    #[test]
    fn report_without_spans_has_an_empty_digest() {
        let doc = render_run_report(&RunConfig::default(), &RunMetrics::default());
        assert!(doc.contains("\"total\": 0"), "{doc}");
        assert!(doc.contains("\"by_kind\": {}"), "{doc}");
    }
}
