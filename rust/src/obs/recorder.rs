//! Per-thread span recording behind a run-token scheme.
//!
//! Design constraints, in order:
//! 1. **Free when off.** With no active run, [`span`]/[`instant`] cost one
//!    relaxed atomic load and allocate nothing — the pair-job hot path must
//!    not move the e7/e8 numbers.
//! 2. **Concurrent-test safe.** `cargo test` runs many engines in parallel
//!    in one process. A global on/off flag would bleed spans across tests,
//!    so every run gets a [`RunToken`]; threads opt in with [`adopt`]; each
//!    buffered span is tagged with its run id; [`drain`] filters by token.
//! 3. **Lock-free-ish.** Each thread appends to its own pre-reserved buffer
//!    behind an uncontended mutex (taken only by the owning thread until
//!    the drain at run end), registered once in a global list.
//!
//! Timestamps come from one process-wide monotonic epoch ([`now_ns`]);
//! cross-process alignment happens at the leader when worker spans arrive
//! on the wire carrying the worker's send-time clock.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::{Span, SpanKind};

/// Number of runs currently recording. Recording is attempted only when
/// nonzero — the single branch paid on the disabled hot path.
static ACTIVE_RUNS: AtomicU64 = AtomicU64::new(0);
/// Run ids start at 1; 0 means "this thread belongs to no run".
static NEXT_RUN: AtomicU64 = AtomicU64::new(1);

/// Handle for one recording session. Copyable so it can be captured by the
/// worker-thread closures of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunToken(u64);

type SharedBuf = Arc<Mutex<Vec<(u64, Span)>>>;

fn registry() -> &'static Mutex<Vec<SharedBuf>> {
    static REGISTRY: OnceLock<Mutex<Vec<SharedBuf>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since this process's first clock read. Safe to
/// call whether or not recording is active.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

struct ThreadState {
    run: u64,
    buf: Option<SharedBuf>,
}

thread_local! {
    static TLS: RefCell<ThreadState> = const { RefCell::new(ThreadState { run: 0, buf: None }) };
}

/// Start a recording session. Threads that should contribute spans call
/// [`adopt`] with the returned token (the calling thread is adopted
/// automatically). Balance with [`end_run`].
pub fn begin_run() -> RunToken {
    let token = RunToken(NEXT_RUN.fetch_add(1, Ordering::Relaxed));
    ACTIVE_RUNS.fetch_add(1, Ordering::Relaxed);
    adopt(token);
    token
}

/// Attach the current thread to a run: spans it records from here on are
/// tagged with (and drained by) this token.
pub fn adopt(token: RunToken) {
    TLS.with(|t| t.borrow_mut().run = token.0);
}

/// True when this thread's spans would actually be kept — use to skip
/// span-argument bookkeeping (e.g. eval-counter deltas) when tracing is off.
pub fn recording() -> bool {
    if ACTIVE_RUNS.load(Ordering::Relaxed) == 0 {
        return false;
    }
    TLS.with(|t| t.borrow().run != 0)
}

fn push(span: Span) {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let run = t.run;
        if run == 0 {
            return;
        }
        let buf = t
            .buf
            .get_or_insert_with(|| {
                // First span on this thread: allocate + register once.
                let b: SharedBuf = Arc::new(Mutex::new(Vec::with_capacity(1024)));
                registry().lock().unwrap().push(Arc::clone(&b));
                b
            })
            .clone();
        buf.lock().unwrap().push((run, span));
    });
}

/// Record a completed interval with explicit timestamps — for spans whose
/// start predates the run (e.g. a worker's handshake, clocked before the
/// leader's `Setup` said whether to trace) or reconstructed at the leader
/// for a worker that died without shipping its buffer.
pub fn record(kind: SpanKind, worker: u16, id: u32, arg: u64, start_ns: u64, end_ns: u64) {
    if !recording() {
        return;
    }
    push(Span { kind_code: kind.code(), worker, id, arg, start_ns, end_ns });
}

/// Record a point event (start == end).
pub fn instant(kind: SpanKind, worker: u16, id: u32, arg: u64) {
    if !recording() {
        return;
    }
    let t = now_ns();
    push(Span { kind_code: kind.code(), worker, id, arg, start_ns: t, end_ns: t });
}

/// Open an interval; the span is recorded when the guard drops. Disabled
/// recording makes this a stack-only no-op (no clock read, no allocation).
pub fn span(kind: SpanKind, worker: u16, id: u32) -> SpanGuard {
    let armed = recording();
    SpanGuard {
        kind,
        worker,
        id,
        arg: 0,
        start_ns: if armed { now_ns() } else { 0 },
        armed,
    }
}

/// RAII interval recorder returned by [`span`].
pub struct SpanGuard {
    kind: SpanKind,
    worker: u16,
    id: u32,
    arg: u64,
    start_ns: u64,
    armed: bool,
}

impl SpanGuard {
    /// Attach the kind-scoped payload (evals, FLOPs, bytes, …).
    pub fn set_arg(&mut self, arg: u64) {
        self.arg = arg;
    }

    pub fn armed(&self) -> bool {
        self.armed
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            push(Span {
                kind_code: self.kind.code(),
                worker: self.worker,
                id: self.id,
                arg: self.arg,
                start_ns: self.start_ns,
                end_ns: now_ns(),
            });
        }
    }
}

/// Remove and return every span recorded under `token`, across all threads
/// that adopted it, in per-thread recording order.
pub fn drain(token: RunToken) -> Vec<Span> {
    let bufs: Vec<SharedBuf> = registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for buf in bufs {
        let mut b = buf.lock().unwrap();
        b.retain(|(run, s)| {
            if *run == token.0 {
                out.push(*s);
                false
            } else {
                true
            }
        });
    }
    out
}

/// Finish a session: drain its spans and drop the process-wide enable if
/// this was the last active run.
pub fn end_run(token: RunToken) -> Vec<Span> {
    let spans = drain(token);
    ACTIVE_RUNS.fetch_sub(1, Ordering::Relaxed);
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recording_is_a_no_op() {
        // No run on this thread: guards and instants must record nothing.
        TLS.with(|t| t.borrow_mut().run = 0);
        {
            let mut g = span(SpanKind::Job, 0, 7);
            g.set_arg(99);
            assert!(!g.armed());
        }
        instant(SpanKind::Stall, 0, 1, 0);
        let token = begin_run();
        // Nothing recorded before begin_run is attributed to this token.
        assert!(end_run(token).is_empty());
    }

    #[test]
    fn spans_are_tagged_and_drained_per_run() {
        let token = begin_run();
        {
            let mut g = span(SpanKind::Job, 3, 11);
            g.set_arg(42);
        }
        instant(SpanKind::Admit, 0, 5, 5);
        let spans = end_run(token);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].kind(), Some(SpanKind::Job));
        assert_eq!(spans[0].worker, 3);
        assert_eq!(spans[0].id, 11);
        assert_eq!(spans[0].arg, 42);
        assert!(spans[0].end_ns >= spans[0].start_ns);
        assert_eq!(spans[1].kind(), Some(SpanKind::Admit));
        assert_eq!(spans[1].start_ns, spans[1].end_ns);
        // A second drain finds nothing: the buffers were emptied.
        assert!(drain(token).is_empty());
    }

    #[test]
    fn concurrent_runs_do_not_bleed_spans() {
        let token_a = begin_run();
        let token_b_holder = std::thread::spawn(|| {
            let token_b = begin_run();
            instant(SpanKind::Chaos, 1, 100, 0);
            token_b
        })
        .join()
        .unwrap();
        instant(SpanKind::Fold, 0, 200, 0);
        let a = end_run(token_a);
        let b = end_run(token_b_holder);
        assert_eq!(a.len(), 1, "run A sees only its own span");
        assert_eq!(a[0].id, 200);
        assert_eq!(b.len(), 1, "run B sees only its own span");
        assert_eq!(b[0].id, 100);
    }

    #[test]
    fn spawned_threads_contribute_after_adopt() {
        let token = begin_run();
        let handles: Vec<_> = (0..4u16)
            .map(|w| {
                std::thread::spawn(move || {
                    adopt(token);
                    for j in 0..8u32 {
                        let _g = span(SpanKind::Job, w, u32::from(w) * 8 + j);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let spans = end_run(token);
        assert_eq!(spans.len(), 32);
        // Per-thread recording order is preserved: each worker's ids ascend.
        for w in 0..4u16 {
            let ids: Vec<u32> = spans.iter().filter(|s| s.worker == w).map(|s| s.id).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted);
        }
    }
}
