//! Leader-side live progress ticker.
//!
//! One `\r`-rewritten stderr line while the gather loop runs: jobs
//! done/total, gathered bytes, and the elastic counters (stalls,
//! admissions) the moment they move. Stays silent when stderr is not a
//! tty (CI logs don't want carriage returns) or when the run asked for
//! `--quiet`; when silent, `tick` is a single bool check.

use std::io::{IsTerminal, Write};
use std::time::{Duration, Instant};

const REDRAW_EVERY: Duration = Duration::from_millis(100);

pub struct Progress {
    active: bool,
    total: usize,
    last_draw: Option<Instant>,
    drew_anything: bool,
}

impl Progress {
    /// `enabled` is the config side (`[obs] progress` / `--quiet`); the
    /// tty check is ours.
    pub fn new(total: usize, enabled: bool) -> Progress {
        Progress {
            active: enabled && std::io::stderr().is_terminal(),
            total,
            last_draw: None,
            drew_anything: false,
        }
    }

    pub fn active(&self) -> bool {
        self.active
    }

    /// Redraw at most every 100 ms.
    pub fn tick(&mut self, done: usize, bytes: u64, stalls: u32, admitted: u32) {
        if !self.active {
            return;
        }
        let now = Instant::now();
        if let Some(last) = self.last_draw {
            if now.duration_since(last) < REDRAW_EVERY {
                return;
            }
        }
        self.last_draw = Some(now);
        self.drew_anything = true;
        let mut line = format!(
            "\r  jobs {done}/{} | gathered {}",
            self.total,
            crate::util::human_bytes(bytes)
        );
        if stalls > 0 {
            line.push_str(&format!(" | stalls {stalls}"));
        }
        if admitted > 0 {
            line.push_str(&format!(" | admitted {admitted}"));
        }
        // Pad so a shrinking line doesn't leave stale characters behind.
        let mut err = std::io::stderr().lock();
        let _ = write!(err, "{line:<70}");
        let _ = err.flush();
    }

    /// Clear the ticker line so the final report starts on a clean row.
    pub fn finish(&mut self) {
        if self.active && self.drew_anything {
            let mut err = std::io::stderr().lock();
            let _ = write!(err, "\r{:<70}\r", "");
            let _ = err.flush();
        }
        self.active = false;
    }
}

impl Drop for Progress {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_ticker_never_draws() {
        let mut p = Progress::new(10, false);
        assert!(!p.active());
        p.tick(1, 100, 0, 0); // must be a no-op, not a panic
        p.finish();
        assert!(!p.active());
    }

    #[test]
    fn tty_gate_applies_even_when_enabled() {
        // Under `cargo test` stderr is a pipe, so the tty gate holds the
        // ticker off regardless of the config side.
        let p = Progress::new(10, true);
        assert!(!p.active() || std::io::stderr().is_terminal());
    }
}
