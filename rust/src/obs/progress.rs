//! Leader-side live progress ticker.
//!
//! One `\r`-rewritten stderr line while the gather loop runs: jobs
//! done/total, gathered bytes, and the elastic counters (stalls,
//! admissions) the moment they move. Stays silent when stderr is not a
//! tty (CI logs don't want carriage returns) or when the run asked for
//! `--quiet`; when silent, `tick` is a single bool check.

use std::io::{IsTerminal, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

const REDRAW_EVERY: Duration = Duration::from_millis(100);

/// A ticker line is currently painted on the terminal. Process-global so
/// [`crate::obs::emit`] can clear it before a log line lands — otherwise
/// the `\r` line and the log write clobber each other.
static LIVE: AtomicBool = AtomicBool::new(false);
/// A log line wiped the ticker; the next `tick` repaints immediately
/// instead of waiting out the redraw throttle.
static DIRTY: AtomicBool = AtomicBool::new(false);

/// Clear a live ticker line under the caller's stderr lock, so the log
/// line about to be written starts on a clean row, and schedule an
/// immediate repaint. No-op (and no bytes written) when no line is up.
pub(crate) fn clear_for_log(err: &mut impl Write) {
    if LIVE.swap(false, Ordering::Relaxed) {
        let _ = write!(err, "\r{:<70}\r", "");
        DIRTY.store(true, Ordering::Relaxed);
    }
}

pub struct Progress {
    active: bool,
    total: usize,
    last_draw: Option<Instant>,
}

impl Progress {
    /// `enabled` is the config side (`[obs] progress` / `--quiet`); the
    /// tty check is ours.
    pub fn new(total: usize, enabled: bool) -> Progress {
        Progress {
            active: enabled && std::io::stderr().is_terminal(),
            total,
            last_draw: None,
        }
    }

    pub fn active(&self) -> bool {
        self.active
    }

    /// Redraw at most every 100 ms — except right after a log line wiped
    /// the ticker, which repaints on the next tick unconditionally.
    pub fn tick(&mut self, done: usize, bytes: u64, stalls: u32, admitted: u32) {
        if !self.active {
            return;
        }
        let now = Instant::now();
        if !DIRTY.swap(false, Ordering::Relaxed) {
            if let Some(last) = self.last_draw {
                if now.duration_since(last) < REDRAW_EVERY {
                    return;
                }
            }
        }
        self.last_draw = Some(now);
        let mut line = format!(
            "\r  jobs {done}/{} | gathered {}",
            self.total,
            crate::util::human_bytes(bytes)
        );
        if stalls > 0 {
            line.push_str(&format!(" | stalls {stalls}"));
        }
        if admitted > 0 {
            line.push_str(&format!(" | admitted {admitted}"));
        }
        // Pad so a shrinking line doesn't leave stale characters behind.
        let mut err = std::io::stderr().lock();
        let _ = write!(err, "{line:<70}");
        let _ = err.flush();
        LIVE.store(true, Ordering::Relaxed);
    }

    /// Clear the ticker line so the final report starts on a clean row.
    pub fn finish(&mut self) {
        if self.active && LIVE.swap(false, Ordering::Relaxed) {
            let mut err = std::io::stderr().lock();
            let _ = write!(err, "\r{:<70}\r", "");
            let _ = err.flush();
        }
        self.active = false;
    }
}

impl Drop for Progress {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_ticker_never_draws() {
        let mut p = Progress::new(10, false);
        assert!(!p.active());
        p.tick(1, 100, 0, 0); // must be a no-op, not a panic
        p.finish();
        assert!(!p.active());
    }

    #[test]
    fn tty_gate_applies_even_when_enabled() {
        // Under `cargo test` stderr is a pipe, so the tty gate holds the
        // ticker off regardless of the config side.
        let p = Progress::new(10, true);
        assert!(!p.active() || std::io::stderr().is_terminal());
    }

    #[test]
    fn log_clear_wipes_live_line_and_schedules_repaint() {
        // No live line: nothing written, nothing scheduled.
        let mut sink = Vec::new();
        LIVE.store(false, Ordering::Relaxed);
        DIRTY.store(false, Ordering::Relaxed);
        clear_for_log(&mut sink);
        assert!(sink.is_empty(), "no clear bytes without a live ticker line");
        assert!(!DIRTY.load(Ordering::Relaxed));
        // Live line: clear sequence written, immediate repaint scheduled.
        LIVE.store(true, Ordering::Relaxed);
        clear_for_log(&mut sink);
        assert!(sink.starts_with(b"\r"), "clear starts with carriage return");
        assert!(sink.ends_with(b"\r"), "cursor parked at column 0 for the log line");
        assert!(!LIVE.load(Ordering::Relaxed), "line no longer on screen");
        assert!(DIRTY.swap(false, Ordering::Relaxed), "repaint scheduled");
    }
}
