//! Hand-rolled JSON fragments for the exporters — the offline vendor set
//! has no serde, and the two documents we emit (Chrome trace, run report)
//! are flat enough that string assembly plus correct escaping is all the
//! machinery needed. Same spirit as the `to_json` writer in the e7 bench.

/// RFC 8259 string escaping, quotes included.
pub fn string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite floats render with Rust's shortest-roundtrip formatting (always
/// valid JSON); NaN/inf — which JSON cannot represent — become `null`.
pub fn num(v: f64) -> String {
    if v.is_finite() {
        // `{}` on f64 never emits exponents for the magnitudes we record,
        // but "1e300"-style output is still legal JSON, so pass through.
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// `"key": value` pair, for assembling objects.
pub fn field(key: &str, value: &str) -> String {
    format!("{}: {}", string(key), value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_escape_quotes_backslashes_and_control_chars() {
        assert_eq!(string("plain"), "\"plain\"");
        assert_eq!(string("a\"b"), "\"a\\\"b\"");
        assert_eq!(string("a\\b"), "\"a\\\\b\"");
        assert_eq!(string("a\nb\tc"), "\"a\\nb\\tc\"");
        assert_eq!(string("\u{1}"), "\"\\u0001\"");
        assert_eq!(string("héllo"), "\"héllo\"");
    }

    #[test]
    fn numbers_are_json_safe() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(0.0), "0");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn fields_compose() {
        assert_eq!(field("jobs", "12"), "\"jobs\": 12");
    }
}
