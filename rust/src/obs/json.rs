//! Hand-rolled JSON fragments for the exporters — the offline vendor set
//! has no serde, and the two documents we emit (Chrome trace, run report)
//! are flat enough that string assembly plus correct escaping is all the
//! machinery needed. Same spirit as the `to_json` writer in the e7 bench.

/// RFC 8259 string escaping, quotes included.
pub fn string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite floats render with Rust's shortest-roundtrip formatting (always
/// valid JSON); NaN/inf — which JSON cannot represent — become `null`.
pub fn num(v: f64) -> String {
    if v.is_finite() {
        // `{}` on f64 never emits exponents for the magnitudes we record,
        // but "1e300"-style output is still legal JSON, so pass through.
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// `"key": value` pair, for assembling objects.
pub fn field(key: &str, value: &str) -> String {
    format!("{}: {}", string(key), value)
}

/// Parsed JSON value — the read half of this module, used by
/// `demst report diff` to load run reports back. Objects keep insertion
/// order (a `Vec`, not a map): report documents are small and ordered
/// iteration makes diff output deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object member lookup (first match; reports never repeat keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Dotted-path lookup: `doc.path("metrics.wall_s")`.
    pub fn path(&self, path: &str) -> Option<&Value> {
        path.split('.').try_fold(self, |v, key| v.get(key))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse one JSON document (RFC 8259 subset sufficient for our own
/// exporters: no surrogate-pair `\u` escapes — the reports are ASCII).
/// Errors carry a byte offset so a truncated report is diagnosable.
pub fn parse(s: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| format!("bad \\u escape at byte {}", self.pos))?;
                            self.pos += 4;
                            out.push(char::from_u32(hex).ok_or_else(|| {
                                format!("bad codepoint at byte {}", self.pos)
                            })?);
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte sequences pass
                    // through unmodified — the source is a &str, so valid)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_escape_quotes_backslashes_and_control_chars() {
        assert_eq!(string("plain"), "\"plain\"");
        assert_eq!(string("a\"b"), "\"a\\\"b\"");
        assert_eq!(string("a\\b"), "\"a\\\\b\"");
        assert_eq!(string("a\nb\tc"), "\"a\\nb\\tc\"");
        assert_eq!(string("\u{1}"), "\"\\u0001\"");
        assert_eq!(string("héllo"), "\"héllo\"");
    }

    #[test]
    fn numbers_are_json_safe() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(0.0), "0");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn fields_compose() {
        assert_eq!(field("jobs", "12"), "\"jobs\": 12");
    }

    #[test]
    fn parser_round_trips_a_report_shaped_document() {
        let doc = r#"{
  "report_version": 1,
  "tool": "demst",
  "metrics": { "wall_s": 0.125, "jobs": 6, "sharded": false, "isa": "avx2" },
  "workers": [{ "worker": 0, "busy_s": 0.25 }, { "worker": 1, "busy_s": 0.75 }],
  "empty_obj": {},
  "empty_arr": [],
  "nothing": null
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("report_version").and_then(Value::as_f64), Some(1.0));
        assert_eq!(v.path("metrics.wall_s").and_then(Value::as_f64), Some(0.125));
        assert_eq!(v.path("metrics.jobs").and_then(Value::as_f64), Some(6.0));
        assert_eq!(v.path("metrics.sharded"), Some(&Value::Bool(false)));
        assert_eq!(v.path("metrics.isa").and_then(Value::as_str), Some("avx2"));
        let workers = v.get("workers").and_then(Value::as_arr).unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[1].get("busy_s").and_then(Value::as_f64), Some(0.75));
        assert_eq!(v.get("empty_obj"), Some(&Value::Obj(vec![])));
        assert_eq!(v.get("empty_arr"), Some(&Value::Arr(vec![])));
        assert_eq!(v.get("nothing"), Some(&Value::Null));
        assert_eq!(v.get("absent"), None);
    }

    #[test]
    fn parser_round_trips_our_own_escaping() {
        let original = "a\"b\\c\nd\te\u{1}héllo";
        let doc = format!("{{{}}}", field("s", &string(original)));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("s").and_then(Value::as_str), Some(original));
    }

    #[test]
    fn parser_handles_numbers_including_negatives_and_exponents() {
        let v = parse("[0, -1, 2.5, 1e3, -4.25e-2]").unwrap();
        let nums: Vec<f64> =
            v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(nums, vec![0.0, -1.0, 2.5, 1000.0, -0.0425]);
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "{\"a\" 1}", "tru", "\"unterminated", "1 2"] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}
