//! Fleet-mergeable metrics: relaxed-atomic counters, gauges, and
//! log-linear-bucket histograms.
//!
//! The histogram bucket layout is a compile-time constant shared by every
//! worker, so per-worker histograms merge associatively and commutatively by
//! bucket-wise add: count and sum are *exactly* preserved under any merge
//! tree (sums wrap mod 2^64, like every other u64 tally on the wire), and
//! quantile estimates carry at most one bucket of error. Values `0..8` get
//! an exact bucket each; from 8 up, each power-of-two decade splits into
//! `SUBS = 8` sub-buckets, bounding the relative bucket width at 12.5%
//! across the full u64 range in `N_BUCKETS = 496` buckets.
//!
//! Everything here is `std`-only: atomics for the hot path, a compact
//! little-endian binary codec for shipping snapshots inside `WorkerDone`
//! and `MetricsPush` frames (wire v7), and a [`MetricsHub`] that keeps the
//! leader's fleet-wide view — the substrate `obs::expose` renders as
//! Prometheus text.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// log2 of the number of sub-buckets per power-of-two decade.
pub const SUB_BITS: u32 = 3;
/// Sub-buckets per decade: relative bucket width is `1 / SUBS` = 12.5%.
pub const SUBS: usize = 1 << SUB_BITS;
/// Exact buckets for 0..8, then 61 decades (exponents 3..=63) of 8.
pub const N_BUCKETS: usize = SUBS + (64 - SUB_BITS as usize) * SUBS;

/// Bucket index for a recorded value. Total order: larger values never map
/// to a smaller index.
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // 3..=63
    let sub = ((v >> (e - SUB_BITS)) - SUBS as u64) as usize; // 0..8
    SUBS + (e - SUB_BITS) as usize * SUBS + sub
}

/// Half-open value range `[lo, hi)` covered by bucket `idx`. The last
/// bucket's upper bound saturates at `u64::MAX` (it covers the top of the
/// u64 range).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    debug_assert!(idx < N_BUCKETS);
    if idx < SUBS {
        return (idx as u64, idx as u64 + 1);
    }
    let g = (idx - SUBS) / SUBS; // decade: exponent - SUB_BITS
    let s = ((idx - SUBS) % SUBS) as u64;
    let lo = (SUBS as u64 + s) << g;
    (lo, lo.saturating_add(1u64 << g))
}

/// Counter identities. Fixed order: the wire codec and the exposition both
/// index by discriminant.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Ctr {
    JobsCompleted,
    JobsStolen,
    DistEvals,
    LinkTxBytes,
    LinkRxBytes,
    PeerTxBytes,
    PeerRxBytes,
}

impl Ctr {
    pub const ALL: [Ctr; 7] = [
        Ctr::JobsCompleted,
        Ctr::JobsStolen,
        Ctr::DistEvals,
        Ctr::LinkTxBytes,
        Ctr::LinkRxBytes,
        Ctr::PeerTxBytes,
        Ctr::PeerRxBytes,
    ];

    /// Metric name suffix (the exposition prepends `demst_`).
    pub fn name(self) -> &'static str {
        match self {
            Ctr::JobsCompleted => "jobs_completed_total",
            Ctr::JobsStolen => "jobs_stolen_total",
            Ctr::DistEvals => "dist_evals_total",
            Ctr::LinkTxBytes => "link_tx_bytes_total",
            Ctr::LinkRxBytes => "link_rx_bytes_total",
            Ctr::PeerTxBytes => "peer_tx_bytes_total",
            Ctr::PeerRxBytes => "peer_rx_bytes_total",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Ctr::JobsCompleted => "Pair jobs completed",
            Ctr::JobsStolen => "Pair jobs run off their affinity deck",
            Ctr::DistEvals => "Distance evaluations performed",
            Ctr::LinkTxBytes => "Bytes written on the leader link",
            Ctr::LinkRxBytes => "Bytes read on the leader link",
            Ctr::PeerTxBytes => "Bytes shipped on worker-to-worker links",
            Ctr::PeerRxBytes => "Bytes received on worker-to-worker links",
        }
    }
}

/// Gauge identities. Gauges merge by summation (fleet total).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Gauge {
    QueueDepth,
}

impl Gauge {
    pub const ALL: [Gauge; 1] = [Gauge::QueueDepth];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "queue_depth",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "Pair jobs waiting in the leader queue",
        }
    }
}

/// Histogram identities, instrumented at the PR-9 span points.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Hist {
    /// Pair-job wall latency, nanoseconds.
    JobLatency,
    /// Local-MST build latency, nanoseconds.
    LocalMst,
    /// ⊕-fold latency, nanoseconds.
    Fold,
    /// Peer tree-fetch latency, nanoseconds.
    PeerFetch,
    /// Panel kernel throughput per job, milli-GFLOP/s (GFLOP/s × 1000).
    PanelGflops,
}

impl Hist {
    pub const ALL: [Hist; 5] =
        [Hist::JobLatency, Hist::LocalMst, Hist::Fold, Hist::PeerFetch, Hist::PanelGflops];

    /// Metric name suffix, already carrying the exposition unit.
    pub fn name(self) -> &'static str {
        match self {
            Hist::JobLatency => "job_latency_seconds",
            Hist::LocalMst => "local_mst_seconds",
            Hist::Fold => "fold_seconds",
            Hist::PeerFetch => "peer_fetch_seconds",
            Hist::PanelGflops => "panel_gflops",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Hist::JobLatency => "Pair-job wall latency",
            Hist::LocalMst => "Local MST build latency",
            Hist::Fold => "Tree fold latency",
            Hist::PeerFetch => "Peer tree-fetch latency",
            Hist::PanelGflops => "Panel kernel throughput per job",
        }
    }

    /// Recorded-unit per exposition-unit: ns per second, milli-GFLOP/s per
    /// GFLOP/s. Divide recorded values by this for exposition.
    pub fn unit_scale(self) -> f64 {
        match self {
            Hist::PanelGflops => 1e3,
            _ => 1e9,
        }
    }
}

const N_CTRS: usize = Ctr::ALL.len();
const N_GAUGES: usize = Gauge::ALL.len();
const N_HISTS: usize = Hist::ALL.len();

/// The slowest pair job seen so far: merge keeps the max by latency.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct SlowJob {
    pub ns: u64,
    pub i: u32,
    pub j: u32,
}

struct AtomHist {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Box<[AtomicU64]>,
}

impl AtomHist {
    fn new() -> Self {
        AtomHist {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn record(&self, v: u64) {
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.min.fetch_min(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
    }

    fn snapshot(&self) -> HistSnap {
        HistSnap {
            count: self.count.load(Relaxed),
            sum: self.sum.load(Relaxed),
            min: self.min.load(Relaxed),
            max: self.max.load(Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Relaxed)).collect(),
        }
    }
}

/// Point-in-time copy of one histogram. `min` is `u64::MAX` while empty so
/// that merge is `min(a, b)` with no special case.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct HistSnap {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// Dense bucket occupancy, length `N_BUCKETS` (the codec ships only the
    /// occupied ones).
    pub buckets: Vec<u64>,
}

impl Default for HistSnap {
    fn default() -> Self {
        HistSnap { count: 0, sum: 0, min: u64::MAX, max: 0, buckets: vec![0; N_BUCKETS] }
    }
}

impl HistSnap {
    /// Bucket-wise add: associative and commutative, exact on count/sum.
    pub fn merge(&mut self, other: &HistSnap) {
        self.count = self.count.wrapping_add(other.count);
        self.sum = self.sum.wrapping_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a = a.wrapping_add(*b);
        }
    }

    /// Quantile estimate for `q ∈ [0, 1]`: the midpoint of the bucket
    /// holding the rank-`⌈q·count⌉` value, clamped into that bucket and
    /// into the observed `[min, max]`. Always lies within the bucket's
    /// bounds, so the error is at most the bucket width (≤ 12.5% relative).
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= target {
                let (lo, hi) = bucket_bounds(idx);
                let mid = lo + (hi - lo) / 2;
                let cap = hi.saturating_sub(1).max(lo);
                return Some(mid.clamp(lo, cap).clamp(self.min.min(cap), self.max.max(lo)));
            }
        }
        Some(self.max) // unreachable when buckets are consistent with count
    }

    fn occupied(&self) -> usize {
        self.buckets.iter().filter(|&&c| c != 0).count()
    }
}

/// Point-in-time copy of a whole registry: what ships on the wire and what
/// the leader merges fleet-wide.
#[derive(Clone, PartialEq, Debug)]
pub struct Snapshot {
    pub counters: [u64; N_CTRS],
    pub gauges: [i64; N_GAUGES],
    pub slowest: Option<SlowJob>,
    /// One per `Hist::ALL`, in order.
    pub hists: Vec<HistSnap>,
}

impl Default for Snapshot {
    fn default() -> Self {
        Snapshot {
            counters: [0; N_CTRS],
            gauges: [0; N_GAUGES],
            slowest: None,
            hists: vec![HistSnap::default(); N_HISTS],
        }
    }
}

impl Snapshot {
    pub fn counter(&self, c: Ctr) -> u64 {
        self.counters[c as usize]
    }

    pub fn gauge(&self, g: Gauge) -> i64 {
        self.gauges[g as usize]
    }

    pub fn hist(&self, h: Hist) -> &HistSnap {
        &self.hists[h as usize]
    }

    /// Fleet merge: counters and gauges add, histograms add bucket-wise,
    /// slowest-job keeps the max by latency.
    pub fn merge(&mut self, other: &Snapshot) {
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a = a.wrapping_add(*b);
        }
        for (a, b) in self.gauges.iter_mut().zip(&other.gauges) {
            *a = a.wrapping_add(*b);
        }
        if other.slowest.is_some_and(|o| self.slowest.is_none_or(|s| o.ns > s.ns)) {
            self.slowest = other.slowest;
        }
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.merge(b);
        }
    }

    /// Encoded size in bytes — the single source of truth for the byte
    /// model, mirroring `wire::encoded_len`.
    pub fn wire_bytes(&self) -> u64 {
        let hist_bytes: u64 =
            self.hists.iter().map(|h| 34 + 10 * h.occupied() as u64).sum();
        4 + 8 * N_CTRS as u64 + 8 * N_GAUGES as u64 + 16 + hist_bytes
    }

    /// Compact little-endian codec: a 4-byte shape header (so a version-
    /// skewed block fails loudly), dense counters/gauges, the slowest-job
    /// triple, then per histogram `count/sum/min/max`, an occupied-bucket
    /// count, and `(index u16, count u64)` pairs in ascending index order.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() as usize);
        out.extend_from_slice(&[N_CTRS as u8, N_GAUGES as u8, N_HISTS as u8, 0]);
        for c in &self.counters {
            out.extend_from_slice(&c.to_le_bytes());
        }
        for g in &self.gauges {
            out.extend_from_slice(&g.to_le_bytes());
        }
        let slow = self.slowest.unwrap_or(SlowJob { ns: 0, i: 0, j: 0 });
        out.extend_from_slice(&slow.ns.to_le_bytes());
        out.extend_from_slice(&slow.i.to_le_bytes());
        out.extend_from_slice(&slow.j.to_le_bytes());
        for h in &self.hists {
            out.extend_from_slice(&h.count.to_le_bytes());
            out.extend_from_slice(&h.sum.to_le_bytes());
            out.extend_from_slice(&h.min.to_le_bytes());
            out.extend_from_slice(&h.max.to_le_bytes());
            out.extend_from_slice(&(h.occupied() as u16).to_le_bytes());
            for (idx, &c) in h.buckets.iter().enumerate() {
                if c != 0 {
                    out.extend_from_slice(&(idx as u16).to_le_bytes());
                    out.extend_from_slice(&c.to_le_bytes());
                }
            }
        }
        debug_assert_eq!(out.len() as u64, self.wire_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Snapshot> {
        let mut r = Cursor { buf, at: 0 };
        let shape = [r.u8()?, r.u8()?, r.u8()?, r.u8()?];
        if shape != [N_CTRS as u8, N_GAUGES as u8, N_HISTS as u8, 0] {
            bail!("metrics block shape {shape:?} does not match this build");
        }
        let mut snap = Snapshot::default();
        for c in snap.counters.iter_mut() {
            *c = r.u64()?;
        }
        for g in snap.gauges.iter_mut() {
            *g = r.u64()? as i64;
        }
        let (ns, i, j) = (r.u64()?, r.u32()?, r.u32()?);
        snap.slowest = (ns != 0).then_some(SlowJob { ns, i, j });
        for h in snap.hists.iter_mut() {
            h.count = r.u64()?;
            h.sum = r.u64()?;
            h.min = r.u64()?;
            h.max = r.u64()?;
            let nz = r.u16()? as usize;
            if nz > N_BUCKETS {
                bail!("metrics block claims {nz} occupied buckets (max {N_BUCKETS})");
            }
            let mut prev: Option<usize> = None;
            for _ in 0..nz {
                let idx = r.u16()? as usize;
                if idx >= N_BUCKETS {
                    bail!("metrics bucket index {idx} out of range");
                }
                if prev.is_some_and(|p| idx <= p) {
                    bail!("metrics bucket indices must be strictly ascending");
                }
                prev = Some(idx);
                h.buckets[idx] = r.u64()?;
            }
        }
        if r.at != buf.len() {
            bail!("metrics block has {} trailing bytes", buf.len() - r.at);
        }
        Ok(snap)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.buf.len() - self.at < n {
            bail!("metrics block truncated at byte {}", self.at);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// One process's live metrics. Recording is lock-free (relaxed atomics)
/// except the slowest-job tracker, which takes a short mutex only on the
/// job-completion path.
pub struct Registry {
    counters: [AtomicU64; N_CTRS],
    gauges: [AtomicI64; N_GAUGES],
    hists: [AtomHist; N_HISTS],
    slowest: Mutex<Option<SlowJob>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicI64::new(0)),
            hists: std::array::from_fn(|_| AtomHist::new()),
            slowest: Mutex::new(None),
        }
    }

    pub fn add(&self, c: Ctr, delta: u64) {
        self.counters[c as usize].fetch_add(delta, Relaxed);
    }

    pub fn gauge_set(&self, g: Gauge, v: i64) {
        self.gauges[g as usize].store(v, Relaxed);
    }

    pub fn gauge_add(&self, g: Gauge, delta: i64) {
        self.gauges[g as usize].fetch_add(delta, Relaxed);
    }

    pub fn observe(&self, h: Hist, v: u64) {
        self.hists[h as usize].record(v);
    }

    /// Record one completed pair job: latency histogram, completion
    /// counter, and the slowest-job tracker in one call.
    pub fn observe_job(&self, ns: u64, i: u32, j: u32) {
        self.observe(Hist::JobLatency, ns);
        self.add(Ctr::JobsCompleted, 1);
        let mut slow = self.slowest.lock().unwrap();
        if slow.is_none_or(|s| ns > s.ns) {
            *slow = Some(SlowJob { ns, i, j });
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: std::array::from_fn(|i| self.counters[i].load(Relaxed)),
            gauges: std::array::from_fn(|i| self.gauges[i].load(Relaxed)),
            slowest: *self.slowest.lock().unwrap(),
            hists: self.hists.iter().map(|h| h.snapshot()).collect(),
        }
    }
}

/// The leader's fleet-wide view: its own registry plus the latest snapshot
/// pushed by each remote worker (pushes are cumulative, so latest-wins
/// replacement is the correct merge input). Created per run — never a
/// process global, so parallel in-process runs can't cross-contaminate.
#[derive(Default)]
pub struct MetricsHub {
    pub local: Registry,
    workers: Mutex<HashMap<u16, Snapshot>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        MetricsHub::default()
    }

    /// Install `snap` as worker `id`'s latest cumulative snapshot.
    pub fn absorb(&self, id: u16, snap: Snapshot) {
        self.workers.lock().unwrap().insert(id, snap);
    }

    /// Number of remote workers that have pushed at least once.
    pub fn workers_reporting(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Fleet-wide merged view: leader-local registry ⊕ every worker's
    /// latest snapshot.
    pub fn merged(&self) -> Snapshot {
        let mut out = self.local.snapshot();
        for snap in self.workers.lock().unwrap().values() {
            out.merge(snap);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_total_and_tight() {
        // Every probe value lands in a bucket whose bounds contain it, and
        // the index is monotone in the value.
        let probes: Vec<u64> = (0..200)
            .chain([255, 256, 257, 1023, 1024, 4095, 1 << 20, (1 << 40) + 17, u64::MAX / 2])
            .chain([u64::MAX - 1, u64::MAX])
            .collect();
        let mut last_idx = 0;
        for &v in &probes {
            let idx = bucket_index(v);
            assert!(idx < N_BUCKETS, "{v} -> {idx}");
            assert!(idx >= last_idx, "index must be monotone at {v}");
            last_idx = idx;
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v, "{v} below bucket {idx} [{lo},{hi})");
            assert!(v < hi || hi == u64::MAX, "{v} above bucket {idx} [{lo},{hi})");
            // relative width bound: (hi - lo) / lo <= 1/8 for lo >= 8
            if lo >= 8 && hi != u64::MAX {
                assert!(hi - lo <= lo / 8, "bucket {idx} wider than 12.5%");
            }
        }
        // the top bucket is the last one
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn merge_preserves_count_and_sum_exactly() {
        let a = Registry::new();
        let b = Registry::new();
        for v in [0u64, 1, 7, 8, 9, 100, 12_345, 1 << 33] {
            a.observe(Hist::JobLatency, v);
            b.observe(Hist::JobLatency, v * 3 + 1);
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let mut ab = sa.clone();
        ab.merge(&sb);
        let mut ba = sb.clone();
        ba.merge(&sa);
        assert_eq!(ab, ba, "merge is commutative");
        let h = ab.hist(Hist::JobLatency);
        assert_eq!(h.count, 16);
        let want: u64 = [0u64, 1, 7, 8, 9, 100, 12_345, 1 << 33]
            .iter()
            .map(|v| v + v * 3 + 1)
            .sum();
        assert_eq!(h.sum, want);
        assert_eq!(h.min, 0);
        assert_eq!(h.max, (1u64 << 33) * 3 + 1);
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count, "buckets account for every sample");
    }

    #[test]
    fn quantiles_stay_within_bucket_and_range() {
        let r = Registry::new();
        let vals: Vec<u64> = (1..=1000).map(|i| i * 37).collect();
        for &v in &vals {
            r.observe(Hist::Fold, v);
        }
        let h = r.snapshot().hist(Hist::Fold).clone();
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let est = h.quantile(q).unwrap();
            assert!(est >= h.min && est <= h.max, "q={q} est {est} outside [min,max]");
            // the estimate is inside *some* bucket that brackets the true
            // rank value within one bucket
            let rank = ((q * 1000.0).ceil() as usize).clamp(1, 1000);
            let truth = vals[rank - 1];
            let (lo, hi) = bucket_bounds(bucket_index(truth));
            assert!(est >= lo && est < hi, "q={q}: est {est} not in truth bucket [{lo},{hi})");
        }
        assert!(HistSnap::default().quantile(0.5).is_none(), "empty histogram has no quantile");
    }

    #[test]
    fn snapshot_codec_roundtrips_and_pins_size() {
        let r = Registry::new();
        r.add(Ctr::DistEvals, 12_345);
        r.add(Ctr::LinkTxBytes, 999);
        r.gauge_set(Gauge::QueueDepth, -3);
        r.observe_job(5_000_000, 4, 9);
        r.observe_job(1_000_000, 0, 1);
        r.observe(Hist::PeerFetch, 42);
        let snap = r.snapshot();
        let buf = snap.encode();
        assert_eq!(buf.len() as u64, snap.wire_bytes(), "encode length == wire_bytes");
        assert_eq!(Snapshot::decode(&buf).unwrap(), snap);
        assert_eq!(snap.slowest, Some(SlowJob { ns: 5_000_000, i: 4, j: 9 }));
        // empty snapshot: fixed-size header + per-hist fixed blocks only
        let empty = Snapshot::default();
        assert_eq!(
            empty.wire_bytes(),
            4 + 8 * 7 + 8 * 1 + 16 + 5 * 34,
            "empty snapshot size is pinned"
        );
        assert_eq!(Snapshot::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn decode_rejects_corrupt_blocks() {
        let snap = {
            let r = Registry::new();
            r.observe(Hist::JobLatency, 17);
            r.snapshot()
        };
        let good = snap.encode();
        assert!(Snapshot::decode(&good[..good.len() - 1]).is_err(), "truncated");
        let mut shape = good.clone();
        shape[2] = 99;
        assert!(Snapshot::decode(&shape).is_err(), "shape mismatch");
        let mut extra = good.clone();
        extra.push(0);
        assert!(Snapshot::decode(&extra).is_err(), "trailing bytes");
        // a forged huge occupied-bucket count is refused before allocation
        let hist_at = 4 + 8 * 7 + 8 + 16; // first hist block
        let mut forged = good;
        forged[hist_at + 32..hist_at + 34].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(Snapshot::decode(&forged).is_err(), "hostile bucket count rejected");
    }

    #[test]
    fn hub_merges_fleet_wide_with_latest_wins_pushes() {
        let hub = MetricsHub::new();
        hub.local.observe_job(10, 0, 1);
        let mk = |jobs: u64, ns: u64| {
            let r = Registry::new();
            for k in 0..jobs {
                r.observe_job(ns + k, 2, 3);
            }
            r.snapshot()
        };
        hub.absorb(1, mk(2, 100));
        hub.absorb(1, mk(3, 100)); // cumulative re-push replaces
        hub.absorb(2, mk(1, 999));
        let fleet = hub.merged();
        assert_eq!(fleet.counter(Ctr::JobsCompleted), 1 + 3 + 1);
        assert_eq!(fleet.hist(Hist::JobLatency).count, 5);
        assert_eq!(fleet.slowest.unwrap().ns, 999);
        assert_eq!(hub.workers_reporting(), 2);
    }
}
