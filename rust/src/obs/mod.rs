//! obs — zero-dependency structured run telemetry: spans, logs, exporters.
//!
//! The flight recorder for a distributed run. Every interesting interval
//! (pair job, local MST, panel product, ⊕-fold, peer fetch, handshake) and
//! every interesting instant (stall demotion, mid-run admission, injected
//! chaos fault, failover) becomes a [`Span`]: a fixed 32-byte record with
//! IDs that survive the wire. Workers record spans into per-thread buffers
//! and ship them back piggybacked on `WorkerDone` (wire v6), so the leader
//! reassembles a *fleet-wide* timeline without a second collection channel.
//!
//! Pieces:
//! - [`recorder`] — per-thread span buffers behind a run-token scheme:
//!   recording is off by default and costs one relaxed atomic load when
//!   disabled (zero allocations on the job hot path, so e7/e8 don't move);
//! - [`trace`] — Chrome-trace / Perfetto JSON exporter
//!   (`demst run --trace-out trace.json`): one track per worker, duration
//!   events for jobs/folds/fetches, instant events for faults;
//! - [`report`] — versioned machine-readable run report
//!   (`--report-out run.json`): full `RunMetrics` + per-worker breakdown +
//!   config fingerprint, validated in CI by `scripts/check_run_report.py`;
//! - [`metrics`] — fleet-mergeable counters/gauges/log-linear histograms
//!   (wire v7: compact snapshots ride `WorkerDone` and periodic
//!   `MetricsPush` frames; the leader's [`metrics::MetricsHub`] merges
//!   them fleet-wide);
//! - [`expose`] — hand-rolled Prometheus text exposition (format 0.0.4)
//!   on a tiny HTTP listener (`--metrics-listen`), scrapeable mid-run;
//! - [`progress`] — leader-side live ticker (jobs done/total, bytes,
//!   stalls, admissions; auto-off when stderr is not a tty or `--quiet`);
//! - [`json`] — the tiny hand-rolled JSON helpers: string/number writers
//!   plus the minimal parser `report diff` reads run reports back with
//!   (no serde in the offline vendor set);
//! - the [`log!`](crate::obs_log) macro — `DEMST_LOG`-leveled stderr
//!   logging replacing the ad-hoc `eprintln!` diagnostics.

pub mod expose;
pub mod json;
pub mod metrics;
pub mod progress;
pub mod recorder;
pub mod report;
pub mod trace;

pub use progress::Progress;
pub use recorder::{
    adopt, begin_run, drain, end_run, instant, now_ns, record, recording, span, RunToken,
    SpanGuard,
};

/// What a [`Span`] measures. Codes are wire-stable (wire v6): renumbering
/// is a wire break, so new kinds append.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum SpanKind {
    /// One bipartite pair job (`arg` = distance evals).
    Job = 1,
    /// One subset's local MST build (`arg` = distance evals).
    LocalMst = 2,
    /// One panel-product block (`arg` = FLOPs).
    Panel = 3,
    /// One ⊕-fold of two partial forests (`arg` = edges folded).
    Fold = 4,
    /// One worker↔worker cached-tree fetch (`arg` = bytes received).
    PeerFetch = 5,
    /// Connect → Hello/Setup handshake on a worker link.
    Handshake = 6,
    /// Instant: a link demoted by the liveness deadline.
    Stall = 7,
    /// Instant: a worker admitted mid-run (`arg` = worker id).
    Admit = 8,
    /// Instant: an injected chaos fault fired (`arg` = frame number).
    Chaos = 9,
    /// Instant: a dead link's jobs returned to the deck (`arg` = jobs).
    Failover = 10,
}

impl SpanKind {
    pub fn code(self) -> u8 {
        self as u8
    }

    pub fn from_code(code: u8) -> Option<SpanKind> {
        Some(match code {
            1 => SpanKind::Job,
            2 => SpanKind::LocalMst,
            3 => SpanKind::Panel,
            4 => SpanKind::Fold,
            5 => SpanKind::PeerFetch,
            6 => SpanKind::Handshake,
            7 => SpanKind::Stall,
            8 => SpanKind::Admit,
            9 => SpanKind::Chaos,
            10 => SpanKind::Failover,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Job => "job",
            SpanKind::LocalMst => "local_mst",
            SpanKind::Panel => "panel",
            SpanKind::Fold => "fold",
            SpanKind::PeerFetch => "peer_fetch",
            SpanKind::Handshake => "handshake",
            SpanKind::Stall => "stall",
            SpanKind::Admit => "admit",
            SpanKind::Chaos => "chaos",
            SpanKind::Failover => "failover",
        }
    }

    /// Instant kinds have `start_ns == end_ns` and export as Chrome-trace
    /// `ph:"i"` events rather than duration slices.
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Stall | SpanKind::Admit | SpanKind::Chaos | SpanKind::Failover
        )
    }
}

/// One timestamped interval (or instant, when `start_ns == end_ns`).
/// Exactly [`crate::net::wire::SPAN_BYTES`] = 32 bytes on the wire:
/// kind u8 · pad u8 · worker u16 · id u32 · arg u64 · start u64 · end u64.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    pub kind_code: u8,
    /// Track the span belongs to (worker rank; leader uses its own rank 0
    /// tracks only for fold/reduce work it does itself).
    pub worker: u16,
    /// Kind-scoped id: job id for `Job`, subset for `LocalMst`, peer for
    /// `PeerFetch`, worker for `Admit`/`Stall`/`Failover`.
    pub id: u32,
    /// Kind-scoped payload (see [`SpanKind`] docs).
    pub arg: u64,
    /// Nanoseconds since the recording process's epoch (re-based onto the
    /// leader's clock when shipped over the wire).
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Span {
    pub fn kind(&self) -> Option<SpanKind> {
        SpanKind::from_code(self.kind_code)
    }
}

/// Severity for [`log!`](crate::obs_log). `DEMST_LOG` picks the maximum
/// printed level: `off|error|warn|info|debug|trace` (default `info`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// 0 = off; otherwise the highest `Level` that prints. Parsed from
/// `DEMST_LOG` once per process.
fn max_level() -> u8 {
    use std::sync::OnceLock;
    static MAX: OnceLock<u8> = OnceLock::new();
    *MAX.get_or_init(|| {
        match std::env::var("DEMST_LOG").ok().as_deref() {
            Some("off") | Some("0") | Some("none") => 0,
            Some("error") => Level::Error as u8,
            Some("warn") | Some("warning") => Level::Warn as u8,
            Some("info") => Level::Info as u8,
            Some("debug") => Level::Debug as u8,
            Some("trace") => Level::Trace as u8,
            // Unknown values fall back to the default rather than erroring:
            // logging must never take a run down.
            _ => Level::Info as u8,
        }
    })
}

pub fn level_enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Sink for [`log!`](crate::obs_log). Formatting is deferred: when the
/// level is filtered out nothing is rendered. Holds the stderr lock across
/// clearing a live progress-ticker line and writing the log line, so the
/// `\r` ticker and log output never clobber each other; the ticker
/// repaints on its next tick.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if level_enabled(level) {
        use std::io::Write;
        let mut err = std::io::stderr().lock();
        progress::clear_for_log(&mut err);
        let _ = writeln!(err, "[demst {}] {args}", level.name());
    }
}

/// `obs::log!(warn, "fmt", args...)` — leveled stderr logging.
///
/// The first token is a literal level ident (`error|warn|info|debug|trace`);
/// the rest is `format!` syntax. Filtered levels cost one memoized load and
/// never format.
#[macro_export]
macro_rules! obs_log {
    (error, $($t:tt)*) => { $crate::obs::emit($crate::obs::Level::Error, format_args!($($t)*)) };
    (warn,  $($t:tt)*) => { $crate::obs::emit($crate::obs::Level::Warn,  format_args!($($t)*)) };
    (info,  $($t:tt)*) => { $crate::obs::emit($crate::obs::Level::Info,  format_args!($($t)*)) };
    (debug, $($t:tt)*) => { $crate::obs::emit($crate::obs::Level::Debug, format_args!($($t)*)) };
    (trace, $($t:tt)*) => { $crate::obs::emit($crate::obs::Level::Trace, format_args!($($t)*)) };
}
pub use crate::obs_log as log;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_kind_codes_roundtrip_and_stay_stable() {
        for code in 1u8..=10 {
            let k = SpanKind::from_code(code).expect("codes 1..=10 are assigned");
            assert_eq!(k.code(), code);
            assert!(!k.name().is_empty());
        }
        assert_eq!(SpanKind::from_code(0), None);
        assert_eq!(SpanKind::from_code(11), None);
        // Wire-stable pins: renumbering these is a wire break.
        assert_eq!(SpanKind::Job.code(), 1);
        assert_eq!(SpanKind::Fold.code(), 4);
        assert_eq!(SpanKind::Failover.code(), 10);
    }

    #[test]
    fn instant_kinds_are_exactly_the_point_events() {
        let instants: Vec<_> =
            (1u8..=10).filter_map(SpanKind::from_code).filter(|k| k.is_instant()).collect();
        assert_eq!(
            instants,
            vec![SpanKind::Stall, SpanKind::Admit, SpanKind::Chaos, SpanKind::Failover]
        );
    }

    #[test]
    fn log_macro_compiles_at_every_level() {
        // Smoke the macro plumbing; output goes to stderr and is not captured.
        crate::obs::log!(trace, "trace {}", 1);
        crate::obs::log!(debug, "debug {}", 2);
        crate::obs::log!(info, "info {}", 3);
        crate::obs::log!(warn, "warn {}", 4);
        crate::obs::log!(error, "error {}", 5);
        assert!(level_enabled(Level::Error) || max_level() == 0);
    }
}
