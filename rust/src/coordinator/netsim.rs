//! Simulated network: byte/message accounting plus an optional latency +
//! bandwidth delay model.
//!
//! Every leader↔worker send goes through [`NetSim::send`], which (a) adds the
//! message's wire size to the right direction counter and (b) if
//! `simulate_delays` is set, sleeps `latency + bytes/bandwidth` *in the
//! sending thread* before delivery — modelling a blocking rendezvous send on
//! a full-duplex link, good enough to surface the `O(|V||P|)` vs `O(|V|)`
//! gather asymmetry as wallclock, not just counters.

use super::messages::Message;
use crate::config::NetConfig;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Traffic direction, for the per-phase accounting the paper's cost model
/// distinguishes (scatter of vectors vs gather of tree edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Scatter,
    Gather,
    Control,
}

/// Shared traffic counters.
#[derive(Debug, Default)]
pub struct NetCounters {
    pub scatter_bytes: AtomicU64,
    pub gather_bytes: AtomicU64,
    pub control_bytes: AtomicU64,
    pub messages: AtomicU64,
}

impl NetCounters {
    pub fn total_bytes(&self) -> u64 {
        self.scatter_bytes.load(Ordering::Relaxed)
            + self.gather_bytes.load(Ordering::Relaxed)
            + self.control_bytes.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.scatter_bytes.load(Ordering::Relaxed),
            self.gather_bytes.load(Ordering::Relaxed),
            self.control_bytes.load(Ordering::Relaxed),
            self.messages.load(Ordering::Relaxed),
        )
    }
}

/// The simulated network fabric (shared by all endpoints).
#[derive(Clone)]
pub struct NetSim {
    cfg: NetConfig,
    counters: Arc<NetCounters>,
}

impl NetSim {
    pub fn new(cfg: NetConfig) -> Self {
        Self { cfg, counters: Arc::new(NetCounters::default()) }
    }

    pub fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// Transfer delay for `bytes` under the configured link model.
    pub fn model_delay(&self, bytes: u64) -> Duration {
        Duration::from_micros(self.cfg.latency_us)
            + Duration::from_secs_f64(bytes as f64 / self.cfg.bandwidth)
    }

    /// Account for (and, with `simulate_delays`, sleep for) a message of
    /// `bytes` that is *modeled* but not physically delivered — used by the
    /// pull-based exec scheduler, where workers claim jobs from a shared
    /// queue instead of receiving them over a channel, yet the scatter of
    /// the job payload must still be charged to the link.
    pub fn charge(&self, bytes: u64, dir: Direction) {
        let ctr = match dir {
            Direction::Scatter => &self.counters.scatter_bytes,
            Direction::Gather => &self.counters.gather_bytes,
            Direction::Control => &self.counters.control_bytes,
        };
        ctr.fetch_add(bytes, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        if self.cfg.simulate_delays {
            std::thread::sleep(self.model_delay(bytes));
        }
    }

    /// Account for and (optionally) delay a message, then deliver it.
    /// Returns `Err` if the receiving endpoint hung up.
    pub fn send(
        &self,
        tx: &Sender<Message>,
        msg: Message,
        dir: Direction,
    ) -> Result<(), std::sync::mpsc::SendError<Message>> {
        self.charge(msg.wire_bytes(), dir);
        tx.send(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::decomp::PairJob;
    use std::sync::mpsc::channel;

    fn job_msg(n: usize, d: usize) -> Message {
        Message::Job {
            job: PairJob { id: 0, i: 0, j: 1 },
            global_ids: (0..n as u32).collect(),
            points: Dataset::zeros(n, d),
        }
    }

    #[test]
    fn counters_accumulate_by_direction() {
        let net = NetSim::new(NetConfig::default());
        let (tx, rx) = channel();
        net.send(&tx, job_msg(10, 4), Direction::Scatter).unwrap();
        net.send(&tx, Message::Shutdown, Direction::Control).unwrap();
        let (s, g, c, m) = net.counters().snapshot();
        assert_eq!(s, 16 + 40 + 160);
        assert_eq!(g, 0);
        assert_eq!(c, 16);
        assert_eq!(m, 2);
        drop(rx);
    }

    #[test]
    fn delay_model_scales_with_bytes() {
        let cfg = NetConfig { simulate_delays: false, latency_us: 100, bandwidth: 1e6 };
        let net = NetSim::new(cfg);
        let d1 = net.model_delay(0);
        let d2 = net.model_delay(1_000_000);
        assert_eq!(d1, Duration::from_micros(100));
        assert_eq!(d2, Duration::from_micros(100) + Duration::from_secs(1));
    }

    #[test]
    fn send_to_dropped_receiver_errors() {
        let net = NetSim::new(NetConfig::default());
        let (tx, rx) = channel();
        drop(rx);
        assert!(net.send(&tx, Message::Shutdown, Direction::Control).is_err());
    }

    #[test]
    fn simulated_delay_actually_sleeps() {
        let cfg = NetConfig { simulate_delays: true, latency_us: 2000, bandwidth: 1e12 };
        let net = NetSim::new(cfg);
        let (tx, _rx) = channel();
        let t = std::time::Instant::now();
        net.send(&tx, Message::Shutdown, Direction::Control).unwrap();
        assert!(t.elapsed() >= Duration::from_micros(1500));
    }
}
