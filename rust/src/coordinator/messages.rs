//! Typed messages between leader and workers, with wire-size accounting.
//!
//! Wire sizes model a compact binary encoding: fixed 16-byte header per
//! message (type tag, ids, lengths) + payload. The netsim charges these
//! sizes; nothing is actually serialized (threads share memory), which keeps
//! the simulation honest *and* fast.

use crate::data::Dataset;
use crate::decomp::PairJob;
use crate::graph::Edge;
use std::time::Duration;

/// Message header bytes (tag + routing + length fields).
pub const HEADER_BYTES: u64 = 16;

/// Leader ↔ worker messages.
#[derive(Debug)]
pub enum Message {
    /// Leader → worker: compute d-MST(S_i ∪ S_j). Carries the actual vectors
    /// (the scatter) and the local→global index map.
    Job { job: PairJob, global_ids: Vec<u32>, points: Dataset },
    /// Worker → leader (gather mode): one pair-tree, reindexed to global
    /// ids, plus the job's kernel compute time (used to model makespans on
    /// machines with fewer cores than ranks — see `metrics::modeled_makespan`).
    Result { job_id: u32, worker: usize, edges: Vec<Edge>, compute: Duration },
    /// Worker → leader (final): locally ⊕-combined tree (reduce mode only)
    /// plus work/timing/locality stats.
    WorkerDone {
        worker: usize,
        local_tree: Option<Vec<Edge>>,
        dist_evals: u64,
        busy: Duration,
        jobs_run: u32,
        /// pair jobs this worker claimed from another worker's affinity deck
        jobs_stolen: u32,
        /// subset-panel cache hits (bipartite-merge kernel only)
        panel_hits: u64,
        /// subset-panel cache misses (bipartite-merge kernel only)
        panel_misses: u64,
    },
    /// Leader → worker: drain and report.
    Shutdown,
}

/// Wire bytes of a pair-job scatter shipping `ids` vectors of dimension `d`
/// (header + global-id map + vector payload). The pull-based exec scheduler
/// charges this without materializing a [`Message::Job`]; kept next to
/// [`Message::wire_bytes`] so the two models cannot drift.
pub fn job_wire_bytes(ids: usize, d: usize) -> u64 {
    HEADER_BYTES + ids as u64 * 4 + (ids * d) as u64 * 4
}

impl Message {
    /// Bytes this message would occupy on the wire.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Job { global_ids, points, .. } => {
                HEADER_BYTES + global_ids.len() as u64 * 4 + points.payload_bytes()
            }
            Message::Result { edges, .. } => {
                HEADER_BYTES + edges.len() as u64 * Edge::WIRE_BYTES as u64
            }
            Message::WorkerDone { local_tree, .. } => {
                // stats block: dist_evals u64 + busy u64 + jobs_run u32 +
                // jobs_stolen u32 + panel_hits u64 + panel_misses u64
                HEADER_BYTES
                    + 40
                    + local_tree.as_ref().map_or(0, |t| t.len() as u64 * Edge::WIRE_BYTES as u64)
            }
            Message::Shutdown => HEADER_BYTES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_bytes_dominated_by_vectors() {
        let points = Dataset::zeros(100, 64);
        let msg = Message::Job {
            job: PairJob { id: 0, i: 0, j: 1 },
            global_ids: (0..100).collect(),
            points,
        };
        assert_eq!(msg.wire_bytes(), 16 + 400 + 100 * 64 * 4);
        assert_eq!(job_wire_bytes(100, 64), msg.wire_bytes(), "models agree");
    }

    #[test]
    fn result_bytes_linear_in_edges() {
        let edges = vec![Edge::new(0, 1, 1.0); 99];
        let msg = Message::Result { job_id: 3, worker: 0, edges, compute: Duration::ZERO };
        assert_eq!(msg.wire_bytes(), 16 + 99 * 12);
    }

    #[test]
    fn done_with_and_without_tree() {
        let a = Message::WorkerDone {
            worker: 0,
            local_tree: None,
            dist_evals: 10,
            busy: Duration::ZERO,
            jobs_run: 1,
            jobs_stolen: 0,
            panel_hits: 0,
            panel_misses: 0,
        };
        let b = Message::WorkerDone {
            worker: 0,
            local_tree: Some(vec![Edge::new(0, 1, 1.0); 5]),
            dist_evals: 10,
            busy: Duration::ZERO,
            jobs_run: 1,
            jobs_stolen: 2,
            panel_hits: 7,
            panel_misses: 3,
        };
        assert_eq!(a.wire_bytes(), 56, "header 16 + 40-byte stats block");
        assert_eq!(b.wire_bytes(), 56 + 60);
    }
}
