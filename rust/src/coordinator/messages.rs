//! Typed messages between leader and workers, with wire-size accounting.
//!
//! Wire sizes are computed from the **real binary encoding** in
//! [`crate::net::wire`] — a fixed 16-byte framed header (length prefix, type
//! tag, ids, lengths) + payload. Under the simulated transport nothing is
//! serialized (threads share memory) but the charged sizes are exactly what
//! the TCP transport puts on the socket, which keeps the simulation honest:
//! `encode(msg).len() == msg.wire_bytes()` for every variant (pinned by a
//! proptest in `tests/proptests.rs`).

use crate::data::Dataset;
use crate::decomp::PairJob;
use crate::graph::Edge;
use std::time::Duration;

/// Message header bytes (length prefix + tag + routing + length fields).
pub const HEADER_BYTES: u64 = 16;

/// Sentinel `to` in [`Message::FoldShip`]: the receiver is the reduction
/// root — keep the folded forest and report it in `WorkerDone` instead of
/// shipping it to a peer.
pub const FOLD_KEEP: u16 = u16::MAX;

/// One worker's peer-plane listener address, as observed by the leader:
/// the IP the worker's leader connection arrived from, paired with the
/// listener port the worker advertised in its [`crate::net::wire::Hello`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeerAddr {
    /// source IP of the worker's leader connection (v4 or v6)
    pub ip: std::net::IpAddr,
    /// the worker's advertised peer listener port (0 = no listener)
    pub port: u16,
}

/// One subset's share of a pair-job scatter under the resident-set model:
/// the vectors (with their global-id map) and/or the cached local MST,
/// shipped only when the executing worker does not already hold them.
#[derive(Clone, Debug, PartialEq)]
pub struct SubsetShip {
    /// partition subset index
    pub part: u32,
    /// global-id map + the subset's rows (`ids.len() == points.n`)
    pub vectors: Option<(Vec<u32>, Dataset)>,
    /// the subset's cached local MST, compare-form weights
    /// (bipartite-merge kernel only); always `|S_k| - 1` edges
    pub tree: Option<Vec<Edge>>,
    /// peer-routed tree: the section ships **zero** payload bytes and the
    /// executing worker pulls the subset's cached local MST from its
    /// building anchor over a peer link instead (mutually exclusive with
    /// `tree`; the leader's `PeerBook` names the anchor)
    pub routed: bool,
}

/// Leader ↔ worker messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Leader → worker: compute d-MST(S_i ∪ S_j). Carries the actual vectors
    /// (the scatter) and the local→global index map.
    Job { job: PairJob, global_ids: Vec<u32>, points: Dataset },
    /// Leader → worker: one pair job under the resident-set model — only
    /// the subsets (vectors and/or cached trees) the worker is missing ride
    /// along; everything else is already resident from earlier jobs. The
    /// wire size is exactly the engine's per-job scatter charge.
    PairAssign { job: PairJob, ships: Vec<SubsetShip> },
    /// Leader → worker: build the local MST of one partition subset
    /// (bipartite-merge phase 1) and keep the subset resident.
    LocalJob { part: u32, global_ids: Vec<u32>, points: Dataset },
    /// Leader → worker (sharded runs): build the local MST of a subset the
    /// worker already holds from its shard files — phase 1 without any
    /// vectors on the wire (the frame is its 16-byte header).
    LocalAssign { part: u32 },
    /// Worker → leader: one subset's local MST (global ids, compare-form
    /// weights) plus the build time.
    LocalDone { part: u32, edges: Vec<Edge>, compute: Duration },
    /// Worker → leader (gather mode): one pair-tree, reindexed to global
    /// ids, plus the job's kernel compute time (used to model makespans on
    /// machines with fewer cores than ranks — see `metrics::modeled_makespan`).
    Result { job_id: u32, worker: usize, edges: Vec<Edge>, compute: Duration },
    /// Worker → leader (reduce mode): job folded into the worker-local tree;
    /// nothing to gather yet. Lets the leader's rendezvous loop advance.
    Ack { job_id: u32 },
    /// Worker → leader: a peer-routed tree fetch failed (dead or refusing
    /// anchor), so the job was **not** executed — it must return to the
    /// exactly-once lane and be re-planned with the tree shipped inline.
    PairFail { job_id: u32 },
    /// Worker → leader: reply to a [`Message::FoldShip`] directive — the
    /// worker folded the expected peer partials (and shipped the result on,
    /// unless it is the root). `ok = false` means a peer never delivered and
    /// the worker keeps its partial for the leader-assisted fallback.
    FoldDone { ok: bool },
    /// Worker ↔ worker: opens a peer link (sent once per link by the
    /// connecting side; carries the sender's worker id for logging and the
    /// handshake magic for sanity).
    PeerHello { from: u16 },
    /// Worker → worker: pull one subset's cached local MST from its
    /// building anchor (the routed half of a `PairAssign`).
    TreeFetch { part: u32 },
    /// Worker → worker: a tree payload on a peer link. `fold = false`: the
    /// reply to a [`Message::TreeFetch`] (a subset's cached local MST, keyed
    /// by `part`). `fold = true`: a ⊕-reduction hop — the sender's folded
    /// partial MSF (`part` carries the sender's worker id), to be ⊕-merged
    /// into the receiver's partial under a tree/ring topology.
    TreeShip { part: u32, fold: bool, edges: Vec<Edge> },
    /// Leader → worker (reduce topologies): fold directive. Wait for
    /// `expect` peer partials, ⊕-fold them into your own, then ship the
    /// result to worker `to` — or keep it for your `WorkerDone` when
    /// `to == `[`FOLD_KEEP`].
    FoldShip { to: u16, expect: u16 },
    /// Leader → worker: the fleet's peer-plane routing table. `peers[w]` is
    /// worker `w`'s listener address; `builders[k]` is the worker id that
    /// built (anchors) subset `k`'s local MST, [`FOLD_KEEP`] when the
    /// leader holds it (in-process build).
    PeerBook { peers: Vec<PeerAddr>, builders: Vec<u16> },
    /// Worker → leader (final): locally ⊕-combined tree (reduce mode only)
    /// plus work/timing/locality stats.
    WorkerDone {
        worker: usize,
        local_tree: Option<Vec<Edge>>,
        dist_evals: u64,
        busy: Duration,
        jobs_run: u32,
        /// pair jobs this worker claimed from another worker's affinity deck
        jobs_stolen: u32,
        /// subset-panel cache hits (bipartite-merge kernel only)
        panel_hits: u64,
        /// subset-panel cache misses (bipartite-merge kernel only)
        panel_misses: u64,
        /// distance-kernel floating-point ops spent in `panel_block` calls
        panel_flops: u64,
        /// wall time spent inside `panel_block` calls
        panel_time: Duration,
        /// max threads a single panel call fanned out to (0 = no panels ran)
        panel_threads: u32,
        /// [`crate::geometry::Isa`] wire code of the panel path (0 = none)
        panel_isa: u8,
        /// bytes this worker sent over peer links (tree ships + fold hops)
        peer_tx_bytes: u64,
        /// peer-plane frames this worker sent (fetch replies + fold ships)
        peer_ships: u32,
        /// telemetry spans recorded during the run, shipped only when the
        /// leader's [`crate::net::wire::Setup`] set the trace flag (empty
        /// otherwise, so trace-off byte models stay exact)
        spans: Vec<crate::obs::Span>,
        /// the worker's [`crate::obs::now_ns`] at send time — the leader
        /// re-bases shipped span timestamps onto its own clock with it
        now_ns: u64,
        /// chaos-transport faults this worker's link injected (0 outside
        /// chaos runs)
        chaos_faults: u32,
        /// final cumulative metrics snapshot, shipped only when the
        /// leader's [`crate::net::wire::Setup`] set the metrics flag
        /// (`None` otherwise, so metrics-off byte models stay exact)
        metrics: Option<crate::obs::metrics::Snapshot>,
    },
    /// Worker → leader: a periodic *cumulative* metrics snapshot for the
    /// leader's live fleet view (the `/metrics` exposition). Sent only when
    /// the [`crate::net::wire::Setup`] metrics flag armed it, rate-limited
    /// to the setup's push cadence; the leader absorbs it like a heartbeat
    /// — never acked, never a window credit — and latest-wins replaces the
    /// worker's previous snapshot.
    MetricsPush { worker: u16, snap: crate::obs::metrics::Snapshot },
    /// Either direction: header-only liveness keepalive. The leader
    /// multiplexes it over idle links so a worker's read deadline only
    /// trips when the link is truly dead or stalled; receivers skip it
    /// (never acked, never counted as a window credit).
    Heartbeat,
    /// Leader → worker: drain and report.
    Shutdown,
}

/// Wire bytes of a pair-job scatter shipping `ids` vectors of dimension `d`
/// (header + global-id map + vector payload). The pull-based exec scheduler
/// charges this without materializing a [`Message::Job`]; it delegates to
/// the same [`crate::net::wire`] size arithmetic the encoder uses, so the
/// two models cannot drift.
pub fn job_wire_bytes(ids: usize, d: usize) -> u64 {
    crate::net::wire::vectors_payload_bytes(ids, d) + HEADER_BYTES
}

impl Message {
    /// Bytes this message occupies on the wire: the exact length of its
    /// [`crate::net::wire`] encoding (header + payload).
    pub fn wire_bytes(&self) -> u64 {
        crate::net::wire::encoded_len(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_bytes_dominated_by_vectors() {
        let points = Dataset::zeros(100, 64);
        let msg = Message::Job {
            job: PairJob { id: 0, i: 0, j: 1 },
            global_ids: (0..100).collect(),
            points,
        };
        assert_eq!(msg.wire_bytes(), 16 + 400 + 100 * 64 * 4);
        assert_eq!(job_wire_bytes(100, 64), msg.wire_bytes(), "models agree");
    }

    #[test]
    fn result_bytes_linear_in_edges() {
        let edges = vec![Edge::new(0, 1, 1.0); 99];
        let msg = Message::Result { job_id: 3, worker: 0, edges, compute: Duration::ZERO };
        assert_eq!(msg.wire_bytes(), 16 + 99 * 12);
    }

    #[test]
    fn done_with_and_without_tree() {
        let a = Message::WorkerDone {
            worker: 0,
            local_tree: None,
            dist_evals: 10,
            busy: Duration::ZERO,
            jobs_run: 1,
            jobs_stolen: 0,
            panel_hits: 0,
            panel_misses: 0,
            panel_flops: 0,
            panel_time: Duration::ZERO,
            panel_threads: 0,
            panel_isa: 0,
            peer_tx_bytes: 0,
            peer_ships: 0,
            spans: vec![],
            now_ns: 0,
            chaos_faults: 0,
            metrics: None,
        };
        let b = Message::WorkerDone {
            worker: 0,
            local_tree: Some(vec![Edge::new(0, 1, 1.0); 5]),
            dist_evals: 10,
            busy: Duration::ZERO,
            jobs_run: 1,
            jobs_stolen: 2,
            panel_hits: 7,
            panel_misses: 3,
            panel_flops: 1 << 20,
            panel_time: Duration::from_micros(500),
            panel_threads: 4,
            panel_isa: 2,
            peer_tx_bytes: 4096,
            peer_ships: 3,
            spans: vec![crate::obs::Span::default(); 2],
            now_ns: 12345,
            chaos_faults: 1,
            metrics: None,
        };
        assert_eq!(a.wire_bytes(), 112, "header 16 + 96-byte stats block");
        assert_eq!(b.wire_bytes(), 112 + 2 * 32 + 60, "spans ride between stats and tree");
    }

    #[test]
    fn done_metrics_block_charges_its_exact_encoded_size() {
        let snap = crate::obs::metrics::Snapshot::default();
        let with = Message::WorkerDone {
            worker: 0,
            local_tree: None,
            dist_evals: 0,
            busy: Duration::ZERO,
            jobs_run: 0,
            jobs_stolen: 0,
            panel_hits: 0,
            panel_misses: 0,
            panel_flops: 0,
            panel_time: Duration::ZERO,
            panel_threads: 0,
            panel_isa: 0,
            peer_tx_bytes: 0,
            peer_ships: 0,
            spans: vec![],
            now_ns: 0,
            chaos_faults: 0,
            metrics: Some(snap.clone()),
        };
        assert_eq!(with.wire_bytes(), 112 + snap.wire_bytes(), "metrics ride after the spans");
        let push = Message::MetricsPush { worker: 3, snap: snap.clone() };
        assert_eq!(push.wire_bytes(), 16 + snap.wire_bytes());
    }

    #[test]
    fn local_job_matches_job_wire_model() {
        let msg = Message::LocalJob {
            part: 2,
            global_ids: (0..30).collect(),
            points: Dataset::zeros(30, 8),
        };
        assert_eq!(msg.wire_bytes(), job_wire_bytes(30, 8));
    }

    #[test]
    fn local_done_and_ack_sizes() {
        let done = Message::LocalDone {
            part: 1,
            edges: vec![Edge::new(0, 1, 1.0); 29],
            compute: Duration::ZERO,
        };
        assert_eq!(done.wire_bytes(), 16 + 29 * 12);
        assert_eq!(Message::Ack { job_id: 7 }.wire_bytes(), 16);
        assert_eq!(Message::LocalAssign { part: 3 }.wire_bytes(), 16);
        assert_eq!(Message::Heartbeat.wire_bytes(), 16, "keepalive is header-only");
        assert_eq!(Message::Shutdown.wire_bytes(), 16);
    }

    #[test]
    fn pair_assign_charges_only_whats_shipped() {
        // header only (everything resident)
        let bare = Message::PairAssign { job: PairJob { id: 0, i: 0, j: 1 }, ships: vec![] };
        assert_eq!(bare.wire_bytes(), 16);
        // one subset's vectors + tree
        let ship = SubsetShip {
            part: 1,
            vectors: Some(((0..10).collect(), Dataset::zeros(10, 4))),
            tree: Some(vec![Edge::new(0, 1, 1.0); 9]),
            routed: false,
        };
        // a peer-routed section charges nothing on the leader link
        let routed = SubsetShip { part: 1, vectors: None, tree: None, routed: true };
        let msg = Message::PairAssign {
            job: PairJob { id: 0, i: 0, j: 1 },
            ships: vec![routed],
        };
        assert_eq!(msg.wire_bytes(), 16);
        let msg = Message::PairAssign { job: PairJob { id: 0, i: 0, j: 1 }, ships: vec![ship] };
        assert_eq!(msg.wire_bytes(), 16 + (10 * 4 + 10 * 4 * 4) + 9 * 12);
    }
}
