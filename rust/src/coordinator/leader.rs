//! Leader front-end: a thin wrapper over the shared [`crate::exec`] engine.
//!
//! Everything that used to live here — the scatter deal, the worker loop,
//! the gather, the final sparse MST — is now the engine's single
//! implementation ([`crate::exec::execute_pooled`]), shared with the serial
//! reference path. This module keeps the distributed-run entry point, the
//! worker-count policy, and the [`DistOutput`] surface.

use crate::config::{RunConfig, TransportChoice};
use crate::coordinator::metrics::RunMetrics;
use crate::data::Dataset;
use crate::graph::Edge;
use crate::net::NetSim;

/// Output of a distributed run.
#[derive(Clone, Debug)]
pub struct DistOutput {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    pub metrics: RunMetrics,
    /// number of workers used
    pub workers: usize,
}

/// Resolve the worker count: explicit, else one per pair job capped at the
/// machine's parallelism.
pub fn resolve_workers(cfg: &RunConfig) -> usize {
    crate::exec::resolve_workers(cfg)
}

/// Run the paper's Algorithm 1 distributed: rank workers pulling jobs from
/// the cost-LPT queue, gather (default) or local-⊕ + tree reduction
/// (`cfg.reduce_tree`), optionally folding arriving trees into a bounded
/// running MSF (`cfg.stream_reduce`). Under `transport = sim` (default)
/// ranks are threads over the byte-modeled [`NetSim`]; under
/// `transport = tcp` the identical engine drives remote `demst worker`
/// processes over real sockets ([`crate::net::launch`]), with the byte
/// counters fed by actual encoded frames. Returns the exact MSF plus
/// measured metrics.
pub fn run_distributed(ds: &Dataset, cfg: &RunConfig) -> anyhow::Result<DistOutput> {
    anyhow::ensure!(
        cfg.shard_manifest.is_none(),
        "run_distributed takes a leader-resident dataset; sharded runs go through run_sharded"
    );
    let run = match cfg.transport {
        TransportChoice::Sim => {
            let net = NetSim::new(cfg.net.clone());
            crate::exec::execute_pooled(ds, cfg, &net)?
        }
        TransportChoice::Tcp => crate::net::launch::run_leader(ds, cfg)?,
    };
    Ok(DistOutput { mst: run.mst, metrics: run.metrics, workers: run.workers })
}

/// Run a **sharded** distributed EMST: the leader plans from
/// `cfg.shard_manifest` alone and never materializes subset vectors — the
/// worker fleet loads them from local shard files
/// (`demst worker --shard ... --shard-ids ...`). Always `transport = tcp`.
pub fn run_sharded(cfg: &RunConfig) -> anyhow::Result<DistOutput> {
    let run = crate::net::launch::run_leader_sharded(cfg)?;
    Ok(DistOutput { mst: run.mst, metrics: run.metrics, workers: run.workers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{KernelChoice, RunConfig};
    use crate::data::generators::{gaussian_blobs, uniform, BlobSpec};
    use crate::decomp::{decomposed_mst, DecompConfig};
    use crate::dense::PrimDense;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    fn base_cfg(parts: usize, workers: usize) -> RunConfig {
        RunConfig {
            parts,
            workers,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        }
    }

    #[test]
    fn distributed_matches_serial_reference() {
        let ds = uniform(90, 6, 1.0, Pcg64::seeded(600));
        let cfg = base_cfg(5, 3);
        let dist = run_distributed(&ds, &cfg).unwrap();
        let serial = decomposed_mst(
            &ds,
            &DecompConfig {
                parts: cfg.parts,
                strategy: cfg.strategy,
                seed: cfg.seed,
                keep_pair_trees: false,
            },
            &PrimDense::sq_euclid(),
        );
        assert_eq!(normalize_tree(&serial.mst), normalize_tree(&dist.mst));
        assert!(is_spanning_tree(ds.n, &dist.mst));
        assert_eq!(dist.metrics.dist_evals, serial.dist_evals);
        assert_eq!(dist.metrics.jobs, 10);
    }

    #[test]
    fn reduce_tree_mode_same_result_less_gather() {
        let ds = gaussian_blobs(
            &BlobSpec { n: 120, d: 8, k: 6, std: 0.4, spread: 6.0 },
            Pcg64::seeded(601),
        );
        let mut cfg = base_cfg(6, 4);
        let gather = run_distributed(&ds, &cfg).unwrap();
        cfg.reduce_tree = true;
        let reduced = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(normalize_tree(&gather.mst), normalize_tree(&reduced.mst));
        assert!(
            reduced.metrics.gather_bytes < gather.metrics.gather_bytes,
            "reduce {} !< gather {}",
            reduced.metrics.gather_bytes,
            gather.metrics.gather_bytes
        );
        assert_eq!(gather.metrics.jobs, reduced.metrics.jobs);
    }

    #[test]
    fn single_part_degenerate() {
        let ds = uniform(30, 4, 1.0, Pcg64::seeded(602));
        let cfg = base_cfg(1, 1);
        let out = run_distributed(&ds, &cfg).unwrap();
        let expect = PrimDense::sq_euclid().mst(&ds);
        use crate::dense::DenseMst;
        assert_eq!(normalize_tree(&expect), normalize_tree(&out.mst));
    }

    #[test]
    fn worker_counts_do_not_change_result() {
        let ds = uniform(72, 5, 1.0, Pcg64::seeded(603));
        let expect = run_distributed(&ds, &base_cfg(4, 1)).unwrap();
        for workers in [2usize, 3, 6] {
            let got = run_distributed(&ds, &base_cfg(4, workers)).unwrap();
            assert_eq!(
                normalize_tree(&expect.mst),
                normalize_tree(&got.mst),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn scatter_bytes_match_model() {
        // Dense byte model (affinity routing off): scatter = sum over jobs
        // of header + ids*4 + vectors*4*d, with |S_i ∪ S_j| = 2n/|P|.
        let n = 96usize;
        let d = 7usize;
        let ds = uniform(n, d, 1.0, Pcg64::seeded(604));
        let mut cfg = base_cfg(4, 2);
        cfg.strategy = crate::decomp::PartitionStrategy::Block;
        cfg.affinity = false;
        let out = run_distributed(&ds, &cfg).unwrap();
        let m = 2 * n / 4;
        let per_job = 16 + m as u64 * 4 + (m * d) as u64 * 4;
        assert_eq!(out.metrics.scatter_bytes, 6 * per_job);
    }

    #[test]
    fn affinity_routing_ships_fewer_scatter_bytes() {
        // Default (affinity on) vs the dense model: same tree, strictly
        // fewer bytes for parts >= 4 with few workers, and the saved
        // counter accounts for the difference exactly.
        let ds = uniform(96, 7, 1.0, Pcg64::seeded(605));
        let mut cfg = base_cfg(4, 2);
        cfg.affinity = false;
        let dense = run_distributed(&ds, &cfg).unwrap();
        cfg.affinity = true;
        let aff = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(normalize_tree(&dense.mst), normalize_tree(&aff.mst));
        assert!(
            aff.metrics.scatter_bytes < dense.metrics.scatter_bytes,
            "affinity {} !< dense {}",
            aff.metrics.scatter_bytes,
            dense.metrics.scatter_bytes
        );
        assert_eq!(
            aff.metrics.scatter_bytes + aff.metrics.scatter_saved_bytes,
            dense.metrics.scatter_bytes
        );
    }

    #[test]
    fn resolve_workers_caps_at_jobs() {
        let mut cfg = base_cfg(3, 100); // 3 pair jobs
        assert_eq!(resolve_workers(&cfg), 3);
        cfg.workers = 2;
        assert_eq!(resolve_workers(&cfg), 2);
        cfg.parts = 1;
        cfg.workers = 5;
        assert_eq!(resolve_workers(&cfg), 1);
    }
}
