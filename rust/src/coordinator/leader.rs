//! Leader: partition → scatter jobs → gather/reduce → final sparse MST.

use super::messages::Message;
use super::metrics::RunMetrics;
use super::netsim::{Direction, NetSim};
use super::worker::worker_main;
use crate::config::RunConfig;
use crate::data::Dataset;
use crate::decomp::reduction::reduce_trees;
use crate::decomp::{pair_count, partition_indices, PairSchedule};
use crate::graph::Edge;
use crate::mst::kruskal;
use std::sync::mpsc::channel;
use std::time::Instant;

/// Output of a distributed run.
#[derive(Clone, Debug)]
pub struct DistOutput {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    pub metrics: RunMetrics,
    /// number of workers used
    pub workers: usize,
}

/// Resolve the worker count: explicit, else one per pair job capped at the
/// machine's parallelism.
pub fn resolve_workers(cfg: &RunConfig) -> usize {
    let jobs = pair_count(cfg.parts).max(1);
    if cfg.workers > 0 {
        cfg.workers.min(jobs)
    } else {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        jobs.min(cores)
    }
}

/// Run the paper's Algorithm 1 distributed: thread-per-rank workers, jobs
/// dealt round-robin, gather (default) or local-⊕ + tree reduction
/// (`cfg.reduce_tree`). Returns the exact MSF plus measured metrics.
pub fn run_distributed(ds: &Dataset, cfg: &RunConfig) -> anyhow::Result<DistOutput> {
    let t_start = Instant::now();
    let parts = partition_indices(ds, cfg.parts, cfg.strategy, cfg.seed);
    let schedule = PairSchedule::new(cfg.parts);
    let n_workers = resolve_workers(cfg);
    let net = NetSim::new(cfg.net.clone());
    let counters = net.counters();

    let (tx_leader, rx_leader) = channel::<Message>();
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut worker_trees: Vec<Vec<Edge>> = Vec::new();
    let mut metrics = RunMetrics::default();
    metrics.worker_busy = vec![std::time::Duration::ZERO; n_workers];
    metrics.kernel = crate::runtime::resolved_kernel_name(cfg).to_string();
    metrics.kernel_fallback = crate::runtime::kernel_fallback_note(cfg);

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // Spawn workers.
        let mut to_workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx_w, rx_w) = channel::<Message>();
            to_workers.push(tx_w);
            let tx_leader = tx_leader.clone();
            let net = net.clone();
            let cfg_ref = &*cfg;
            let n_global = ds.n;
            scope.spawn(move || {
                worker_main(w, n_global, cfg_ref, &net, rx_w, tx_leader, cfg_ref.reduce_tree);
            });
        }
        drop(tx_leader); // leader keeps only rx

        // Scatter: deal jobs round-robin. Each job ships S_i ∪ S_j vectors.
        if cfg.parts == 1 {
            // Degenerate: single subset, one "pair" job of the whole set.
            let ids: Vec<u32> = parts[0].clone();
            let points = ds.gather(&ids);
            net.send(
                &to_workers[0],
                Message::Job {
                    job: crate::decomp::PairJob { id: 0, i: 0, j: 0 },
                    global_ids: ids,
                    points,
                },
                Direction::Scatter,
            )
            .map_err(|_| anyhow::anyhow!("worker 0 hung up during scatter"))?;
        } else {
            for job in &schedule.jobs {
                let si = &parts[job.i as usize];
                let sj = &parts[job.j as usize];
                // sorted union: keeps local tie-breaks aligned with the
                // global strict edge order (see decomp::algorithm::run_pair)
                let ids = crate::decomp::algorithm::merge_sorted_ids(si, sj);
                let points = ds.gather(&ids);
                let w = (job.id as usize) % n_workers;
                net.send(
                    &to_workers[w],
                    Message::Job { job: *job, global_ids: ids, points },
                    Direction::Scatter,
                )
                .map_err(|_| anyhow::anyhow!("worker {w} hung up during scatter"))?;
            }
        }
        for tx in &to_workers {
            let _ = net.send(tx, Message::Shutdown, Direction::Control);
        }

        // Gather.
        let mut done = 0usize;
        while done < n_workers {
            let msg = rx_leader.recv().expect("all workers hung up");
            match msg {
                Message::Result { edges, compute, .. } => {
                    metrics.jobs += 1;
                    metrics.job_times.push(compute);
                    union_edges.extend_from_slice(&edges);
                }
                Message::WorkerDone { worker, local_tree, dist_evals, busy, jobs_run } => {
                    metrics.dist_evals += dist_evals;
                    metrics.worker_busy[worker] = busy;
                    if cfg.reduce_tree {
                        metrics.jobs += jobs_run;
                    }
                    if let Some(t) = local_tree {
                        worker_trees.push(t);
                    }
                    done += 1;
                }
                other => anyhow::bail!("leader received unexpected message {other:?}"),
            }
        }
        Ok(())
    })?;

    let expected_jobs = if cfg.parts == 1 { 1 } else { schedule.len() as u32 };
    if metrics.jobs != expected_jobs {
        anyhow::bail!(
            "job count mismatch: expected {expected_jobs}, completed {} (worker failure?)",
            metrics.jobs
        );
    }

    // Final sparse MST. (Perf note: deduplicating (u,v) pairs first was
    // tried and reverted — dedup itself sorts the full union, so it only
    // adds work; Kruskal handles parallel edges natively and the whole step
    // is < 10 ms at E8 scale.)
    let t_mst = Instant::now();
    let mst = if cfg.reduce_tree {
        // Workers already ⊕-combined locally; finish the reduction tree at
        // the leader (the inter-worker hops were charged on WorkerDone).
        let (tree, _stats) = reduce_trees(ds.n, &worker_trees);
        tree
    } else {
        kruskal(ds.n, &union_edges)
    };
    metrics.union_edges = if cfg.reduce_tree {
        worker_trees.iter().map(|t| t.len()).sum()
    } else {
        union_edges.len()
    };
    metrics.final_mst = t_mst.elapsed();

    let (s, g, c, m) = counters.snapshot();
    metrics.scatter_bytes = s;
    metrics.gather_bytes = g;
    metrics.control_bytes = c;
    metrics.messages = m;
    metrics.wall = t_start.elapsed();

    Ok(DistOutput { mst, metrics, workers: n_workers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{KernelChoice, RunConfig};
    use crate::data::generators::{gaussian_blobs, uniform, BlobSpec};
    use crate::decomp::{decomposed_mst, DecompConfig};
    use crate::dense::PrimDense;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    fn base_cfg(parts: usize, workers: usize) -> RunConfig {
        RunConfig {
            parts,
            workers,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        }
    }

    #[test]
    fn distributed_matches_serial_reference() {
        let ds = uniform(90, 6, 1.0, Pcg64::seeded(600));
        let cfg = base_cfg(5, 3);
        let dist = run_distributed(&ds, &cfg).unwrap();
        let serial = decomposed_mst(
            &ds,
            &DecompConfig {
                parts: cfg.parts,
                strategy: cfg.strategy,
                seed: cfg.seed,
                keep_pair_trees: false,
            },
            &PrimDense::sq_euclid(),
        );
        assert_eq!(normalize_tree(&serial.mst), normalize_tree(&dist.mst));
        assert!(is_spanning_tree(ds.n, &dist.mst));
        assert_eq!(dist.metrics.dist_evals, serial.dist_evals);
        assert_eq!(dist.metrics.jobs, 10);
    }

    #[test]
    fn reduce_tree_mode_same_result_less_gather() {
        let ds = gaussian_blobs(
            &BlobSpec { n: 120, d: 8, k: 6, std: 0.4, spread: 6.0 },
            Pcg64::seeded(601),
        );
        let mut cfg = base_cfg(6, 4);
        let gather = run_distributed(&ds, &cfg).unwrap();
        cfg.reduce_tree = true;
        let reduced = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(normalize_tree(&gather.mst), normalize_tree(&reduced.mst));
        assert!(
            reduced.metrics.gather_bytes < gather.metrics.gather_bytes,
            "reduce {} !< gather {}",
            reduced.metrics.gather_bytes,
            gather.metrics.gather_bytes
        );
        assert_eq!(gather.metrics.jobs, reduced.metrics.jobs);
    }

    #[test]
    fn single_part_degenerate() {
        let ds = uniform(30, 4, 1.0, Pcg64::seeded(602));
        let cfg = base_cfg(1, 1);
        let out = run_distributed(&ds, &cfg).unwrap();
        let expect = PrimDense::sq_euclid().mst(&ds);
        use crate::dense::DenseMst;
        assert_eq!(normalize_tree(&expect), normalize_tree(&out.mst));
    }

    #[test]
    fn worker_counts_do_not_change_result() {
        let ds = uniform(72, 5, 1.0, Pcg64::seeded(603));
        let expect = run_distributed(&ds, &base_cfg(4, 1)).unwrap();
        for workers in [2usize, 3, 6] {
            let got = run_distributed(&ds, &base_cfg(4, workers)).unwrap();
            assert_eq!(
                normalize_tree(&expect.mst),
                normalize_tree(&got.mst),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn scatter_bytes_match_model() {
        // Block partition, even sizes: scatter = sum over jobs of
        // header + ids*4 + vectors*4*d, with |S_i ∪ S_j| = 2n/|P|.
        let n = 96usize;
        let d = 7usize;
        let ds = uniform(n, d, 1.0, Pcg64::seeded(604));
        let mut cfg = base_cfg(4, 2);
        cfg.strategy = crate::decomp::PartitionStrategy::Block;
        let out = run_distributed(&ds, &cfg).unwrap();
        let m = 2 * n / 4;
        let per_job = 16 + m as u64 * 4 + (m * d) as u64 * 4;
        assert_eq!(out.metrics.scatter_bytes, 6 * per_job);
    }

    #[test]
    fn resolve_workers_caps_at_jobs() {
        let mut cfg = base_cfg(3, 100); // 3 pair jobs
        assert_eq!(resolve_workers(&cfg), 3);
        cfg.workers = 2;
        assert_eq!(resolve_workers(&cfg), 2);
        cfg.parts = 1;
        cfg.workers = 5;
        assert_eq!(resolve_workers(&cfg), 1);
    }
}
