//! Run metrics: what the leader reports after a distributed run.

use std::time::Duration;

/// Aggregated metrics of one distributed EMST run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// wallclock of the whole run
    pub wall: Duration,
    /// bytes scattered (vector payloads to workers)
    pub scatter_bytes: u64,
    /// bytes gathered (tree edges back to the leader)
    pub gather_bytes: u64,
    /// control-plane bytes
    pub control_bytes: u64,
    /// total messages
    pub messages: u64,
    /// d-MST kernel distance evaluations, summed over workers
    pub dist_evals: u64,
    /// pair jobs executed
    pub jobs: u32,
    /// per-worker busy time (kernel compute, excluding idle/recv)
    pub worker_busy: Vec<Duration>,
    /// edges in the gathered union before the final sparse MST
    pub union_edges: usize,
    /// time the leader spent in the final sparse MST
    pub final_mst: Duration,
    /// per-job kernel compute times (gather mode), in completion order
    pub job_times: Vec<Duration>,
    /// d-MST kernel the workers actually ran (after backend resolution)
    pub kernel: String,
    /// set when the requested kernel was unavailable in this build and the
    /// backend resolver substituted another (e.g. `boruvka-xla` without
    /// `--features backend-xla`)
    pub kernel_fallback: Option<String>,
    /// pair-job kernel the exec engine ran ("dense" | "bipartite-merge")
    pub pair_kernel: String,
    /// whether the leader folded trees into a running MSF as they arrived
    pub stream_reduce: bool,
    /// which transport carried the run's bytes: "sim" (modeled charges) or
    /// "tcp" (counters fed by actual encoded frames on the sockets)
    pub transport: String,
    /// wall time of the local-MST phase (bipartite-merge kernel only)
    pub phase_local_mst: Duration,
    /// wall time of the pair-job phase (scatter → solve → gather)
    pub phase_pair: Duration,
    /// leader time spent ⊕-reducing / final sparse MST (streaming merges +
    /// the final pass)
    pub phase_reduce: Duration,
    /// distance evaluations spent building the local-MST cache
    /// (`Σ_k |S_k|(|S_k|-1)/2`; zero for the dense pair kernel)
    pub local_mst_evals: u64,
    /// distance evaluations spent inside pair jobs (the bipartite blocks
    /// for the merge kernel; everything for the dense kernel)
    pub pair_evals: u64,
    /// scatter bytes the subset-affinity resident-set model avoided shipping
    /// versus the dense `S_i ∪ S_j`-per-job model (0 with affinity off:
    /// the dense model is then charged byte-for-byte)
    pub scatter_saved_bytes: u64,
    /// pair jobs a worker claimed from another worker's affinity deck
    pub jobs_stolen: u32,
    /// subset-panel cache hits across workers (bipartite-merge kernel)
    pub panel_hits: u64,
    /// subset-panel cache misses across workers (bipartite-merge kernel)
    pub panel_misses: u64,
    /// streaming ⊕-folds performed at the leader (`stream_reduce` only)
    pub reduce_folds: u32,
    /// total edges scanned by the streaming merge-join folds — bounded by
    /// `reduce_folds · 2(|V|-1)`, the no-full-re-sort witness
    pub reduce_fold_edges: u64,
    /// max pair jobs in flight per worker link before the leader awaits a
    /// reply (1 = strict rendezvous; sim runs report 1)
    pub pipeline_window: u32,
    /// whether the run was sharded: the plan came from a shard manifest
    /// and the leader never held subset vectors
    pub sharded: bool,
    /// vector-section bytes that passed through the leader (scattered
    /// subset payloads, modeled or real) — the leader-bottleneck witness,
    /// **0 by construction on a sharded run**
    pub leader_ingest_bytes: u64,
    /// vector payload the worker fleet loaded from local shard files
    /// (summed per resident copy); 0 on unsharded runs
    pub shard_local_bytes: u64,
    /// worker links that died mid-run and were failed over
    pub worker_failures: u32,
    /// pair jobs returned to the deck by a failed worker and re-run on the
    /// surviving fleet (each still recorded exactly once at the leader)
    pub jobs_reassigned: u32,
    /// SIMD ISA label of the panel kernels ("scalar" | "avx2" | "neon");
    /// remote workers report theirs over the wire and override this. Empty
    /// when the bipartite panel path did not run.
    pub panel_isa: String,
    /// SIMD lane width of the panel kernels (1 for scalar)
    pub panel_lanes: u32,
    /// why the panel path fell back to scalar, when it did (config off,
    /// env off, ISA not detected) — mirrors `kernel_fallback`
    pub panel_fallback: Option<String>,
    /// distance-kernel floating-point ops inside `panel_block`, summed
    /// over workers
    pub panel_flops: u64,
    /// wall time inside `panel_block`, summed over workers
    pub panel_time: Duration,
    /// max threads a single panel call fanned out to across the fleet
    pub panel_threads_used: u32,
    /// leader-link bytes that were *control*: frame headers, directives,
    /// and gathered results — `scatter + gather + control − leader_data`
    pub leader_control_bytes: u64,
    /// leader-link bytes that were scatter-direction *data payload*
    /// (vectors + inline trees beyond frame headers) — **0 by
    /// construction on sharded peer-routed runs**, the leaderless
    /// data-plane witness
    pub leader_data_bytes: u64,
    /// worker↔worker bytes that never crossed the leader: routed tree
    /// fetches and ⊕-fold ships (worker-measured on TCP, modeled on the
    /// simulated fabric — exactly one source is ever nonzero)
    pub peer_bytes: u64,
    /// trees shipped over peer links (`TreeShip` frames), fleet-wide
    pub peer_ships: u32,
    /// where the ⊕-reduction folded: "leader" | "tree" | "ring"
    pub reduce_topology: String,
    /// whether the peer data plane routed tree fetches this run
    pub peer_route: bool,
    /// links demoted because they blew the liveness deadline without dying
    /// — a subset of `worker_failures` (stall ⊂ failure)
    pub stalls_detected: u32,
    /// header-only Heartbeat frames the leader pulsed over idle links
    pub heartbeats_sent: u64,
    /// workers admitted mid-run via the Join/AdmitAck handshake and
    /// activated by the engine (late joins that never got a deck don't
    /// count — they are farewelled with a Shutdown instead)
    pub workers_admitted: u32,
    /// faults the deterministic chaos transport actually fired this run
    /// (worker-counted, shipped back on `WorkerDone`) — 0 outside
    /// chaos-smoke runs
    pub chaos_faults_injected: u64,
    /// the reassembled fleet-wide span timeline (empty unless the run
    /// recorded with `[obs] trace`/`--trace-out`): worker spans arrive
    /// piggybacked on `WorkerDone` and are re-based onto the leader's
    /// clock; leader/in-process spans drain from the thread recorders
    pub spans: Vec<crate::obs::Span>,
    /// the fleet-merged metrics snapshot at run end (counters, gauges, and
    /// mergeable histograms): the leader's own registry ⊕ every worker's
    /// final `WorkerDone` block. Always present after a pooled run —
    /// recording is unconditional; only wire shipping is config-gated
    pub fleet_metrics: Option<crate::obs::metrics::Snapshot>,
    /// how many remote workers shipped at least one metrics snapshot
    pub metrics_workers_reporting: u32,
}

impl RunMetrics {
    /// Parallel efficiency proxy: mean worker busy time / wall.
    pub fn busy_efficiency(&self) -> f64 {
        if self.worker_busy.is_empty() || self.wall.is_zero() {
            return 0.0;
        }
        let mean: f64 = self.worker_busy.iter().map(|d| d.as_secs_f64()).sum::<f64>()
            / self.worker_busy.len() as f64;
        mean / self.wall.as_secs_f64()
    }

    /// Load imbalance: max busy / mean busy (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.worker_busy.is_empty() {
            return 1.0;
        }
        let mean: f64 = self.worker_busy.iter().map(|d| d.as_secs_f64()).sum::<f64>()
            / self.worker_busy.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        let max = self.worker_busy.iter().map(|d| d.as_secs_f64()).fold(0.0, f64::max);
        max / mean
    }

    /// Modeled makespan for `workers` ranks under LPT (longest-processing-
    /// time-first) scheduling of the measured per-job compute times.
    ///
    /// Why modeled: the paper's setting is `p = |P|(|P|-1)/2` distributed
    /// ranks; this testbed may have fewer cores than ranks (possibly one),
    /// so thread wallclock under-reports the achievable speedup. LPT over
    /// per-job times models the distributed schedule (E4); communication is
    /// charged separately from the byte counters + the netsim link model.
    ///
    /// IMPORTANT: job times are `Instant` wall times measured inside the
    /// worker, so they are only oversubscription-free when the run used
    /// `workers <= cores` — collect them from a `workers = 1` run (as the
    /// E4/E8 drivers do) before modeling larger rank counts.
    pub fn modeled_makespan(&self, workers: usize) -> Duration {
        assert!(workers >= 1);
        let mut jobs: Vec<Duration> = self.job_times.clone();
        jobs.sort_unstable_by(|a, b| b.cmp(a));
        let mut loads = vec![Duration::ZERO; workers];
        for j in jobs {
            // assign to least-loaded worker
            let w = loads
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| **l)
                .map(|(i, _)| i)
                .unwrap();
            loads[w] += j;
        }
        loads.into_iter().max().unwrap_or(Duration::ZERO)
    }

    /// Total kernel compute across all jobs.
    pub fn total_compute(&self) -> Duration {
        self.job_times.iter().sum()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        use crate::util::{human_bytes, human_count};
        let mut s = format!(
            "wall={:?} jobs={} dist_evals={} scatter={} gather={} msgs={} union_edges={} eff={:.2} imb={:.2}",
            self.wall,
            self.jobs,
            human_count(self.dist_evals),
            human_bytes(self.scatter_bytes),
            human_bytes(self.gather_bytes),
            self.messages,
            self.union_edges,
            self.busy_efficiency(),
            self.imbalance(),
        );
        if !self.kernel.is_empty() {
            s.push_str(&format!(" kernel={}", self.kernel));
        }
        if !self.pair_kernel.is_empty() {
            s.push_str(&format!(" pair_kernel={}", self.pair_kernel));
        }
        if self.stream_reduce {
            s.push_str(" stream_reduce");
        }
        if !self.transport.is_empty() {
            s.push_str(&format!(" transport={}", self.transport));
        }
        if self.pipeline_window > 1 {
            s.push_str(&format!(" window={}", self.pipeline_window));
        }
        if self.sharded {
            s.push_str(" sharded");
        }
        if matches!(self.reduce_topology.as_str(), "tree" | "ring") {
            s.push_str(&format!(" topology={}", self.reduce_topology));
        }
        if self.peer_route {
            s.push_str(" peer_route");
        }
        if self.worker_failures > 0 {
            s.push_str(&format!(
                " failures={} reassigned={}",
                self.worker_failures, self.jobs_reassigned
            ));
        }
        if self.stalls_detected > 0 {
            s.push_str(&format!(" stalls={}", self.stalls_detected));
        }
        if self.workers_admitted > 0 {
            s.push_str(&format!(" admitted={}", self.workers_admitted));
        }
        if self.heartbeats_sent > 0 {
            s.push_str(&format!(" heartbeats={}", self.heartbeats_sent));
        }
        if self.chaos_faults_injected > 0 {
            s.push_str(&format!(" chaos_faults={}", self.chaos_faults_injected));
        }
        if let Some(note) = &self.kernel_fallback {
            s.push_str(&format!(" (fallback: {note})"));
        }
        s
    }

    /// Sharding line: where the vector payload actually lived. Empty on
    /// unsharded runs.
    pub fn sharding_summary(&self) -> String {
        use crate::util::human_bytes;
        if !self.sharded {
            return String::new();
        }
        format!(
            "leader_ingest={} shard_local={}",
            human_bytes(self.leader_ingest_bytes),
            human_bytes(self.shard_local_bytes)
        )
    }

    /// Fraction of panel-cache probes that hit (0.0 when the bipartite
    /// kernel did not run).
    pub fn panel_hit_rate(&self) -> f64 {
        let probes = self.panel_hits + self.panel_misses;
        if probes == 0 {
            0.0
        } else {
            self.panel_hits as f64 / probes as f64
        }
    }

    /// Locality line: affinity scatter savings, panel-cache hit rate, deck
    /// steals, and streaming-fold cost. Empty string when nothing applies
    /// (dense scatter model, dense pair kernel, no streaming).
    pub fn locality_summary(&self) -> String {
        use crate::util::human_bytes;
        let mut parts: Vec<String> = Vec::new();
        if self.scatter_saved_bytes > 0 {
            parts.push(format!("scatter_saved={}", human_bytes(self.scatter_saved_bytes)));
        }
        let probes = self.panel_hits + self.panel_misses;
        if probes > 0 {
            parts.push(format!(
                "panel_cache={}/{} hits ({:.0}%)",
                self.panel_hits,
                probes,
                100.0 * self.panel_hit_rate()
            ));
        }
        if self.jobs_stolen > 0 {
            parts.push(format!("stolen={}", self.jobs_stolen));
        }
        if self.reduce_folds > 0 {
            parts.push(format!(
                "folds={} fold_edges={}",
                self.reduce_folds, self.reduce_fold_edges
            ));
        }
        if self.data_plane_active() {
            parts.push(format!(
                "leader_control={} leader_data={} peer={}",
                human_bytes(self.leader_control_bytes),
                human_bytes(self.leader_data_bytes),
                human_bytes(self.peer_bytes)
            ));
            if self.peer_ships > 0 {
                parts.push(format!("peer_ships={}", self.peer_ships));
            }
        }
        parts.join(" ")
    }

    /// Whether the leaderless data plane did anything this run: peer
    /// routing was on, a tree/ring reduction ran, or peer bytes moved.
    pub fn data_plane_active(&self) -> bool {
        self.peer_route
            || self.peer_bytes > 0
            || matches!(self.reduce_topology.as_str(), "tree" | "ring")
    }

    /// Aggregate panel-kernel throughput in GFLOP/s (0.0 when no panel
    /// time was measured). Summed flops over summed wall time — a fleet
    /// average, not a single-core peak.
    pub fn panel_gflops(&self) -> f64 {
        let secs = self.panel_time.as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.panel_flops as f64 / secs / 1e9
        }
    }

    /// Kernel line: which SIMD path the bipartite panel kernels ran, their
    /// lane width, thread fan-out, and measured throughput. Empty when the
    /// panel path never ran (dense pair kernel, or no bipartite blocks).
    pub fn kernel_summary(&self) -> String {
        if self.panel_isa.is_empty() {
            return String::new();
        }
        let mut s = format!("isa={} lanes={}", self.panel_isa, self.panel_lanes);
        if self.panel_threads_used > 0 {
            s.push_str(&format!(" threads={}", self.panel_threads_used));
        }
        if self.panel_time > Duration::ZERO {
            s.push_str(&format!(" panel_gflops={:.2}", self.panel_gflops()));
        }
        if let Some(note) = &self.panel_fallback {
            s.push_str(&format!(" (fallback: {note})"));
        }
        s
    }

    /// Grow `worker_busy` to the *final* fleet size: the startup ranks
    /// plus every worker admitted mid-run. Some paths (a worker admitted
    /// after its deck drained, or admitted and immediately idle) never
    /// touch the admitted rank's busy slot, so the per-worker report would
    /// silently omit it — the roster printed by `demst run` must be the
    /// fleet that finished the run, not the one that started it.
    pub fn finalize_roster(&mut self, n_start: usize) {
        let roster = n_start + self.workers_admitted as usize;
        if self.worker_busy.len() < roster {
            self.worker_busy.resize(roster, Duration::ZERO);
        }
    }

    /// Per-phase breakdown (local-MST / pair / reduce timing and eval
    /// split) — the measurement surface for the bipartite-merge kernel.
    pub fn phase_summary(&self) -> String {
        use crate::util::human_count;
        format!(
            "local_mst={:?} ({} evals) pairs={:?} ({} evals) reduce={:?}",
            self.phase_local_mst,
            human_count(self.local_mst_evals),
            self.phase_pair,
            human_count(self.pair_evals),
            self.phase_reduce,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_and_imbalance() {
        let m = RunMetrics {
            wall: Duration::from_secs(2),
            worker_busy: vec![Duration::from_secs(1), Duration::from_secs(2)],
            ..Default::default()
        };
        assert!((m.busy_efficiency() - 0.75).abs() < 1e-9);
        assert!((m.imbalance() - 2.0 / 1.5).abs() < 1e-9);
    }

    #[test]
    fn makespan_lpt_model() {
        let m = RunMetrics {
            job_times: vec![
                Duration::from_secs(4),
                Duration::from_secs(3),
                Duration::from_secs(3),
                Duration::from_secs(2),
            ],
            ..Default::default()
        };
        assert_eq!(m.modeled_makespan(1), Duration::from_secs(12));
        // LPT with 2 workers: [4,2] vs [3,3] -> 6
        assert_eq!(m.modeled_makespan(2), Duration::from_secs(6));
        assert_eq!(m.modeled_makespan(4), Duration::from_secs(4));
        assert_eq!(m.modeled_makespan(100), Duration::from_secs(4));
        assert_eq!(m.total_compute(), Duration::from_secs(12));
    }

    #[test]
    fn degenerate_cases() {
        let m = RunMetrics::default();
        assert_eq!(m.busy_efficiency(), 0.0);
        assert_eq!(m.imbalance(), 1.0);
        assert!(m.summary().contains("jobs=0"));
        assert!(!m.summary().contains("kernel="), "empty kernel omitted");
    }

    #[test]
    fn summary_and_phase_breakdown_report_pair_kernel() {
        let m = RunMetrics {
            pair_kernel: "bipartite-merge".into(),
            stream_reduce: true,
            transport: "tcp".into(),
            local_mst_evals: 1200,
            pair_evals: 3400,
            pipeline_window: 2,
            sharded: true,
            worker_failures: 1,
            jobs_reassigned: 3,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("pair_kernel=bipartite-merge"), "{s}");
        assert!(s.contains("stream_reduce"), "{s}");
        assert!(s.contains("transport=tcp"), "{s}");
        assert!(s.contains("window=2"), "{s}");
        assert!(s.contains("sharded"), "{s}");
        assert!(s.contains("failures=1 reassigned=3"), "{s}");
        let p = m.phase_summary();
        assert!(p.contains("local_mst="), "{p}");
        assert!(p.contains("1.20K evals"), "{p}");
    }

    #[test]
    fn summary_reports_liveness_counters_only_when_nonzero() {
        let quiet = RunMetrics::default().summary();
        assert!(!quiet.contains("stalls="), "{quiet}");
        assert!(!quiet.contains("admitted="), "{quiet}");
        assert!(!quiet.contains("heartbeats="), "{quiet}");
        let m = RunMetrics {
            worker_failures: 2,
            jobs_reassigned: 5,
            stalls_detected: 1,
            workers_admitted: 1,
            heartbeats_sent: 12,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("failures=2 reassigned=5"), "{s}");
        assert!(s.contains("stalls=1"), "{s}");
        assert!(s.contains("admitted=1"), "{s}");
        assert!(s.contains("heartbeats=12"), "{s}");
    }

    #[test]
    fn summary_reports_chaos_faults_only_when_injected() {
        assert!(!RunMetrics::default().summary().contains("chaos_faults="));
        let m = RunMetrics { chaos_faults_injected: 3, ..Default::default() };
        assert!(m.summary().contains("chaos_faults=3"), "{}", m.summary());
    }

    #[test]
    fn finalize_roster_covers_workers_admitted_mid_run() {
        // 2 startup ranks, 1 admitted mid-run that never logged busy time:
        // the printed roster must still have 3 slots.
        let mut m = RunMetrics {
            worker_busy: vec![Duration::from_secs(1), Duration::from_secs(2)],
            workers_admitted: 1,
            ..Default::default()
        };
        m.finalize_roster(2);
        assert_eq!(m.worker_busy.len(), 3);
        assert_eq!(m.worker_busy[2], Duration::ZERO);
        // Already-sized rosters (the admission path that did resize) are
        // left alone — no truncation, no double-extend.
        let mut sized = RunMetrics {
            worker_busy: vec![Duration::from_secs(1); 4],
            workers_admitted: 1,
            ..Default::default()
        };
        sized.finalize_roster(3);
        assert_eq!(sized.worker_busy.len(), 4);
        sized.finalize_roster(2);
        assert_eq!(sized.worker_busy.len(), 4, "never shrink a measured roster");
    }

    #[test]
    fn sharding_summary_reports_payload_residency() {
        assert_eq!(RunMetrics::default().sharding_summary(), "");
        let m = RunMetrics {
            sharded: true,
            leader_ingest_bytes: 0,
            shard_local_bytes: 4096,
            ..Default::default()
        };
        let s = m.sharding_summary();
        assert!(s.contains("leader_ingest=0 B"), "{s}");
        assert!(s.contains("shard_local=4.00 KiB"), "{s}");
    }

    #[test]
    fn locality_summary_composes_and_omits_empty() {
        assert_eq!(RunMetrics::default().locality_summary(), "");
        assert_eq!(RunMetrics::default().panel_hit_rate(), 0.0);
        let m = RunMetrics {
            scatter_saved_bytes: 2048,
            panel_hits: 9,
            panel_misses: 3,
            jobs_stolen: 2,
            reduce_folds: 6,
            reduce_fold_edges: 420,
            ..Default::default()
        };
        assert!((m.panel_hit_rate() - 0.75).abs() < 1e-9);
        let s = m.locality_summary();
        assert!(s.contains("scatter_saved=2.00 KiB"), "{s}");
        assert!(s.contains("panel_cache=9/12 hits (75%)"), "{s}");
        assert!(s.contains("stolen=2"), "{s}");
        assert!(s.contains("folds=6 fold_edges=420"), "{s}");
    }

    #[test]
    fn kernel_summary_reports_isa_threads_and_gflops() {
        assert_eq!(RunMetrics::default().kernel_summary(), "", "no panels, no line");
        let m = RunMetrics {
            panel_isa: "avx2".into(),
            panel_lanes: 8,
            panel_threads_used: 4,
            panel_flops: 2_000_000_000,
            panel_time: Duration::from_secs(1),
            ..Default::default()
        };
        assert!((m.panel_gflops() - 2.0).abs() < 1e-9);
        let s = m.kernel_summary();
        assert!(s.contains("isa=avx2 lanes=8"), "{s}");
        assert!(s.contains("threads=4"), "{s}");
        assert!(s.contains("panel_gflops=2.00"), "{s}");
        // fallback note rides along like the dense kernel's
        let f = RunMetrics {
            panel_isa: "scalar".into(),
            panel_lanes: 1,
            panel_fallback: Some("DEMST_SIMD=off".into()),
            ..Default::default()
        };
        let s = f.kernel_summary();
        assert!(s.contains("isa=scalar lanes=1"), "{s}");
        assert!(s.contains("fallback: DEMST_SIMD=off"), "{s}");
        assert_eq!(RunMetrics::default().panel_gflops(), 0.0);
    }

    #[test]
    fn locality_summary_splits_the_data_plane() {
        // inactive plane: the split is omitted entirely
        let quiet = RunMetrics {
            leader_control_bytes: 900,
            leader_data_bytes: 100,
            ..Default::default()
        };
        assert!(!quiet.data_plane_active());
        assert!(!quiet.locality_summary().contains("leader_control"));
        let m = RunMetrics {
            reduce_topology: "ring".into(),
            peer_route: true,
            leader_control_bytes: 2048,
            leader_data_bytes: 0,
            peer_bytes: 4096,
            peer_ships: 7,
            ..Default::default()
        };
        assert!(m.data_plane_active());
        let s = m.locality_summary();
        assert!(s.contains("leader_control=2.00 KiB"), "{s}");
        assert!(s.contains("leader_data=0 B"), "{s}");
        assert!(s.contains("peer=4.00 KiB"), "{s}");
        assert!(s.contains("peer_ships=7"), "{s}");
        let top = m.summary();
        assert!(top.contains("topology=ring"), "{top}");
        assert!(top.contains("peer_route"), "{top}");
    }

    #[test]
    fn summary_reports_kernel_and_fallback() {
        let m = RunMetrics {
            kernel: "boruvka-rust".into(),
            kernel_fallback: Some("backend-xla not compiled".into()),
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("kernel=boruvka-rust"), "{s}");
        assert!(s.contains("fallback: backend-xla not compiled"), "{s}");
    }
}
