//! The distributed runtime front-end: typed messages, the simulated network
//! with exact byte accounting, run metrics, and the per-rank kernel factory.
//!
//! This realizes the paper's execution model — `p = |P|(|P|-1)/2` independent
//! d-MST jobs, a scatter of vector subsets, **zero** mid-compute
//! communication, and a final gather of tree edges (or the `⊕`-reduction
//! variant) — on a single machine, faithfully enough that the communication
//! *measurements* (E3) are exact counts, not estimates.
//!
//! The execution itself (worker pool, cost-LPT job dealing with idle
//! stealing, streaming ⊕-reduction) is the shared [`crate::exec`] engine;
//! [`run_distributed`] is a thin wrapper that provides the transport
//! fabric — the simulated [`NetSim`] by default, or real TCP links against
//! `demst worker` processes for `transport = tcp` (see [`crate::net`]) —
//! and returns [`RunMetrics`]. Under the simulated fabric, workers are OS
//! threads, each owning its own d-MST kernel instance (including, for
//! `KernelChoice::BoruvkaXla`, its own PJRT client and compiled
//! executables: PJRT handles are thread-local by construction in the `xla`
//! crate, which conveniently mirrors per-rank process memory).
//!
//! The simulated network itself now lives in [`crate::net::sim`] (this
//! module re-exports it under its old names); its byte model and counters
//! are unchanged.

pub mod messages;
pub mod metrics;
pub mod worker;
pub mod leader;

pub use leader::{run_distributed, run_sharded, DistOutput};
pub use messages::Message;
pub use metrics::RunMetrics;
pub use crate::net::{NetCounters, NetSim};
