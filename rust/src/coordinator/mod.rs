//! The distributed runtime front-end: typed messages, the simulated network
//! with exact byte accounting, run metrics, and the per-rank kernel factory.
//!
//! This realizes the paper's execution model — `p = |P|(|P|-1)/2` independent
//! d-MST jobs, a scatter of vector subsets, **zero** mid-compute
//! communication, and a final gather of tree edges (or the `⊕`-reduction
//! variant) — on a single machine, faithfully enough that the communication
//! *measurements* (E3) are exact counts, not estimates.
//!
//! The execution itself (worker pool, cost-LPT job dealing with idle
//! stealing, streaming ⊕-reduction) is the shared [`crate::exec`] engine;
//! [`run_distributed`] is a thin wrapper that provides the [`NetSim`]
//! fabric and returns [`RunMetrics`]. Workers are OS threads, each owning
//! its own d-MST kernel instance (including, for
//! `KernelChoice::BoruvkaXla`, its own PJRT client and compiled
//! executables: PJRT handles are thread-local by construction in the `xla`
//! crate, which conveniently mirrors per-rank process memory).

pub mod messages;
pub mod netsim;
pub mod metrics;
pub mod worker;
pub mod leader;

pub use leader::{run_distributed, DistOutput};
pub use messages::Message;
pub use metrics::RunMetrics;
pub use netsim::{NetCounters, NetSim};
