//! Worker rank: owns a d-MST kernel, executes pair jobs, reports results.

use super::messages::Message;
use super::netsim::{Direction, NetSim};
use crate::config::RunConfig;
use crate::decomp::reduction::tree_merge;
use crate::dense::DenseMst;
use crate::graph::Edge;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

/// Build this worker's kernel via the backend resolver. Called *inside* the
/// worker thread so PJRT handles (not `Send`) stay thread-local, like
/// per-rank process memory. When the requested kernel is not compiled into
/// this build (e.g. `boruvka-xla` without `--features backend-xla`), the
/// resolver substitutes the blocked Rust provider; the leader reports the
/// substitution in `RunMetrics::kernel_fallback`.
pub fn build_kernel(cfg: &RunConfig) -> anyhow::Result<Box<dyn DenseMst>> {
    let (kernel, _fallback) = crate::runtime::build_dense_kernel(cfg)?;
    Ok(kernel)
}

/// Worker main loop.
///
/// Gather mode (`local_reduce = false`): each pair tree is sent back
/// immediately (`O(|V||P|)` aggregate gather traffic).
/// Reduce mode (`local_reduce = true`): pair trees are ⊕-combined locally
/// and a single ≤`|V|-1`-edge tree is sent at shutdown (`O(|V|)` per worker).
pub fn worker_main(
    worker_id: usize,
    n_global: usize,
    cfg: &RunConfig,
    net: &NetSim,
    rx: Receiver<Message>,
    tx_leader: Sender<Message>,
    local_reduce: bool,
) {
    let kernel = match build_kernel(cfg) {
        Ok(k) => k,
        Err(e) => {
            // Report failure as an empty done message; the leader surfaces
            // the error when results are missing.
            eprintln!("worker {worker_id}: kernel init failed: {e:#}");
            let _ = net.send(
                &tx_leader,
                Message::WorkerDone {
                    worker: worker_id,
                    local_tree: None,
                    dist_evals: 0,
                    busy: Duration::ZERO,
                    jobs_run: 0,
                },
                Direction::Gather,
            );
            return;
        }
    };
    let mut busy = Duration::ZERO;
    let mut jobs_run = 0u32;
    let mut local_tree: Option<Vec<Edge>> = None;
    while let Ok(msg) = rx.recv() {
        match msg {
            Message::Job { job, global_ids, points } => {
                let t = Instant::now();
                let local = kernel.mst(&points);
                let tree: Vec<Edge> = local
                    .iter()
                    .map(|e| {
                        Edge::new(
                            global_ids[e.u as usize],
                            global_ids[e.v as usize],
                            e.w,
                        )
                    })
                    .collect();
                let compute = t.elapsed();
                busy += compute;
                jobs_run += 1;
                if local_reduce {
                    let t2 = Instant::now();
                    local_tree = Some(match local_tree.take() {
                        None => tree,
                        Some(prev) => tree_merge(n_global, &prev, &tree),
                    });
                    busy += t2.elapsed();
                } else if net
                    .send(
                        &tx_leader,
                        Message::Result { job_id: job.id, worker: worker_id, edges: tree, compute },
                        Direction::Gather,
                    )
                    .is_err()
                {
                    return; // leader gone
                }
            }
            Message::Shutdown => break,
            other => {
                debug_assert!(false, "worker received unexpected message {other:?}");
            }
        }
    }
    let _ = net.send(
        &tx_leader,
        Message::WorkerDone {
            worker: worker_id,
            local_tree,
            dist_evals: kernel.dist_evals(),
            busy,
            jobs_run,
        },
        Direction::Gather,
    );
}
