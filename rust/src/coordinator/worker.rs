//! Worker-rank kernel construction.
//!
//! The worker *loop* (claim job → solve → report) lives in the shared exec
//! engine ([`crate::exec::engine`]); what remains here is the per-rank
//! kernel factory, kept in the coordinator because its contract is about
//! rank-local state, not scheduling.

use crate::config::RunConfig;
use crate::dense::DenseMst;

/// Build this worker's d-MST kernel via the backend resolver. Called
/// *inside* the worker thread so PJRT handles (not `Send`) stay
/// thread-local, like per-rank process memory. When the requested kernel is
/// not compiled into this build (e.g. `boruvka-xla` without
/// `--features backend-xla`), the resolver substitutes the blocked Rust
/// provider; the leader reports the substitution in
/// `RunMetrics::kernel_fallback`.
pub fn build_kernel(cfg: &RunConfig) -> anyhow::Result<Box<dyn DenseMst>> {
    let (kernel, _fallback) = crate::runtime::build_dense_kernel(cfg)?;
    Ok(kernel)
}
