//! The `demst worker` process: the far end of one leader↔worker TCP link.
//!
//! A worker connects (with bounded-backoff retries), optionally **loads
//! shard files first** (`--shard <manifest> --shard-ids ...`: subsets read
//! and digest-verified from local disk, so their vectors never touch the
//! leader), handshakes (`Hello` → `Setup` → `SetupAck` →
//! `ShardAdvertise`), then serves frames until `Shutdown`:
//!
//! - `LocalJob` — compute one partition subset's local MST over the shipped
//!   rows (bipartite-merge phase 1), reply `LocalDone`, and keep the subset
//!   **resident** (vectors, per-row aux values, tree);
//! - `LocalAssign` — the sharded twin: same local MST, but over a subset
//!   this worker already holds from its shard files (the frame is 16
//!   bytes — no vectors on the wire);
//! - `PairAssign` — absorb whatever subsets ride along (the leader ships
//!   exactly what this worker is missing under its resident-set model),
//!   solve the pair job with the configured kernel, and reply `Result`
//!   (gather mode) or fold into the worker-local ⊕-tree and reply `Ack`
//!   (reduce mode);
//! - `Job` — the paper-literal full-union scatter: solve the shipped union
//!   with the dense kernel directly (kept for wire completeness; the
//!   engine's proxies always use `PairAssign`);
//! - `PeerBook` — store the fleet's peer routing table (listener addresses
//!   + subset builders); no reply. A `PairAssign` section flagged *routed*
//!   then pulls its cached tree from the building anchor over a
//!   worker↔worker link (`PeerHello` once per link, `TreeFetch` →
//!   `TreeShip`) instead of the leader link; a dead anchor degrades the job
//!   to a `PairFail` reply and the leader re-plans it tree-inline;
//! - `FoldShip` — ⊕-reduction directive (tree/ring topologies): wait for
//!   the announced number of peer partial MSFs, fold them into the local
//!   partial, ship the result to the named peer (or keep it, as the
//!   reduction root), reply `FoldDone`;
//! - `Shutdown` — reply the final `WorkerDone` (busy time, distance
//!   evaluations, panel stats, peer-plane traffic witnesses, and the folded
//!   tree in reduce mode) and exit.
//!
//! Liveness: a `Setup` with nonzero `liveness_ms` arms a read deadline on
//! the leader link (and on peer-fetch replies) — a leader silent past it
//! (no job, no `Heartbeat`) is treated as stalled and the worker exits with
//! a [`super::STALL_MARK`]-tagged error instead of hanging forever.
//! `Heartbeat` frames are skipped. The fold wait derives from the same
//! deadline (`liveness / 2`) so a fold degrade always resolves before the
//! leader's own deadline trips.
//!
//! Admission: a `Setup` stamped `mid_run` means this worker is joining an
//! already-running leader — it answers with the versioned `Join` (plus its
//! `ShardAdvertise`) and waits for `AdmitAck` before serving; the manifest
//! check is identical to startup.
//!
//! Chaos: when `DEMST_CHAOS_PLAN` is set, all leader-link frame IO runs
//! through the deterministic [`super::chaos::ChaosLink`] wrapper
//! (delay/stall/drop/truncate/garbage/exit on frame N), and
//! `DEMST_CHAOS_PEER_DENY` makes the next N routed peer fetches fail — so
//! every failure path above is reproducibly injectable. The legacy abrupt
//! exits ([`CHAOS_EXIT_ENV`], [`CHAOS_EXIT_ON_FOLD_ENV`]) remain.
//!
//! Exactness: the worker never holds the full matrix, only gathered
//! subsets — and every kernel it runs is bit-identical to the leader's
//! in-process path over those rows ([`subset_mst_gathered`],
//! [`bipartite_filtered_prim_blocked`] over a [`DistanceBlock::panel_block`]
//! panel, the dense kernels over the merged union), because per-pair
//! distance arithmetic is independent of the surrounding rows and all
//! tie-breaks compare global ids.

use super::chaos::{self, ChaosLink};
use super::wire::{self, Hello, Join, SetupAck, ShardAdvertise, WireCtx, WIRE_VERSION};
use crate::config::{PairKernelChoice, RunConfig};
use crate::coordinator::messages::{Message, PeerAddr, SubsetShip, FOLD_KEEP};
use crate::data::Dataset;
use crate::decomp::reduction::tree_merge;
use crate::decomp::PairJob;
use crate::dense::DenseMst;
use crate::exec::{
    bipartite_filtered_prim_blocked, subset_mst_gathered, KeyedLru, PanelPerf, PANEL_CACHE_CAP,
};
use crate::geometry::blocked::{distance_block_with, DistanceBlock};
use crate::geometry::simd::{self, PanelSettings};
use crate::geometry::CountingMetric;
use crate::graph::Edge;
use crate::shard::{Manifest, Shard};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Chaos hook (failure-injection tests and `scripts/chaos_smoke.sh`): when
/// this env var is set to `N`, the worker process exits abruptly — sockets
/// torn down by the OS, no shutdown handshake, exactly like a SIGKILL —
/// upon receiving its `(N+1)`-th pair job. Leaves one job dead in flight,
/// which the leader must reassign.
pub const CHAOS_EXIT_ENV: &str = "DEMST_CHAOS_EXIT_AFTER_JOBS";

/// Chaos hook for the reduction topologies: when set (to anything), the
/// worker exits abruptly upon receiving its `FoldShip` directive — mid-fold,
/// after its pair jobs were acked but before its partial MSF shipped
/// anywhere. The leader must return every job folded into the lost partial
/// to the exactly-once lane.
pub const CHAOS_EXIT_ON_FOLD_ENV: &str = "DEMST_CHAOS_EXIT_ON_FOLD";

/// How long a fold directive waits for the expected peer partials before
/// degrading to `FoldDone { ok: false }` (the worker then keeps everything
/// that did arrive and reports it in its `WorkerDone` for the leader to
/// fold — exactly-once either way, because ⊕ is idempotent). This is the
/// fallback for liveness-disabled runs; with liveness on, the wait is
/// `liveness / 2` so the degrade always lands before the leader's own
/// read deadline would trip on the silent `FoldDone`.
const FOLD_WAIT: Duration = Duration::from_secs(30);

/// Client-side peer-link settings (threaded from `WorkerOptions` + the
/// handshake `Setup` into the fetch/ship paths).
#[derive(Clone, Copy)]
struct PeerCfg {
    /// a dead anchor should degrade to `PairFail` promptly, not hang the deck
    connect_timeout: Duration,
    /// read deadline on fetch replies (None = wait forever)
    read_deadline: Option<Duration>,
}

/// State shared between the worker's main loop and its peer-listener
/// threads. The listener serves two frame kinds, both independent of the
/// main loop (so a fetch never deadlocks two busy workers):
/// `TreeFetch` → reply the subset's cached local MST from `trees`;
/// `TreeShip { fold: true }` → push the partial into `inbox` and wake the
/// main loop's fold wait.
struct PeerState {
    /// built local MSTs (compare-form weights), indexed by subset
    trees: Mutex<Vec<Option<Vec<Edge>>>>,
    /// ⊕-fold partials received from peers (emission-form)
    inbox: Mutex<Vec<Vec<Edge>>>,
    arrived: Condvar,
    /// peer-plane bytes this worker put on peer sockets (either role)
    tx_bytes: AtomicU64,
    /// peer-plane payload frames sent (fetch replies + fold ships)
    ships: AtomicU32,
    shutdown: AtomicBool,
}

impl PeerState {
    fn new(parts: usize) -> Self {
        Self {
            trees: Mutex::new(vec![None; parts]),
            inbox: Mutex::new(Vec::new()),
            arrived: Condvar::new(),
            tx_bytes: AtomicU64::new(0),
            ships: AtomicU32::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    fn publish(&self, part: usize, tree: &[Edge]) {
        self.trees.lock().unwrap()[part] = Some(tree.to_vec());
    }
}

/// Accept loop for the worker's peer listener: non-blocking accept polled
/// against the shutdown flag, one handler thread per peer connection.
/// Handler sockets stay blocking — they exit on EOF when the far worker
/// drops its connection cache at shutdown.
fn spawn_peer_server(listener: TcpListener, peer: Arc<PeerState>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        if listener.set_nonblocking(true).is_err() {
            return;
        }
        while !peer.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((conn, _)) => {
                    let peer = Arc::clone(&peer);
                    std::thread::spawn(move || {
                        let _ = serve_peer_conn(conn, &peer);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(_) => break,
            }
        }
    })
}

/// One accepted peer connection: `PeerHello` first, then fetches and fold
/// ships until the peer hangs up. Reads are bounded (short deadline,
/// re-armed against the shutdown flag) so a silent peer cannot strand this
/// handler past the worker's own shutdown.
fn serve_peer_conn(mut conn: TcpStream, peer: &PeerState) -> Result<()> {
    conn.set_nodelay(true).ok();
    conn.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let read_polled = |conn: &mut TcpStream, peer: &PeerState| -> std::io::Result<Vec<u8>> {
        loop {
            match wire::read_frame_io(conn) {
                Err(e)
                    if super::is_timeout_kind(e.kind())
                        && !peer.shutdown.load(Ordering::Relaxed) =>
                {
                    continue;
                }
                other => return other,
            }
        }
    };
    match wire::decode(&read_polled(&mut conn, peer).context("reading PeerHello")?, None)? {
        Message::PeerHello { .. } => {}
        other => bail!("peer link opened without PeerHello: {other:?}"),
    }
    loop {
        let frame = match read_polled(&mut conn, peer) {
            Ok(f) => f,
            Err(_) => return Ok(()), // EOF / reset / shutdown: peer is done with us
        };
        match wire::decode(&frame, None)? {
            Message::TreeFetch { part } => {
                let edges = peer
                    .trees
                    .lock()
                    .unwrap()
                    .get(part as usize)
                    .and_then(|t| t.clone())
                    // no tree: drop the link — the fetcher degrades the job
                    // to PairFail and the leader re-plans it tree-inline
                    .ok_or_else(|| anyhow!("peer fetch for unbuilt subset {part}"))?;
                let reply = wire::encode(&Message::TreeShip { part, fold: false, edges })?;
                wire::write_frame(&mut conn, &reply)?;
                peer.tx_bytes.fetch_add(reply.len() as u64, Ordering::Relaxed);
                peer.ships.fetch_add(1, Ordering::Relaxed);
            }
            Message::TreeShip { fold: true, edges, .. } => {
                peer.inbox.lock().unwrap().push(edges);
                peer.arrived.notify_all();
            }
            other => bail!("unexpected frame on peer link: {other:?}"),
        }
    }
}

/// The fetcher half of the peer data plane: connect to (or reuse) the
/// builder's peer listener and pull one subset's cached local MST. The
/// worker's own id short-circuits to the local registry. A failed link is
/// evicted from the cache so the next routed job retries fresh.
fn fetch_routed(
    part: u32,
    my_id: u16,
    book: Option<&(Vec<PeerAddr>, Vec<u16>)>,
    conns: &mut HashMap<u16, TcpStream>,
    peer: &PeerState,
    cfg: PeerCfg,
) -> Result<Vec<Edge>> {
    let (peers, builders) = book.ok_or_else(|| anyhow!("routed ship before PeerBook"))?;
    let b = *builders
        .get(part as usize)
        .ok_or_else(|| anyhow!("routed subset {part} outside the builder table"))?;
    if b == my_id {
        return peer
            .trees
            .lock()
            .unwrap()
            .get(part as usize)
            .and_then(|t| t.clone())
            .ok_or_else(|| anyhow!("routed to own registry but subset {part} is unbuilt"));
    }
    if b == FOLD_KEEP {
        bail!("subset {part} has no peer builder (leader-built)");
    }
    if chaos::peer_fetch_denied() {
        bail!("chaos: peer fetch for subset {part} denied (DEMST_CHAOS_PEER_DENY)");
    }
    let fetched = (|| -> Result<Vec<Edge>> {
        let conn = peer_conn(b, my_id, peers, conns, peer, cfg)?;
        let fetch = wire::encode(&Message::TreeFetch { part })?;
        wire::write_frame(conn, &fetch)?;
        peer.tx_bytes.fetch_add(fetch.len() as u64, Ordering::Relaxed);
        let reply = match wire::read_frame_io(conn) {
            Ok(f) => f,
            Err(e) if super::is_timeout_kind(e.kind()) => bail!(
                "builder {b} {}: no TreeShip within the read deadline",
                super::STALL_MARK
            ),
            Err(e) => return Err(e).context("reading TreeShip"),
        };
        match wire::decode(&reply, None)? {
            Message::TreeShip { part: p, fold: false, edges } if p == part => Ok(edges),
            other => bail!("expected TreeShip({part}), got {other:?}"),
        }
    })();
    if fetched.is_err() {
        conns.remove(&b); // half-used link: never reuse it
    }
    fetched
}

/// Get (or open, with a `PeerHello`) the cached connection to worker `to`.
/// Fresh links take `cfg.read_deadline` so a fetch against a stalled
/// builder degrades to `PairFail` instead of hanging the deck.
fn peer_conn<'a>(
    to: u16,
    my_id: u16,
    peers: &[PeerAddr],
    conns: &'a mut HashMap<u16, TcpStream>,
    peer: &PeerState,
    cfg: PeerCfg,
) -> Result<&'a mut TcpStream> {
    if !conns.contains_key(&to) {
        let addr = peers
            .get(to as usize)
            .ok_or_else(|| anyhow!("worker {to} outside the peer book"))?;
        if addr.port == 0 {
            bail!("worker {to} advertises no peer listener");
        }
        let mut conn = TcpStream::connect_timeout(
            &SocketAddr::new(addr.ip, addr.port),
            cfg.connect_timeout,
        )
        .with_context(|| format!("connecting peer link to worker {to}"))?;
        conn.set_nodelay(true).ok();
        conn.set_read_timeout(cfg.read_deadline).ok();
        let hello = wire::encode(&Message::PeerHello { from: my_id })?;
        wire::write_frame(&mut conn, &hello).context("sending PeerHello")?;
        peer.tx_bytes.fetch_add(hello.len() as u64, Ordering::Relaxed);
        conns.insert(to, conn);
    }
    Ok(conns.get_mut(&to).expect("just inserted"))
}

/// Ship this worker's folded partial MSF to peer `to` (a ⊕-reduction hop).
fn ship_fold(
    to: u16,
    my_id: u16,
    edges: Vec<Edge>,
    book: Option<&(Vec<PeerAddr>, Vec<u16>)>,
    conns: &mut HashMap<u16, TcpStream>,
    peer: &PeerState,
    cfg: PeerCfg,
) -> Result<()> {
    let (peers, _) = book.ok_or_else(|| anyhow!("FoldShip before PeerBook"))?;
    let shipped = (|| -> Result<()> {
        let conn = peer_conn(to, my_id, peers, conns, peer, cfg)?;
        let frame = wire::encode(&Message::TreeShip { part: my_id as u32, fold: true, edges })?;
        wire::write_frame(conn, &frame).context("shipping fold partial")?;
        peer.tx_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        peer.ships.fetch_add(1, Ordering::Relaxed);
        Ok(())
    })();
    if shipped.is_err() {
        conns.remove(&to);
    }
    shipped
}

/// What one worker process did, for the `demst worker` exit report.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    pub worker_id: u16,
    /// pair jobs solved
    pub jobs: u32,
    /// local-MST (phase 1) jobs solved
    pub local_jobs: u32,
    pub dist_evals: u64,
    /// actual frame bytes received / sent on the socket
    pub bytes_rx: u64,
    pub bytes_tx: u64,
    /// subsets loaded from local shard files before connecting
    pub shards_loaded: u32,
    /// vector payload bytes those shards kept off the wire
    pub shard_local_bytes: u64,
    /// bytes sent on worker↔worker peer links (tree ships + fold hops)
    pub peer_tx_bytes: u64,
    /// peer payload frames sent (fetch replies + fold ships)
    pub peer_ships: u32,
}

/// How a worker process connects and what it serves.
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// keep retrying the connect for this long (leaders routinely bind
    /// after their workers start)
    pub connect_timeout: Duration,
    /// initial retry backoff; doubles per attempt (±25% jitter), capped at 2 s
    pub connect_backoff: Duration,
    /// peer-link (worker↔worker) connect timeout — a dead anchor should
    /// degrade the routed job to `PairFail` promptly, not hang the deck
    pub peer_connect_timeout: Duration,
    /// shard residency: manifest plus the subset ids to load locally
    pub shards: Option<(std::path::PathBuf, Vec<u32>)>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(10),
            connect_backoff: Duration::from_millis(100),
            peer_connect_timeout: Duration::from_secs(5),
            shards: None,
        }
    }
}

/// One resident partition subset: rows packed in ascending-global-id order,
/// the matching per-row aux values (norms), a zero-padded copy of the rows
/// at the SIMD panel stride, and — once known — the subset's local MST in
/// compare-form weights.
struct Slot {
    ids: Vec<u32>,
    points: Dataset,
    aux: Vec<f32>,
    /// Rows repacked at `stride` (lane-multiple, zero pad) for the SIMD
    /// panel path — the worker-side twin of the in-process `SubsetPanel`.
    panel: Vec<f32>,
    stride: usize,
    tree: Option<Vec<Edge>>,
}

impl Slot {
    fn new(ids: Vec<u32>, points: Dataset, aux: Vec<f32>, tree: Option<Vec<Edge>>) -> Self {
        let (panel, stride) = simd::pad_rows(points.as_slice(), points.n, points.d);
        Self { ids, points, aux, panel, stride, tree }
    }
}

/// Connect to a leader with retries (the leader may still be binding), then
/// serve until shutdown. Unsharded shorthand for [`run_with`].
pub fn run(addr: &str, retry: Duration) -> Result<WorkerReport> {
    run_with(addr, &WorkerOptions { connect_timeout: retry, ..Default::default() })
}

/// Full worker lifecycle: load (and digest-verify) any requested shards
/// from local disk, connect with bounded-backoff retries, serve until
/// shutdown.
pub fn run_with(addr: &str, opts: &WorkerOptions) -> Result<WorkerReport> {
    let loaded = match &opts.shards {
        Some((manifest_path, ids)) => Some(load_shards(manifest_path, ids)?),
        None => None,
    };
    let stream = connect_with_retry(addr, opts.connect_timeout, opts.connect_backoff)?;
    serve_with(stream, loaded, opts)
}

/// A worker's locally loaded shard set, verified against its manifest.
pub struct LoadedShards {
    pub fingerprint: u64,
    pub shards: Vec<Shard>,
}

/// Read the manifest and the requested shard files (digest-verified).
/// An empty `ids` list means "all shards in the manifest".
pub fn load_shards(manifest_path: &Path, ids: &[u32]) -> Result<LoadedShards> {
    let manifest = Manifest::load(manifest_path)?;
    let all: Vec<u32>;
    let ids = if ids.is_empty() {
        all = (0..manifest.parts() as u32).collect();
        &all[..]
    } else {
        ids
    };
    let shards = crate::shard::load_worker_shards(&manifest, ids)?;
    Ok(LoadedShards { fingerprint: manifest.fingerprint(), shards })
}

/// Retry-connect loop: workers are routinely started before (or racing) the
/// leader's bind, so a refused connection is retried until `window` lapses,
/// with the sleep between attempts starting at `backoff` and doubling up to
/// a 2 s cap (bounded backoff — cheap while racing a bind, polite while a
/// leader restarts). Each sleep is jittered ±25% so a fleet of workers
/// restarted together does not hammer the leader's accept queue in
/// lockstep (anti-thundering-herd).
pub fn connect_with_retry(addr: &str, window: Duration, backoff: Duration) -> Result<TcpStream> {
    const BACKOFF_CAP: Duration = Duration::from_secs(2);
    let t0 = Instant::now();
    // Per-process jitter stream: pid ⊕ clock nanos, so simultaneously
    // spawned workers still decorrelate.
    let seed = u64::from(std::process::id())
        ^ std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map_or(0, |d| u64::from(d.subsec_nanos()));
    let mut rng = crate::util::prng::Pcg64::seeded(seed | 1);
    let mut pause = backoff.max(Duration::from_millis(1)).min(BACKOFF_CAP);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if t0.elapsed() >= window {
                    return Err(anyhow!(e)).with_context(|| {
                        format!("could not connect to leader at {addr} within {window:?}")
                    });
                }
                let jittered = pause.mul_f64(0.75 + 0.5 * f64::from(rng.next_f32()));
                std::thread::sleep(jittered.min(window.saturating_sub(t0.elapsed())));
                pause = (pause * 2).min(BACKOFF_CAP);
            }
        }
    }
}

/// Serve one handshaken connection until `Shutdown` (unsharded).
pub fn serve(stream: TcpStream) -> Result<WorkerReport> {
    serve_with(stream, None, &WorkerOptions::default())
}

/// Leader-link frame reads, optionally through the chaos wrapper, under an
/// explicit payload cap (the handshake uses the tighter
/// [`wire::MAX_HANDSHAKE_PAYLOAD`]).
fn link_read_capped(
    stream: &mut TcpStream,
    chaos: &mut Option<ChaosLink>,
    cap: u32,
) -> std::io::Result<Vec<u8>> {
    match chaos {
        Some(c) => c.read_frame(stream),
        None => wire::read_frame_capped_io(stream, cap),
    }
}

fn link_read(stream: &mut TcpStream, chaos: &mut Option<ChaosLink>) -> std::io::Result<Vec<u8>> {
    link_read_capped(stream, chaos, wire::MAX_PAYLOAD)
}

fn link_write(
    stream: &mut TcpStream,
    chaos: &mut Option<ChaosLink>,
    frame: &[u8],
) -> std::io::Result<()> {
    match chaos {
        Some(c) => c.write_frame(stream, frame),
        None => wire::write_frame(stream, frame),
    }
}

/// Saturating `Duration` → nanoseconds for histogram observations.
fn ns_of(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Fold the peer-plane tx bytes accrued since the last snapshot into the
/// registry, then take the cumulative snapshot. Every shipped snapshot
/// (periodic push or final `WorkerDone`) goes through here so the peer
/// counter never double-counts.
fn metrics_snapshot(
    reg: &crate::obs::metrics::Registry,
    peer: &PeerState,
    peer_tx_seen: &mut u64,
) -> crate::obs::metrics::Snapshot {
    let tx = peer.tx_bytes.load(Ordering::Relaxed);
    reg.add(crate::obs::metrics::Ctr::PeerTxBytes, tx.saturating_sub(*peer_tx_seen));
    *peer_tx_seen = tx;
    reg.snapshot()
}

/// Write one unsolicited `MetricsPush` frame with the current cumulative
/// snapshot. Best-effort: a push must never take a healthy link down — the
/// link's real traffic surfaces write errors with proper context.
#[allow(clippy::too_many_arguments)]
fn push_metrics(
    stream: &mut TcpStream,
    chaos: &mut Option<ChaosLink>,
    reg: &crate::obs::metrics::Registry,
    peer: &PeerState,
    peer_tx_seen: &mut u64,
    worker_id: u16,
    report: &mut WorkerReport,
) {
    let snap = metrics_snapshot(reg, peer, peer_tx_seen);
    let msg = Message::MetricsPush { worker: worker_id, snap };
    if let Ok(frame) = wire::encode(&msg) {
        if link_write(stream, chaos, &frame).is_ok() {
            report.bytes_tx += frame.len() as u64;
        }
    }
}

/// Serve one connection until `Shutdown`, optionally with pre-loaded
/// shard residency.
pub fn serve_with(
    mut stream: TcpStream,
    loaded: Option<LoadedShards>,
    opts: &WorkerOptions,
) -> Result<WorkerReport> {
    stream.set_nodelay(true).ok();
    // Clocked before the handshake so the eventual Handshake span covers
    // connect-to-serve even though recording only arms once the leader's
    // Setup says whether this run traces.
    let t_handshake = crate::obs::now_ns();
    // Deterministic fault injection on every leader-link frame (tests and
    // the chaos-smoke CI matrix); None in production.
    let mut chaos_link = ChaosLink::from_env()?;
    // Bind the peer listener before Hello so its port can be advertised.
    // Bind failure degrades gracefully: port 0 = "no peer plane here", and
    // the leader falls back to shipping trees itself.
    let peer_listener = TcpListener::bind("0.0.0.0:0").ok();
    let peer_port = peer_listener
        .as_ref()
        .and_then(|l| l.local_addr().ok())
        .map_or(0, |a| a.port());
    // Bound the handshake so connecting to a silent peer fails instead of
    // hanging; job frames afterwards may legitimately take arbitrarily long.
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .context("setting handshake timeout")?;
    link_write(
        &mut stream,
        &mut chaos_link,
        &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port }),
    )
    .context("sending Hello")?;
    let setup_frame =
        link_read_capped(&mut stream, &mut chaos_link, wire::MAX_HANDSHAKE_PAYLOAD)
            .context("reading Setup (is the peer a demst leader?)")?;
    let setup = wire::decode_setup(&setup_frame)?;
    // Sharded-vs-unsharded agreement must fail HERE, before any job frame:
    // a worker whose shard files were cut from a different partition (or
    // that has none at all for a sharded leader) would otherwise compute
    // over wrong resident data.
    match (&loaded, setup.manifest) {
        (Some(_), 0) => bail!(
            "this worker loaded shards but the leader's run is not sharded — drop --shard or start the leader with `demst run --shard <manifest>`"
        ),
        (Some(l), fp) if l.fingerprint != fp => bail!(
            "shard manifest mismatch: worker loaded {:#018x}, leader announced {fp:#018x} — the shard files were cut from a different `demst partition` run",
            l.fingerprint
        ),
        (None, fp) if fp != 0 => bail!(
            "the leader runs sharded (manifest {fp:#018x}) but this worker loaded no shards — restart it with --shard <manifest> --shard-ids ..."
        ),
        _ => {}
    }
    let shard_ids: Vec<u32> = match &loaded {
        Some(l) => l.shards.iter().map(|s| s.part).collect(),
        None => Vec::new(),
    };
    let advertise = wire::encode_shard_advertise(&ShardAdvertise {
        worker_id: setup.worker_id,
        shard_ids,
    })?;
    if setup.mid_run {
        // Joining an already-running leader: versioned Join in place of the
        // SetupAck, then wait for the AdmitAck before serving — the engine
        // only opens a deck for us once the leader confirms the admission.
        link_write(
            &mut stream,
            &mut chaos_link,
            &wire::encode_join(&Join { worker_id: setup.worker_id, version: WIRE_VERSION }),
        )
        .context("sending Join")?;
        link_write(&mut stream, &mut chaos_link, &advertise).context("sending ShardAdvertise")?;
        let ack_frame = link_read_capped(&mut stream, &mut chaos_link, wire::MAX_HANDSHAKE_PAYLOAD)
            .context("reading AdmitAck")?;
        let ack = wire::decode_admit_ack(&ack_frame)?;
        if ack.worker_id != setup.worker_id {
            bail!("leader admitted id {} but assigned {}", ack.worker_id, setup.worker_id);
        }
    } else {
        link_write(
            &mut stream,
            &mut chaos_link,
            &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
        )
        .context("sending SetupAck")?;
        link_write(&mut stream, &mut chaos_link, &advertise).context("sending ShardAdvertise")?;
    }
    // From here on the deadline is the liveness timeout (None = disabled):
    // the leader heartbeats idle links, so silence past it means a stalled
    // or dead leader — better to exit loudly than hang forever.
    let liveness =
        (setup.liveness_ms > 0).then(|| Duration::from_millis(u64::from(setup.liveness_ms)));
    stream.set_read_timeout(liveness).context("setting link read deadline")?;

    // Telemetry: the leader's Setup decides whether spans are recorded and
    // shipped back in the final WorkerDone. Without the token every span
    // call below is one relaxed atomic load and no allocation.
    let obs_run = setup.trace.then(crate::obs::begin_run);
    crate::obs::record(
        crate::obs::SpanKind::Handshake,
        setup.worker_id,
        u32::from(setup.worker_id),
        0,
        t_handshake,
        crate::obs::now_ns(),
    );
    // Metrics: recording is always on (relaxed atomics, no allocation on
    // the hot path); *shipping* is what the Setup metrics flag gates. When
    // armed, cumulative snapshots ride the final WorkerDone plus periodic
    // unsolicited MetricsPush frames, rate-limited to the push cadence.
    use crate::obs::metrics::{Ctr, Hist, Registry};
    let reg = Registry::new();
    let push_every = (setup.metrics && setup.metrics_push_ms > 0)
        .then(|| Duration::from_millis(u64::from(setup.metrics_push_ms)));
    let mut last_push = Instant::now();
    // Peer-plane tx bytes accrue in the listener threads; the delta since
    // the last snapshot is folded into the registry before each ship.
    let mut peer_tx_seen = 0u64;

    let kind = wire::metric_from_code(setup.metric)?;
    let pair_kernel = wire::pair_kernel_from_code(setup.pair_kernel)?;
    let kernel_choice = wire::kernel_from_code(setup.kernel)?;
    let panel_settings = PanelSettings::detect();
    let block = distance_block_with(kind, panel_settings);
    let sqrt_at_emit = block.compare_form_is_squared();
    let n = setup.n as usize;
    let ctx = WireCtx { d: setup.d as usize, part_sizes: setup.part_sizes.clone() };
    let chaos_exit_after: Option<u32> = std::env::var(CHAOS_EXIT_ENV)
        .ok()
        .and_then(|v| v.trim().parse().ok());
    let chaos_exit_on_fold = std::env::var(CHAOS_EXIT_ON_FOLD_ENV).is_ok();

    // Peer data plane: listener threads share the built-tree registry and
    // the fold inbox with this loop; the book and link cache stay here.
    let peer = Arc::new(PeerState::new(setup.part_sizes.len()));
    let peer_accept = peer_listener.map(|l| spawn_peer_server(l, Arc::clone(&peer)));
    let mut peer_book: Option<(Vec<PeerAddr>, Vec<u16>)> = None;
    let mut peer_conns: HashMap<u16, TcpStream> = HashMap::new();
    let peer_cfg = PeerCfg { connect_timeout: opts.peer_connect_timeout, read_deadline: liveness };
    // With liveness on, a fold degrade must land before the leader's own
    // deadline trips on the silent FoldDone — so wait at most half of it.
    let fold_wait = liveness.map_or(FOLD_WAIT, |t| (t / 2).max(Duration::from_millis(1)));

    let mut store: Vec<Option<Slot>> = Vec::new();
    store.resize_with(setup.part_sizes.len(), || None);
    let mut shard_report = (0u32, 0u64);
    if let Some(l) = loaded {
        for shard in l.shards {
            let k = shard.part as usize;
            if k >= store.len() {
                bail!("loaded shard {k} outside the {}-part run", store.len());
            }
            if shard.points.d != setup.d as usize
                || shard.ids.len() != setup.part_sizes[k] as usize
            {
                bail!("shard {k} shape disagrees with the leader's Setup");
            }
            shard_report.0 += 1;
            shard_report.1 += shard.local_payload_bytes();
            let aux = block.prepare(shard.points.as_slice(), shard.points.n, shard.points.d);
            store[k] = Some(Slot::new(shard.ids, shard.points, aux, None));
        }
    }
    // Built on first dense union solve; carries its own eval counter.
    let mut dense_kernel: Option<Box<dyn DenseMst>> = None;
    let counter = CountingMetric::new(kind);
    // Panel-reuse bookkeeping: the in-process PanelCache's exact policy
    // (shared KeyedLru), stats-only — the subset rows and aux values are
    // already resident here, so there is nothing to rebuild on a miss.
    let mut panel_lru: KeyedLru<()> = KeyedLru::new(PANEL_CACHE_CAP);

    let mut report = WorkerReport {
        worker_id: setup.worker_id,
        shards_loaded: shard_report.0,
        shard_local_bytes: shard_report.1,
        ..Default::default()
    };
    let mut pair_evals = 0u64;
    let mut busy = Duration::ZERO;
    let mut folded: Option<Vec<Edge>> = None;
    let mut panel_perf = PanelPerf::default();

    loop {
        let frame = match link_read(&mut stream, &mut chaos_link) {
            Ok(f) => f,
            Err(e) if super::is_timeout_kind(e.kind()) => bail!(
                "worker {}: leader link {}: no frame within the read deadline",
                setup.worker_id,
                super::STALL_MARK
            ),
            Err(e) => return Err(e).context("reading job frame"),
        };
        report.bytes_rx += frame.len() as u64;
        reg.add(Ctr::LinkRxBytes, frame.len() as u64);
        let msg = wire::decode(&frame, Some(&ctx))?;
        let reply = match msg {
            // Keepalive from the leader: exists only to arm our deadline —
            // and, when metrics are armed, the carrier wave for periodic
            // pushes: an idle worker still reports at heartbeat cadence.
            Message::Heartbeat => {
                if push_every.is_some_and(|every| last_push.elapsed() >= every) {
                    last_push = Instant::now();
                    push_metrics(
                        &mut stream,
                        &mut chaos_link,
                        &reg,
                        &peer,
                        &mut peer_tx_seen,
                        setup.worker_id,
                        &mut report,
                    );
                }
                continue;
            }
            Message::LocalJob { part, global_ids, points } => {
                let evals_before = counter.evals();
                let mut span =
                    crate::obs::span(crate::obs::SpanKind::LocalMst, setup.worker_id, part);
                let t = Instant::now();
                let aux = block.prepare(points.as_slice(), points.n, points.d);
                let tree =
                    subset_mst_gathered(&points, block.as_ref(), &aux, &counter, &global_ids);
                let compute = t.elapsed();
                let evals = counter.evals() - evals_before;
                span.set_arg(evals);
                drop(span);
                reg.observe(Hist::LocalMst, ns_of(compute));
                reg.add(Ctr::DistEvals, evals);
                report.local_jobs += 1;
                let k = part as usize;
                if k >= store.len() {
                    bail!("LocalJob for subset {k} outside the {}-part run", store.len());
                }
                store[k] = Some(Slot::new(global_ids, points, aux, Some(tree.clone())));
                peer.publish(k, &tree);
                Message::LocalDone { part, edges: tree, compute }
            }
            Message::LocalAssign { part } => {
                // Sharded phase 1: the subset is already resident from a
                // local shard file — only the tree needs computing.
                let slot = resident(&store, part, "LocalAssign")?;
                let evals_before = counter.evals();
                let mut span =
                    crate::obs::span(crate::obs::SpanKind::LocalMst, setup.worker_id, part);
                let t = Instant::now();
                let tree = subset_mst_gathered(
                    &slot.points,
                    block.as_ref(),
                    &slot.aux,
                    &counter,
                    &slot.ids,
                );
                let compute = t.elapsed();
                let evals = counter.evals() - evals_before;
                span.set_arg(evals);
                drop(span);
                reg.observe(Hist::LocalMst, ns_of(compute));
                reg.add(Ctr::DistEvals, evals);
                report.local_jobs += 1;
                let k = part as usize;
                store[k].as_mut().expect("resident checked").tree = Some(tree.clone());
                peer.publish(k, &tree);
                Message::LocalDone { part, edges: tree, compute }
            }
            Message::PairAssign { job, ships } => {
                if let Some(limit) = chaos_exit_after {
                    if report.jobs >= limit {
                        // Chaos hook: die like a SIGKILL — no reply, no
                        // shutdown handshake, socket torn down by the OS.
                        crate::obs::log!(
                            warn,
                            "worker {}: {CHAOS_EXIT_ENV}={limit} reached — exiting abruptly",
                            setup.worker_id
                        );
                        std::process::exit(113);
                    }
                }
                let mut fetch_failed = false;
                for ship in ships {
                    let SubsetShip { part, vectors, tree, routed } = ship;
                    if vectors.is_some() || tree.is_some() {
                        absorb(
                            &mut store,
                            block.as_ref(),
                            SubsetShip { part, vectors, tree, routed: false },
                        )?;
                    }
                    if routed {
                        // Pull the tree from its building anchor instead of
                        // the leader link (vectors, if any, rode inline above).
                        let mut fetch_span = crate::obs::span(
                            crate::obs::SpanKind::PeerFetch,
                            setup.worker_id,
                            part,
                        );
                        let t_fetch = Instant::now();
                        match fetch_routed(
                            part,
                            setup.worker_id,
                            peer_book.as_ref(),
                            &mut peer_conns,
                            &peer,
                            peer_cfg,
                        ) {
                            Ok(t) => {
                                // arg = the TreeShip reply's wire bytes
                                let rx_bytes = crate::coordinator::messages::HEADER_BYTES
                                    + (t.len() * Edge::WIRE_BYTES) as u64;
                                fetch_span.set_arg(rx_bytes);
                                reg.observe(Hist::PeerFetch, ns_of(t_fetch.elapsed()));
                                reg.add(Ctr::PeerRxBytes, rx_bytes);
                                absorb(
                                    &mut store,
                                    block.as_ref(),
                                    SubsetShip {
                                        part,
                                        vectors: None,
                                        tree: Some(t),
                                        routed: false,
                                    },
                                )?
                            }
                            Err(e) => {
                                crate::obs::log!(
                                    warn,
                                    "worker {}: peer fetch for subset {part} failed: {e:#}",
                                    setup.worker_id
                                );
                                fetch_failed = true;
                                break;
                            }
                        }
                    }
                }
                if fetch_failed {
                    // The job was NOT executed: hand it back to the leader's
                    // exactly-once lane for a tree-inline re-plan.
                    let frame = wire::encode(&Message::PairFail { job_id: job.id })?;
                    link_write(&mut stream, &mut chaos_link, &frame)
                        .context("sending PairFail")?;
                    report.bytes_tx += frame.len() as u64;
                    reg.add(Ctr::LinkTxBytes, frame.len() as u64);
                    continue;
                }
                let mut job_span =
                    crate::obs::span(crate::obs::SpanKind::Job, setup.worker_id, job.id);
                let (panel_flops_before, panel_time_before) =
                    (panel_perf.flops, panel_perf.time);
                let t = Instant::now();
                let (tree, evals) = match pair_kernel {
                    PairKernelChoice::BipartiteMerge => solve_bipartite(
                        &store,
                        &job,
                        block.as_ref(),
                        kind,
                        panel_settings,
                        sqrt_at_emit,
                        &mut panel_lru,
                        &mut panel_perf,
                    )?,
                    PairKernelChoice::Dense => {
                        let kernel = dense_kernel_mut(
                            &mut dense_kernel,
                            &kernel_choice,
                            kind,
                            &setup.artifacts_dir,
                        )?;
                        solve_dense_union(&store, &job, ctx.d, kernel)?
                    }
                };
                let compute = t.elapsed();
                job_span.set_arg(evals);
                drop(job_span);
                reg.observe_job(ns_of(compute), job.i, job.j);
                reg.add(Ctr::DistEvals, evals);
                // Per-job panel throughput in milli-GFLOP/s (= flops/ns
                // × 1000); the kernel only moves these on the panel path.
                let dflops = panel_perf.flops - panel_flops_before;
                let dns = ns_of(panel_perf.time - panel_time_before);
                if dflops > 0 && dns > 0 {
                    reg.observe(Hist::PanelGflops, dflops.saturating_mul(1_000) / dns);
                }
                pair_evals += evals;
                report.jobs += 1;
                busy += compute;
                if setup.reduce_tree {
                    folded = Some(match folded.take() {
                        None => tree,
                        Some(prev) => tree_merge(n, &prev, &tree),
                    });
                    Message::Ack { job_id: job.id }
                } else {
                    Message::Result {
                        job_id: job.id,
                        worker: setup.worker_id as usize,
                        edges: tree,
                        compute,
                    }
                }
            }
            Message::Job { job, global_ids, points } => {
                // Paper-literal union scatter: the dense kernel over the
                // pre-gathered union, ids mapped back to global.
                let kernel = dense_kernel_mut(
                    &mut dense_kernel,
                    &kernel_choice,
                    kind,
                    &setup.artifacts_dir,
                )?;
                let mut job_span =
                    crate::obs::span(crate::obs::SpanKind::Job, setup.worker_id, job.id);
                let before = kernel.dist_evals();
                let t = Instant::now();
                let local = kernel.mst(&points);
                let compute = t.elapsed();
                let evals = kernel.dist_evals() - before;
                job_span.set_arg(evals);
                drop(job_span);
                reg.observe_job(ns_of(compute), job.i, job.j);
                reg.add(Ctr::DistEvals, evals);
                pair_evals += evals;
                busy += compute;
                report.jobs += 1;
                let edges = local
                    .iter()
                    .map(|e| {
                        Edge::new(global_ids[e.u as usize], global_ids[e.v as usize], e.w)
                    })
                    .collect();
                Message::Result {
                    job_id: job.id,
                    worker: setup.worker_id as usize,
                    edges,
                    compute,
                }
            }
            Message::PeerBook { peers, builders } => {
                // Routing table for the peer plane; no reply — FIFO order
                // guarantees it lands before any routed PairAssign.
                peer_book = Some((peers, builders));
                continue;
            }
            Message::FoldShip { to, expect } => {
                if chaos_exit_on_fold {
                    // Chaos hook: die mid-fold — acked jobs are folded into
                    // a partial that now exists nowhere. The leader must
                    // return every one of them to the exactly-once lane.
                    crate::obs::log!(
                        warn,
                        "worker {}: {CHAOS_EXIT_ON_FOLD_ENV} set — exiting mid-fold",
                        setup.worker_id
                    );
                    std::process::exit(114);
                }
                let mut fold_span = crate::obs::span(
                    crate::obs::SpanKind::Fold,
                    setup.worker_id,
                    u32::from(expect),
                );
                let t_fold = Instant::now();
                // Wait for the expected peer partials (they were confirmed
                // shipped before this directive was sent, so the wait is a
                // delivery race, not a schedule dependency).
                let deadline = Instant::now() + fold_wait;
                let mut inbox = peer.inbox.lock().unwrap();
                while (inbox.len() as u16) < expect && Instant::now() < deadline {
                    let left = deadline.saturating_duration_since(Instant::now());
                    let (guard, _) = peer.arrived.wait_timeout(inbox, left).unwrap();
                    inbox = guard;
                }
                let got: Vec<Vec<Edge>> = inbox.drain(..).collect();
                drop(inbox);
                fold_span.set_arg(got.iter().map(|p| p.len() as u64).sum());
                let mut ok = got.len() as u16 >= expect;
                // Fold everything that DID arrive — those partials live only
                // here now, and ⊕ is idempotent, so folding them in is
                // always safe.
                for partial in got {
                    folded = Some(match folded.take() {
                        None => partial,
                        Some(prev) => tree_merge(n, &prev, &partial),
                    });
                }
                if ok && to != FOLD_KEEP {
                    let partial = folded.take().unwrap_or_default();
                    match ship_fold(
                        to,
                        setup.worker_id,
                        partial.clone(),
                        peer_book.as_ref(),
                        &mut peer_conns,
                        &peer,
                        peer_cfg,
                    ) {
                        Ok(()) => {}
                        Err(e) => {
                            crate::obs::log!(
                                warn,
                                "worker {}: fold ship to worker {to} failed: {e:#}",
                                setup.worker_id
                            );
                            folded = Some(partial); // keep it for WorkerDone
                            ok = false;
                        }
                    }
                }
                reg.observe(Hist::Fold, ns_of(t_fold.elapsed()));
                Message::FoldDone { ok }
            }
            Message::Shutdown => {
                // Wire contract (mirrors the in-process WorkerDone):
                // dist_evals covers the *pair phase* only — the leader
                // accounts the local-MST cache build separately. The human
                // exit report totals everything this process computed.
                report.dist_evals = pair_evals + counter.evals();
                report.peer_tx_bytes = peer.tx_bytes.load(Ordering::Relaxed);
                report.peer_ships = peer.ships.load(Ordering::Relaxed);
                // Drain the recording (if the Setup armed one) and ship the
                // spans piggybacked on WorkerDone. Chaos-fault spans were
                // recorded before this process learned its rank; stamp the
                // final rank onto every span so leader tracks stay coherent.
                let (spans, now_ns) = match obs_run {
                    Some(token) => {
                        let mut spans = crate::obs::end_run(token);
                        for s in &mut spans {
                            s.worker = setup.worker_id;
                        }
                        (spans, crate::obs::now_ns())
                    }
                    None => (Vec::new(), 0),
                };
                let chaos_faults = chaos_link
                    .as_ref()
                    .map_or(0, |c| c.faults_fired().min(u64::from(u32::MAX)) as u32);
                let metrics = setup
                    .metrics
                    .then(|| metrics_snapshot(&reg, &peer, &mut peer_tx_seen));
                let done = Message::WorkerDone {
                    worker: setup.worker_id as usize,
                    local_tree: folded.take(),
                    dist_evals: pair_evals,
                    busy,
                    jobs_run: report.jobs,
                    jobs_stolen: 0,
                    panel_hits: panel_lru.hits,
                    panel_misses: panel_lru.misses,
                    panel_flops: panel_perf.flops,
                    panel_time: panel_perf.time,
                    panel_threads: panel_perf.threads,
                    panel_isa: panel_perf.isa,
                    peer_tx_bytes: report.peer_tx_bytes,
                    peer_ships: report.peer_ships,
                    spans,
                    now_ns,
                    chaos_faults,
                    metrics,
                };
                let frame = wire::encode(&done)?;
                // Best-effort: a leader that already gave up must not turn a
                // clean drain into a worker error.
                if link_write(&mut stream, &mut chaos_link, &frame).is_ok() {
                    report.bytes_tx += frame.len() as u64;
                }
                peer.shutdown.store(true, Ordering::Relaxed);
                peer_conns.clear(); // closed links EOF the far handlers
                if let Some(t) = peer_accept {
                    let _ = t.join(); // bounded: the accept poll is 25 ms
                }
                return Ok(report);
            }
            other => bail!("unexpected frame from leader: {other:?}"),
        };
        // Piggyback a rate-limited MetricsPush ahead of the reply: drivers
        // blocked in recv absorb it and keep waiting for the reply proper,
        // so a busy run reports at job cadence even when the leader's
        // heartbeat pulse can't grab this link's mutex.
        if push_every.is_some_and(|every| last_push.elapsed() >= every) {
            last_push = Instant::now();
            push_metrics(
                &mut stream,
                &mut chaos_link,
                &reg,
                &peer,
                &mut peer_tx_seen,
                setup.worker_id,
                &mut report,
            );
        }
        let frame = wire::encode(&reply)?;
        link_write(&mut stream, &mut chaos_link, &frame).context("sending reply")?;
        report.bytes_tx += frame.len() as u64;
        reg.add(Ctr::LinkTxBytes, frame.len() as u64);
    }
}

/// Integrate one shipped subset section into the resident store.
fn absorb(store: &mut [Option<Slot>], block: &dyn DistanceBlock, ship: crate::coordinator::messages::SubsetShip) -> Result<()> {
    let k = ship.part as usize;
    if k >= store.len() {
        bail!("shipped subset {k} outside the {}-part run", store.len());
    }
    match (ship.vectors, ship.tree) {
        (Some((ids, points)), tree) => {
            let aux = block.prepare(points.as_slice(), points.n, points.d);
            store[k] = Some(Slot::new(ids, points, aux, tree));
        }
        (None, Some(tree)) => match &mut store[k] {
            Some(slot) => slot.tree = Some(tree),
            None => bail!("subset {k}: tree shipped before its vectors"),
        },
        (None, None) => bail!("subset {k}: empty ship section"),
    }
    Ok(())
}

fn resident<'a>(store: &'a [Option<Slot>], k: u32, what: &str) -> Result<&'a Slot> {
    store
        .get(k as usize)
        .and_then(|s| s.as_ref())
        .ok_or_else(|| anyhow!("{what}: subset {k} is not resident (leader ship model bug?)"))
}

/// The bipartite-merge pair kernel over resident subsets: one
/// `|S_i| × |S_j|` panel product + filtered Prim, exactly the in-process
/// [`crate::exec::BipartitePairSolver`] arithmetic. Returns the
/// emission-form tree and the distance evaluations performed; panel-kernel
/// witnesses (flops, wall time, threads, ISA) accumulate into `perf` for
/// the final `WorkerDone` frame.
#[allow(clippy::too_many_arguments)]
fn solve_bipartite(
    store: &[Option<Slot>],
    job: &PairJob,
    block: &dyn DistanceBlock,
    kind: crate::geometry::MetricKind,
    panel_settings: PanelSettings,
    sqrt_at_emit: bool,
    panel_lru: &mut KeyedLru<()>,
    perf: &mut PanelPerf,
) -> Result<(Vec<Edge>, u64)> {
    if job.i == job.j {
        // Degenerate self-pair: the cached local MST is the pair tree.
        let slot = resident(store, job.i, "self-pair job")?;
        let tree = slot
            .tree
            .as_ref()
            .ok_or_else(|| anyhow!("self-pair job: subset {} has no tree", job.i))?;
        return Ok((emit(tree, sqrt_at_emit), 0));
    }
    for part in [job.i, job.j] {
        panel_lru.ensure_with(part, || ());
    }
    let a = resident(store, job.i, "pair job")?;
    let b = resident(store, job.j, "pair job")?;
    let (ti, tj) = match (&a.tree, &b.tree) {
        (Some(ti), Some(tj)) => (ti, tj),
        _ => bail!("pair job ({}, {}): local MST missing on a resident subset", job.i, job.j),
    };
    let d = a.points.d;
    let (m, n) = (a.points.n, b.points.n);
    debug_assert_eq!(a.stride, b.stride, "pad_rows stride is a function of d alone");
    let mut blk = vec![0.0f32; m * n];
    let t = Instant::now();
    block.panel_block(&a.panel, &a.aux, m, &b.panel, &b.aux, n, d, a.stride, &mut blk);
    perf.time += t.elapsed();
    perf.flops += simd::panel_flops(kind, m, n, d);
    perf.threads = perf.threads.max(simd::planned_threads(panel_settings, m, n, d) as u32);
    perf.isa = panel_settings.isa.wire_code();
    let tree = bipartite_filtered_prim_blocked(&a.ids, &b.ids, ti, tj, &blk);
    Ok((emit(&tree, sqrt_at_emit), (m * n) as u64))
}

/// The dense pair kernel over resident subsets: merge the two gathered
/// subsets into one ascending-global-id union (the same packing
/// `decomp::algorithm::run_pair` produces from the full matrix) and run the
/// configured dense d-MST kernel over it.
fn solve_dense_union(
    store: &[Option<Slot>],
    job: &PairJob,
    d: usize,
    kernel: &dyn DenseMst,
) -> Result<(Vec<Edge>, u64)> {
    let a = resident(store, job.i, "dense pair job")?;
    let (ids, union) = if job.i == job.j {
        (a.ids.clone(), a.points.clone())
    } else {
        let b = resident(store, job.j, "dense pair job")?;
        merge_slots(a, b, d)
    };
    let before = kernel.dist_evals();
    let local = kernel.mst(&union);
    let evals = kernel.dist_evals() - before;
    let edges = local
        .iter()
        .map(|e| Edge::new(ids[e.u as usize], ids[e.v as usize], e.w))
        .collect();
    Ok((edges, evals))
}

/// Merge two resident subsets into one ascending-id packed union.
fn merge_slots(a: &Slot, b: &Slot, d: usize) -> (Vec<u32>, Dataset) {
    let m = a.ids.len() + b.ids.len();
    let mut ids = Vec::with_capacity(m);
    let mut data = Vec::with_capacity(m * d);
    let (mut x, mut y) = (0usize, 0usize);
    while x < a.ids.len() || y < b.ids.len() {
        let take_a = match (a.ids.get(x), b.ids.get(y)) {
            (Some(&ga), Some(&gb)) => ga < gb,
            (Some(_), None) => true,
            _ => false,
        };
        if take_a {
            ids.push(a.ids[x]);
            data.extend_from_slice(a.points.row(x));
            x += 1;
        } else {
            ids.push(b.ids[y]);
            data.extend_from_slice(b.points.row(y));
            y += 1;
        }
    }
    (ids, Dataset::new(m, d, data))
}

/// Compare-form → emission-form weights (`sqrt` for Euclid), matching the
/// in-process `emit_tree`.
fn emit(tree: &[Edge], sqrt_at_emit: bool) -> Vec<Edge> {
    if sqrt_at_emit {
        tree.iter().map(|e| Edge::new(e.u, e.v, e.w.sqrt())).collect()
    } else {
        tree.to_vec()
    }
}

/// Build the worker's dense kernel on first use, resolving artifacts
/// against the handshake-announced directory (the leader's `--artifacts`
/// path) so both sides see the same AOT set. A `boruvka-xla` request in a
/// build without the backend still degrades to the blocked Rust provider,
/// exactly like the leader's resolver does.
fn dense_kernel_mut<'a>(
    slot: &'a mut Option<Box<dyn DenseMst>>,
    choice: &crate::config::KernelChoice,
    kind: crate::geometry::MetricKind,
    artifacts_dir: &str,
) -> Result<&'a dyn DenseMst> {
    if slot.is_none() {
        let cfg = RunConfig {
            kernel: choice.clone(),
            metric: kind,
            artifacts_dir: std::path::PathBuf::from(artifacts_dir),
            ..Default::default()
        };
        *slot = Some(crate::coordinator::worker::build_kernel(&cfg)?);
    }
    Ok(slot.as_ref().expect("just built").as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{BipartiteCtx, BipartitePairSolver, LocalMstCache, PairSolver};
    use crate::exec::ExecPlan;
    use crate::geometry::MetricKind;
    use crate::net::wire::Setup;
    use crate::util::prng::Pcg64;
    use std::net::TcpListener;

    fn float_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        Dataset::new(n, d, data)
    }

    /// Drive one worker over a real loopback socket with a hand-rolled
    /// leader: LocalJob both subsets, a resident-only PairAssign, Shutdown —
    /// and check the pair tree is bit-identical to the in-process solver.
    #[test]
    fn worker_serves_bipartite_pair_bit_identical() {
        let ds = float_dataset(31, 40, 5);
        let plan = ExecPlan::new(&ds, 2, crate::decomp::PartitionStrategy::Block, 0);
        let part_sizes: Vec<u32> = plan.parts.iter().map(|p| p.len() as u32).collect();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || run(&addr.to_string(), Duration::from_secs(5)));

        let (mut s, _) = listener.accept().unwrap();
        s.set_nodelay(true).ok();
        // leader side of the handshake
        wire::decode_hello(&wire::read_frame(&mut s).unwrap()).unwrap();
        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: 0,
            n: ds.n as u32,
            d: ds.d as u16,
            metric: wire::metric_code(MetricKind::Euclid),
            kernel: 0,
            pair_kernel: wire::pair_kernel_code(crate::config::PairKernelChoice::BipartiteMerge),
            reduce_tree: false,
            mid_run: false,
            trace: false,
            // armed: the final WorkerDone must carry a metrics snapshot
            // (push cadence 0 = no periodic frames, final-only)
            metrics: true,
            manifest: 0,
            liveness_ms: 0,
            metrics_push_ms: 0,
            part_sizes: part_sizes.clone(),
            artifacts_dir: String::new(),
        };
        wire::write_frame(&mut s, &wire::encode_setup(&setup).unwrap()).unwrap();
        let ack = wire::decode_setup_ack(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert_eq!(ack.worker_id, 0);
        let adv = wire::decode_shard_advertise(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert!(adv.shard_ids.is_empty(), "unsharded worker advertises nothing");

        // phase 1: both subsets
        for (k, ids) in plan.parts.iter().enumerate() {
            let msg = Message::LocalJob {
                part: k as u32,
                global_ids: ids.clone(),
                points: ds.gather(ids),
            };
            wire::write_frame(&mut s, &wire::encode(&msg).unwrap()).unwrap();
            match wire::decode(&wire::read_frame(&mut s).unwrap(), None).unwrap() {
                Message::LocalDone { part, edges, .. } => {
                    assert_eq!(part as usize, k);
                    assert_eq!(edges.len(), ids.len() - 1);
                }
                other => panic!("expected LocalDone, got {other:?}"),
            }
        }
        // phase 2: everything resident — a bare PairAssign
        let job = PairJob { id: 0, i: 0, j: 1 };
        let pa = Message::PairAssign { job, ships: vec![] };
        assert_eq!(pa.wire_bytes(), 16, "resident job ships nothing");
        wire::write_frame(&mut s, &wire::encode(&pa).unwrap()).unwrap();
        let ctx = WireCtx { d: ds.d, part_sizes: part_sizes.clone() };
        let remote_tree =
            match wire::decode(&wire::read_frame(&mut s).unwrap(), Some(&ctx)).unwrap() {
                Message::Result { job_id, edges, .. } => {
                    assert_eq!(job_id, 0);
                    edges
                }
                other => panic!("expected Result, got {other:?}"),
            };
        wire::write_frame(&mut s, &wire::encode(&Message::Shutdown).unwrap()).unwrap();
        match wire::decode(&wire::read_frame(&mut s).unwrap(), None).unwrap() {
            Message::WorkerDone { dist_evals, metrics, .. } => {
                // pair phase only — the local-MST builds are accounted by
                // the leader's cache, exactly like the in-process path
                let expect = (plan.parts[0].len() * plan.parts[1].len()) as u64;
                assert_eq!(dist_evals, expect, "exactly one bipartite block");
                let snap = metrics.expect("armed setup ships a final snapshot");
                use crate::obs::metrics::{Ctr, Hist};
                assert_eq!(snap.counter(Ctr::JobsCompleted), 1);
                assert_eq!(snap.hist(Hist::JobLatency).count, 1);
                assert_eq!(snap.hist(Hist::LocalMst).count, 2, "two local builds");
                assert!(
                    snap.counter(Ctr::DistEvals) >= expect,
                    "registry counts pair + local evals"
                );
                assert_eq!(snap.slowest.map(|s| (s.i, s.j)), Some((0, 1)));
            }
            other => panic!("expected WorkerDone, got {other:?}"),
        }
        let report = worker.join().unwrap().unwrap();
        assert_eq!((report.jobs, report.local_jobs), (1, 2));
        assert!(report.bytes_rx > 0 && report.bytes_tx > 0);

        // in-process oracle over the full matrix
        let bctx = BipartiteCtx::new(&ds, MetricKind::Euclid);
        let cache = LocalMstCache::build_serial(&ds, &bctx, &plan.parts);
        let mut solver = BipartitePairSolver::new(&ds, &bctx, &cache);
        let local_tree = solver.solve(&plan, &job);
        assert_eq!(local_tree, remote_tree, "remote pair tree must be bit-identical");
    }

    /// Sharded worker: subsets come from local shard files, phase 1 is a
    /// 16-byte `LocalAssign`, the pair job ships nothing — and the tree is
    /// bit-identical to the in-process solver over the full matrix.
    #[test]
    fn sharded_worker_serves_from_local_files_bit_identical() {
        let dir = std::env::temp_dir().join("demst_worker_shard_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ds = float_dataset(77, 36, 4);
        let (manifest, manifest_path) = crate::shard::write_dataset_shards(
            &dir,
            "wtest",
            &ds,
            2,
            crate::decomp::PartitionStrategy::Block,
            0,
            MetricKind::SqEuclid,
        )
        .unwrap();
        let plan = ExecPlan::from_layout(manifest.layout());
        let part_sizes: Vec<u32> = plan.parts.iter().map(|p| p.len() as u32).collect();
        let fingerprint = manifest.fingerprint();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = WorkerOptions {
            shards: Some((manifest_path, vec![0, 1])),
            ..Default::default()
        };
        let worker =
            std::thread::spawn(move || run_with(&addr.to_string(), &opts));

        let (mut s, _) = listener.accept().unwrap();
        s.set_nodelay(true).ok();
        wire::decode_hello(&wire::read_frame(&mut s).unwrap()).unwrap();
        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: 0,
            n: ds.n as u32,
            d: ds.d as u16,
            metric: wire::metric_code(MetricKind::SqEuclid),
            kernel: 0,
            pair_kernel: wire::pair_kernel_code(crate::config::PairKernelChoice::BipartiteMerge),
            reduce_tree: false,
            mid_run: false,
            trace: false,
            metrics: false,
            liveness_ms: 0,
            metrics_push_ms: 0,
            manifest: fingerprint,
            part_sizes: part_sizes.clone(),
            artifacts_dir: String::new(),
        };
        wire::write_frame(&mut s, &wire::encode_setup(&setup).unwrap()).unwrap();
        wire::decode_setup_ack(&wire::read_frame(&mut s).unwrap()).unwrap();
        let adv = wire::decode_shard_advertise(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert_eq!(adv.shard_ids, vec![0, 1], "both shards advertised");

        // phase 1: header-only assigns — no vectors cross the wire
        for k in 0..2u32 {
            let la = Message::LocalAssign { part: k };
            assert_eq!(la.wire_bytes(), 16);
            wire::write_frame(&mut s, &wire::encode(&la).unwrap()).unwrap();
            match wire::decode(&wire::read_frame(&mut s).unwrap(), None).unwrap() {
                Message::LocalDone { part, edges, .. } => {
                    assert_eq!(part, k);
                    assert_eq!(edges.len(), part_sizes[k as usize] as usize - 1);
                }
                other => panic!("expected LocalDone, got {other:?}"),
            }
        }
        // phase 2: everything resident — a bare PairAssign
        let job = PairJob { id: 0, i: 0, j: 1 };
        wire::write_frame(
            &mut s,
            &wire::encode(&Message::PairAssign { job, ships: vec![] }).unwrap(),
        )
        .unwrap();
        let ctx = WireCtx { d: ds.d, part_sizes };
        let remote_tree =
            match wire::decode(&wire::read_frame(&mut s).unwrap(), Some(&ctx)).unwrap() {
                Message::Result { edges, .. } => edges,
                other => panic!("expected Result, got {other:?}"),
            };
        wire::write_frame(&mut s, &wire::encode(&Message::Shutdown).unwrap()).unwrap();
        wire::decode(&wire::read_frame(&mut s).unwrap(), None).unwrap();
        let report = worker.join().unwrap().unwrap();
        assert_eq!(report.shards_loaded, 2);
        assert!(report.shard_local_bytes > 0);

        let bctx = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        let cache = LocalMstCache::build_serial(&ds, &bctx, &plan.parts);
        let mut solver = BipartitePairSolver::new(&ds, &bctx, &cache);
        assert_eq!(solver.solve(&plan, &job), remote_tree, "bit-identical from shard files");
    }

    /// A worker whose shards fingerprint differently from the leader's
    /// manifest must refuse the run during the handshake.
    #[test]
    fn manifest_fingerprint_mismatch_fails_handshake() {
        let dir = std::env::temp_dir().join("demst_worker_shard_mismatch");
        std::fs::create_dir_all(&dir).unwrap();
        let ds = float_dataset(78, 24, 3);
        let (_, manifest_path) = crate::shard::write_dataset_shards(
            &dir,
            "mismatch",
            &ds,
            2,
            crate::decomp::PartitionStrategy::Block,
            0,
            MetricKind::SqEuclid,
        )
        .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let opts = WorkerOptions {
            shards: Some((manifest_path, vec![])),
            ..Default::default()
        };
        let worker = std::thread::spawn(move || run_with(&addr.to_string(), &opts));

        let (mut s, _) = listener.accept().unwrap();
        wire::decode_hello(&wire::read_frame(&mut s).unwrap()).unwrap();
        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: 0,
            n: ds.n as u32,
            d: ds.d as u16,
            metric: 0,
            kernel: 0,
            pair_kernel: 0,
            reduce_tree: false,
            mid_run: false,
            trace: false,
            metrics: false,
            liveness_ms: 0,
            metrics_push_ms: 0,
            manifest: 0xdead_0000_0000_0001, // some other partition run
            part_sizes: vec![12, 12],
            artifacts_dir: String::new(),
        };
        wire::write_frame(&mut s, &wire::encode_setup(&setup).unwrap()).unwrap();
        let err = worker.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("manifest mismatch"), "{err}");
    }

    /// A worker handed a `mid_run` Setup answers with the versioned
    /// `Join` + `ShardAdvertise`, waits for `AdmitAck`, skips heartbeats,
    /// and then serves exactly like a startup worker.
    #[test]
    fn mid_run_worker_joins_and_ignores_heartbeats() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || run(&addr.to_string(), Duration::from_secs(5)));

        let (mut s, _) = listener.accept().unwrap();
        s.set_nodelay(true).ok();
        wire::decode_hello(&wire::read_frame(&mut s).unwrap()).unwrap();
        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: 3,
            n: 8,
            d: 2,
            metric: 0,
            kernel: 0,
            pair_kernel: 0,
            reduce_tree: false,
            mid_run: true,
            trace: false,
            metrics: false,
            manifest: 0,
            liveness_ms: 0,
            metrics_push_ms: 0,
            part_sizes: vec![4, 4],
            artifacts_dir: String::new(),
        };
        wire::write_frame(&mut s, &wire::encode_setup(&setup).unwrap()).unwrap();
        let join = wire::decode_join(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert_eq!((join.worker_id, join.version), (3, WIRE_VERSION));
        let adv = wire::decode_shard_advertise(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert_eq!(adv.worker_id, 3);
        assert!(adv.shard_ids.is_empty());
        wire::write_frame(
            &mut s,
            &wire::encode_admit_ack(&wire::AdmitAck { worker_id: 3 }),
        )
        .unwrap();

        // heartbeats are transparent: the worker must still answer Shutdown
        wire::write_frame(&mut s, &wire::encode(&Message::Heartbeat).unwrap()).unwrap();
        wire::write_frame(&mut s, &wire::encode(&Message::Heartbeat).unwrap()).unwrap();
        wire::write_frame(&mut s, &wire::encode(&Message::Shutdown).unwrap()).unwrap();
        match wire::decode(&wire::read_frame(&mut s).unwrap(), None).unwrap() {
            Message::WorkerDone { worker, jobs_run, .. } => {
                assert_eq!((worker, jobs_run), (3, 0));
            }
            other => panic!("expected WorkerDone, got {other:?}"),
        }
        let report = worker.join().unwrap().unwrap();
        assert_eq!(report.worker_id, 3);
    }
}
