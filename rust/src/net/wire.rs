//! Length-prefixed binary framing for [`Message`], plus the versioned
//! connection handshake. **This module is the single source of truth for
//! wire sizes**: [`Message::wire_bytes`] delegates to [`encoded_len`], and
//! [`encode`] produces exactly that many bytes — so the simulated
//! transport's charges and the TCP transport's measured frames are the same
//! number by construction (`tests/proptests.rs` pins
//! `encode(m).len() == m.wire_bytes()` and `decode(encode(m)) == m` for
//! every variant).
//!
//! ## Frame layout
//!
//! Every frame is a fixed 16-byte header followed by `payload_len` bytes.
//! All integers are little-endian.
//!
//! ```text
//! [0..4]  payload_len: u32        (bytes after the header)
//! [4]     tag: u8                 (message type)
//! [5..16] per-tag routing/length fields (see the encoders below)
//! ```
//!
//! Variable-size payloads avoid embedded length fields wherever the length
//! is derivable — `Job`/`LocalJob` derive the id count from
//! `payload_len / (4 + 4d)`, and `PairAssign` derives every section length
//! from the handshake-announced partition sizes (a subset's local MST always
//! has exactly `|S_k| - 1` edges) — which is what lets the frame sizes equal
//! the engine's modeled scatter charges byte-for-byte.
//!
//! ## Wire limits (v5)
//!
//! `parts ≤ 65535`, `d ≤ 65535`, `workers ≤ 255` (per-job `Result` routing),
//! durations saturate at 2⁴⁸−1 ns (~3.2 days per job). [`RunConfig`]
//! validation rejects TCP configurations outside these bounds up front.
//! Handshake frames are additionally capped at [`MAX_HANDSHAKE_PAYLOAD`]
//! bytes, so a hostile or confused peer cannot make the handshake path
//! allocate a gigabyte from a forged length field.
//!
//! ## v7 additions (fleet metrics plane)
//!
//! - [`WorkerDone`](Message::WorkerDone)'s spare stats word becomes
//!   `metrics_bytes`: when the [`Setup`] metrics flag (bit 3) armed the
//!   run, a compact [`crate::obs::metrics::Snapshot`] block (counters,
//!   gauges, occupied histogram buckets) rides between the span block and
//!   the tree. Metrics-off runs ship 0 bytes there, so default byte models
//!   are unchanged.
//! - [`MetricsPush`](Message::MetricsPush) (tag 22) carries a periodic
//!   *cumulative* snapshot for the leader's live `/metrics` exposition.
//!   Like `Heartbeat` it is never acked and never a window credit.
//! - [`Setup`] gains the metrics flag and `metrics_push_ms` (the push
//!   cadence), growing its fixed body from 20 to 24 bytes.
//!
//! ## v5 additions (liveness + mid-run admission)
//!
//! - [`Heartbeat`](Message::Heartbeat) is a header-only keepalive. The
//!   leader multiplexes it over every **idle** link (default every
//!   `liveness_timeout / 3`); both ends run their post-handshake reads
//!   under a `liveness_timeout` read deadline instead of blocking forever,
//!   so a hung-but-alive peer (half-open socket, stalled fetch) is
//!   *detected* and demoted through the exactly-once return lane rather
//!   than wedging the run. Heartbeats are never acked and carry no state —
//!   receivers skip them.
//! - [`Setup`] carries `liveness_ms` (the fleet-wide read deadline, 0 =
//!   disabled) and a `mid_run` flag (header bit 1): a worker connecting to
//!   an **already-running** leader gets `mid_run = true` and answers with
//!   [`Join`] instead of [`SetupAck`], then advertises its shards exactly
//!   like startup, and must not serve until the leader's [`AdmitAck`]
//!   confirms the admission (the leader may still refuse a mis-sharded or
//!   version-skewed joiner at this point).
//!
//! ## v4 additions (peer data plane + reduction topologies)
//!
//! - [`Hello`] carries the worker's **peer listener port**: every worker
//!   binds a worker↔worker listener before connecting, and the leader pairs
//!   the advertised port with the connection's source address to build the
//!   fleet's [`PeerBook`](Message::PeerBook) (sent only when the peer data
//!   plane is active, so default runs stay byte-identical to v3 traffic).
//! - `PairAssign` gains **routed-tree flag bits** (bits 4/5): the section
//!   ships *zero* payload bytes and the executing worker instead pulls the
//!   subset's cached local MST from its building anchor over a peer link
//!   (`PeerHello` once per link, then `TreeFetch` → `TreeShip`).
//! - `TreeShip` doubles as the ⊕-reduction hop (`fold` kind bit): under
//!   `reduce_topology ∈ {tree, ring}` the leader sends header-only
//!   [`FoldShip`](Message::FoldShip) directives and workers fold partial
//!   MSFs among themselves; only the root worker's `WorkerDone` carries a
//!   tree. The `Ack` header gains a status byte (`ok` / `pair-fail` /
//!   `fold-ok` / `fold-fail`) so a dead peer degrades to leader-assisted
//!   recovery instead of wedging the run.
//! - [`WorkerDone`](Message::WorkerDone)'s stats block grows from 64 to 80
//!   bytes: `peer_tx_bytes` (u64) and `peer_ships` (u32) witness the peer
//!   plane's actual traffic (plus 4 spare bytes).
//!
//! ## v3 additions (panel-kernel witnesses)
//!
//! [`WorkerDone`](Message::WorkerDone)'s stats block grows from 40 to 64
//! bytes: `panel_flops` (u64), `panel_time` (u64 nanos), `panel_threads`
//! (u32), and `panel_isa` (u32 holding a [`crate::geometry::Isa`] wire
//! code, 0 = no panels ran) — the SIMD kernel witnesses the leader folds
//! into its run metrics.
//!
//! ## v2 additions (sharded residency + pipelined dispatch)
//!
//! - [`Setup`] carries the leader's shard-manifest fingerprint (0 on
//!   unsharded runs) so a worker that loaded shards cut from a different
//!   partition fails the handshake instead of computing a wrong tree.
//! - The handshake ends with a worker → leader [`ShardAdvertise`] frame
//!   naming the subset ids the worker loaded from local shard files
//!   (empty when unsharded) — the seed of the leader's resident-set model.
//! - `LocalAssign` (header-only) tells a sharded worker to build one
//!   resident subset's local MST without any vectors on the wire.
//! - Dispatch is windowed: the leader may put up to `pipeline_window`
//!   `PairAssign` frames on a link before reading the matching
//!   `Result`/`Ack` replies, which double as the window credits. Workers
//!   serve frames strictly in order, so replies stay FIFO per link and no
//!   new ack frame type is needed.
//!
//! [`RunConfig`]: crate::config::RunConfig

use crate::config::{KernelChoice, PairKernelChoice};
use crate::coordinator::messages::{Message, SubsetShip, HEADER_BYTES};
use crate::data::Dataset;
use crate::decomp::PairJob;
use crate::geometry::MetricKind;
use crate::graph::Edge;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::time::Duration;

/// Protocol version, checked during the handshake.
pub const WIRE_VERSION: u16 = 7;
/// Handshake magic ("DMST").
pub const MAGIC: u32 = 0x444D_5354;
/// Refuse to allocate frames beyond this payload size (corrupt peer guard).
pub const MAX_PAYLOAD: u32 = 1 << 30;
/// Tighter payload cap for handshake-phase frames (`Hello`/`Setup`/
/// `SetupAck`/`Join`/`AdmitAck`/`ShardAdvertise`): the largest legitimate
/// handshake frame is a `Setup` with 65535 part sizes plus an artifacts
/// path — well under 1 MiB — so pre-handshake reads never trust a forged
/// length field beyond this.
pub const MAX_HANDSHAKE_PAYLOAD: u32 = 1 << 20;

const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_JOB: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_WORKER_DONE: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_LOCAL_JOB: u8 = 7;
const TAG_LOCAL_DONE: u8 = 8;
const TAG_PAIR_ASSIGN: u8 = 9;
const TAG_ACK: u8 = 10;
const TAG_SETUP_ACK: u8 = 11;
const TAG_SHARD_ADVERTISE: u8 = 12;
const TAG_LOCAL_ASSIGN: u8 = 13;
const TAG_PEER_HELLO: u8 = 14;
const TAG_TREE_FETCH: u8 = 15;
const TAG_TREE_SHIP: u8 = 16;
const TAG_FOLD_SHIP: u8 = 17;
const TAG_PEER_BOOK: u8 = 18;
const TAG_HEARTBEAT: u8 = 19;
const TAG_JOIN: u8 = 20;
const TAG_ADMIT_ACK: u8 = 21;
const TAG_METRICS_PUSH: u8 = 22;

// `Ack`-tag status codes (header byte [5]); one reply frame shape covers
// the whole pair/fold lane so the FIFO window credits stay uniform.
const ACK_OK: u8 = 0;
const ACK_PAIR_FAIL: u8 = 1;
const ACK_FOLD_OK: u8 = 2;
const ACK_FOLD_FAIL: u8 = 3;

const EDGE_BYTES: u64 = Edge::WIRE_BYTES as u64;
/// v6 `WorkerDone` stats-block bytes (v4/v5 was 80; +4 `span_count`
/// replacing the spare word, +8 `now_ns`, +4 `chaos_faults`, +4 spare).
pub const STATS_BYTES: u64 = 96;
/// Bytes of one telemetry span record in a `WorkerDone` payload: kind,
/// pad, worker, id, arg, start_ns, end_ns.
pub const SPAN_BYTES: u64 = 32;
/// Bytes of one [`crate::coordinator::messages::PeerAddr`] entry in a
/// `PeerBook` payload: family byte, pad, port, 16 address bytes.
pub const PEER_ENTRY_BYTES: u64 = 20;
const MAX_U48: u64 = (1 << 48) - 1;

/// Bytes of one vectors section: global-id map + row-major f32 rows.
pub fn vectors_payload_bytes(ids: usize, d: usize) -> u64 {
    ids as u64 * 4 + (ids * d) as u64 * 4
}

/// Exact frame length (header + payload) of `msg`'s encoding. This is the
/// arithmetic [`Message::wire_bytes`] reports and [`encode`] realizes.
pub fn encoded_len(msg: &Message) -> u64 {
    HEADER_BYTES
        + match msg {
            Message::Job { global_ids, points, .. } => {
                vectors_payload_bytes(global_ids.len(), points.d)
            }
            Message::LocalJob { global_ids, points, .. } => {
                vectors_payload_bytes(global_ids.len(), points.d)
            }
            Message::PairAssign { ships, .. } => ships
                .iter()
                .map(|s| {
                    // a routed tree is flag bits only — the payload travels
                    // worker↔worker as a `TreeShip`, never in this frame
                    s.vectors
                        .as_ref()
                        .map_or(0, |(ids, pts)| vectors_payload_bytes(ids.len(), pts.d))
                        + s.tree.as_ref().map_or(0, |t| t.len() as u64 * EDGE_BYTES)
                })
                .sum::<u64>(),
            Message::LocalDone { edges, .. } => edges.len() as u64 * EDGE_BYTES,
            Message::Result { edges, .. } => edges.len() as u64 * EDGE_BYTES,
            Message::TreeShip { edges, .. } => edges.len() as u64 * EDGE_BYTES,
            Message::PeerBook { peers, builders } => {
                peers.len() as u64 * PEER_ENTRY_BYTES + builders.len() as u64 * 2
            }
            Message::WorkerDone { local_tree, spans, metrics, .. } => {
                STATS_BYTES
                    + spans.len() as u64 * SPAN_BYTES
                    + metrics.as_ref().map_or(0, |m| m.wire_bytes())
                    + local_tree.as_ref().map_or(0, |t| t.len() as u64 * EDGE_BYTES)
            }
            Message::MetricsPush { snap, .. } => snap.wire_bytes(),
            Message::Ack { .. }
            | Message::PairFail { .. }
            | Message::FoldDone { .. }
            | Message::LocalAssign { .. }
            | Message::PeerHello { .. }
            | Message::TreeFetch { .. }
            | Message::FoldShip { .. }
            | Message::Heartbeat
            | Message::Shutdown => 0,
        }
}

/// Decode context for leader→worker frames whose payload lengths are
/// derived from the handshake-announced partition layout.
#[derive(Clone, Debug)]
pub struct WireCtx {
    pub d: usize,
    pub part_sizes: Vec<u32>,
}

// ---------------------------------------------------------------- encoding

struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    fn new(tag: u8, payload_len: u64) -> Result<Self> {
        if payload_len > MAX_PAYLOAD as u64 {
            bail!("frame payload {payload_len} exceeds wire limit {MAX_PAYLOAD}");
        }
        let mut buf = Vec::with_capacity(HEADER_BYTES as usize + payload_len as usize);
        buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
        buf.push(tag);
        buf.resize(HEADER_BYTES as usize, 0);
        Ok(Self { buf })
    }

    fn set_u8(&mut self, at: usize, v: u8) {
        self.buf[at] = v;
    }

    fn set_u16(&mut self, at: usize, v: u16) {
        self.buf[at..at + 2].copy_from_slice(&v.to_le_bytes());
    }

    fn set_u32(&mut self, at: usize, v: u32) {
        self.buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// 48-bit duration in nanoseconds (saturating), at `at..at+6`.
    fn set_dur48(&mut self, at: usize, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX).min(MAX_U48);
        self.buf[at..at + 6].copy_from_slice(&ns.to_le_bytes()[..6]);
    }

    fn push_u32s(&mut self, vals: &[u32]) {
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn push_f32s(&mut self, vals: &[f32]) {
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn push_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn push_edges(&mut self, edges: &[Edge]) {
        for e in edges {
            self.buf.extend_from_slice(&e.u.to_le_bytes());
            self.buf.extend_from_slice(&e.v.to_le_bytes());
            self.buf.extend_from_slice(&e.w.to_le_bytes());
        }
    }
}

fn need_u16(v: usize, what: &str) -> Result<u16> {
    u16::try_from(v).map_err(|_| anyhow!("{what} {v} exceeds wire limit 65535"))
}

fn need_u8(v: usize, what: &str) -> Result<u8> {
    u8::try_from(v).map_err(|_| anyhow!("{what} {v} exceeds wire limit 255"))
}

fn push_vectors(f: &mut FrameBuf, ids: &[u32], points: &Dataset, what: &str) -> Result<()> {
    if ids.len() != points.n {
        bail!("{what}: id map length {} != point rows {}", ids.len(), points.n);
    }
    f.push_u32s(ids);
    f.push_f32s(points.as_slice());
    Ok(())
}

/// Encode one message into a complete frame (header + payload). The result
/// is exactly [`encoded_len`] bytes long.
pub fn encode(msg: &Message) -> Result<Vec<u8>> {
    let total = encoded_len(msg);
    let payload = total - HEADER_BYTES;
    let mut f = match msg {
        Message::Job { job, global_ids, points } => {
            let mut f = FrameBuf::new(TAG_JOB, payload)?;
            f.set_u16(6, need_u16(points.d, "dimension d")?);
            f.set_u32(8, job.id);
            f.set_u16(12, need_u16(job.i as usize, "subset index i")?);
            f.set_u16(14, need_u16(job.j as usize, "subset index j")?);
            push_vectors(&mut f, global_ids, points, "Job")?;
            f
        }
        Message::LocalJob { part, global_ids, points } => {
            let mut f = FrameBuf::new(TAG_LOCAL_JOB, payload)?;
            f.set_u16(6, need_u16(points.d, "dimension d")?);
            f.set_u32(8, *part);
            push_vectors(&mut f, global_ids, points, "LocalJob")?;
            f
        }
        Message::PairAssign { job, ships } => {
            let mut f = FrameBuf::new(TAG_PAIR_ASSIGN, payload)?;
            let mut flags = 0u8;
            let mut d = 0usize;
            // Payload order is fixed: subset i's vectors, subset i's tree,
            // then subset j's — the flag bits say which sections exist and
            // the handshake-announced sizes say how long each one is.
            let slots: &[u32] = if job.i == job.j { &[job.i] } else { &[job.i, job.j] };
            if ships.len() > slots.len() {
                bail!("PairAssign carries {} ships for a {}-subset job", ships.len(), slots.len());
            }
            let mut at = 0usize;
            for ship in ships {
                let slot = slots[at..]
                    .iter()
                    .position(|&k| k == ship.part)
                    .ok_or_else(|| {
                        anyhow!("PairAssign ship for subset {} not in job ({}, {})", ship.part, job.i, job.j)
                    })?;
                at += slot + 1;
                let bit = at - 1; // 0 = subset i, 1 = subset j
                if ship.vectors.is_none() && ship.tree.is_none() && !ship.routed {
                    bail!("PairAssign ship for subset {} is empty", ship.part);
                }
                if ship.routed && ship.tree.is_some() {
                    bail!("PairAssign ship for subset {} both routes and carries its tree", ship.part);
                }
                if let Some((ids, pts)) = &ship.vectors {
                    flags |= 1 << bit;
                    d = pts.d;
                    push_vectors(&mut f, ids, pts, "PairAssign")?;
                }
                if let Some(tree) = &ship.tree {
                    flags |= 1 << (2 + bit);
                    f.push_edges(tree);
                }
                if ship.routed {
                    // no payload: the worker pulls the tree from the
                    // subset's building anchor over its peer link
                    flags |= 1 << (4 + bit);
                }
            }
            f.set_u8(5, flags);
            f.set_u16(6, need_u16(d, "dimension d")?);
            f.set_u32(8, job.id);
            f.set_u16(12, need_u16(job.i as usize, "subset index i")?);
            f.set_u16(14, need_u16(job.j as usize, "subset index j")?);
            f
        }
        Message::LocalDone { part, edges, compute } => {
            let mut f = FrameBuf::new(TAG_LOCAL_DONE, payload)?;
            f.set_dur48(6, *compute);
            f.set_u32(12, *part);
            f.push_edges(edges);
            f
        }
        Message::Result { job_id, worker, edges, compute } => {
            let mut f = FrameBuf::new(TAG_RESULT, payload)?;
            f.set_u8(5, need_u8(*worker, "worker id")?);
            f.set_dur48(6, *compute);
            f.set_u32(12, *job_id);
            f.push_edges(edges);
            f
        }
        Message::Ack { job_id } => {
            let mut f = FrameBuf::new(TAG_ACK, payload)?;
            f.set_u8(5, ACK_OK);
            f.set_u32(8, *job_id);
            f
        }
        Message::PairFail { job_id } => {
            let mut f = FrameBuf::new(TAG_ACK, payload)?;
            f.set_u8(5, ACK_PAIR_FAIL);
            f.set_u32(8, *job_id);
            f
        }
        Message::FoldDone { ok } => {
            let mut f = FrameBuf::new(TAG_ACK, payload)?;
            f.set_u8(5, if *ok { ACK_FOLD_OK } else { ACK_FOLD_FAIL });
            f
        }
        Message::LocalAssign { part } => {
            let mut f = FrameBuf::new(TAG_LOCAL_ASSIGN, payload)?;
            f.set_u32(8, *part);
            f
        }
        Message::PeerHello { from } => {
            let mut f = FrameBuf::new(TAG_PEER_HELLO, payload)?;
            f.set_u16(6, *from);
            f.set_u32(8, MAGIC);
            f
        }
        Message::TreeFetch { part } => {
            let mut f = FrameBuf::new(TAG_TREE_FETCH, payload)?;
            f.set_u32(8, *part);
            f
        }
        Message::TreeShip { part, fold, edges } => {
            let mut f = FrameBuf::new(TAG_TREE_SHIP, payload)?;
            f.set_u8(5, *fold as u8);
            f.set_u32(8, *part);
            f.push_edges(edges);
            f
        }
        Message::FoldShip { to, expect } => {
            let mut f = FrameBuf::new(TAG_FOLD_SHIP, payload)?;
            f.set_u16(6, *to);
            f.set_u16(8, *expect);
            f
        }
        Message::PeerBook { peers, builders } => {
            let mut f = FrameBuf::new(TAG_PEER_BOOK, payload)?;
            f.set_u16(6, need_u16(peers.len(), "peer-book worker count")?);
            f.set_u16(8, need_u16(builders.len(), "peer-book builder count")?);
            for p in peers {
                let mut entry = [0u8; PEER_ENTRY_BYTES as usize];
                entry[2..4].copy_from_slice(&p.port.to_le_bytes());
                match p.ip {
                    std::net::IpAddr::V4(v4) => {
                        entry[0] = 4;
                        entry[4..8].copy_from_slice(&v4.octets());
                    }
                    std::net::IpAddr::V6(v6) => {
                        entry[0] = 6;
                        entry[4..20].copy_from_slice(&v6.octets());
                    }
                }
                f.buf.extend_from_slice(&entry);
            }
            for b in builders {
                f.buf.extend_from_slice(&b.to_le_bytes());
            }
            f
        }
        Message::WorkerDone {
            worker,
            local_tree,
            dist_evals,
            busy,
            jobs_run,
            jobs_stolen,
            panel_hits,
            panel_misses,
            panel_flops,
            panel_time,
            panel_threads,
            panel_isa,
            peer_tx_bytes,
            peer_ships,
            spans,
            now_ns,
            chaos_faults,
            metrics,
        } => {
            let span_count = u32::try_from(spans.len())
                .map_err(|_| anyhow!("WorkerDone span count exceeds u32"))?;
            let metrics_block = metrics.as_ref().map(|m| m.encode());
            let metrics_bytes = u32::try_from(metrics_block.as_ref().map_or(0, |b| b.len()))
                .map_err(|_| anyhow!("WorkerDone metrics block exceeds u32"))?;
            let mut f = FrameBuf::new(TAG_WORKER_DONE, payload)?;
            f.set_u8(5, local_tree.is_some() as u8);
            f.set_u16(6, need_u16(*worker, "worker id")?);
            f.push_u64(*dist_evals);
            f.push_u64(u64::try_from(busy.as_nanos()).unwrap_or(u64::MAX));
            f.push_u32s(&[*jobs_run, *jobs_stolen]);
            f.push_u64(*panel_hits);
            f.push_u64(*panel_misses);
            f.push_u64(*panel_flops);
            f.push_u64(u64::try_from(panel_time.as_nanos()).unwrap_or(u64::MAX));
            f.push_u32s(&[*panel_threads, *panel_isa as u32]);
            f.push_u64(*peer_tx_bytes);
            f.push_u32s(&[*peer_ships, span_count]);
            f.push_u64(*now_ns);
            f.push_u32s(&[*chaos_faults, metrics_bytes]);
            for s in spans {
                f.buf.push(s.kind_code);
                f.buf.push(0); // pad
                f.buf.extend_from_slice(&s.worker.to_le_bytes());
                f.buf.extend_from_slice(&s.id.to_le_bytes());
                f.push_u64(s.arg);
                f.push_u64(s.start_ns);
                f.push_u64(s.end_ns);
            }
            if let Some(block) = &metrics_block {
                f.buf.extend_from_slice(block);
            }
            if let Some(tree) = local_tree {
                f.push_edges(tree);
            }
            f
        }
        Message::MetricsPush { worker, snap } => {
            let mut f = FrameBuf::new(TAG_METRICS_PUSH, payload)?;
            f.set_u16(6, *worker);
            f.buf.extend_from_slice(&snap.encode());
            f
        }
        Message::Heartbeat => FrameBuf::new(TAG_HEARTBEAT, payload)?,
        Message::Shutdown => FrameBuf::new(TAG_SHUTDOWN, payload)?,
    };
    debug_assert_eq!(f.buf.len() as u64, total, "encoder drifted from encoded_len");
    f.buf.truncate(total as usize); // defensive; lengths asserted above
    Ok(f.buf)
}

// ---------------------------------------------------------------- decoding

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.buf.len());
        let end = end.ok_or_else(|| {
            anyhow!("frame truncated: wanted {n} bytes at offset {}, have {}", self.at, self.buf.len())
        })?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8_at(&self, at: usize) -> u8 {
        self.buf[at]
    }

    fn u16_at(&self, at: usize) -> u16 {
        u16::from_le_bytes(self.buf[at..at + 2].try_into().unwrap())
    }

    fn u32_at(&self, at: usize) -> u32 {
        u32::from_le_bytes(self.buf[at..at + 4].try_into().unwrap())
    }

    fn dur48_at(&self, at: usize) -> Duration {
        let mut b = [0u8; 8];
        b[..6].copy_from_slice(&self.buf[at..at + 6]);
        Duration::from_nanos(u64::from_le_bytes(b))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Everything left in the payload (trailing variable-length sections).
    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.at..];
        self.at = self.buf.len();
        s
    }

    fn edges(&mut self, n: usize) -> Result<Vec<Edge>> {
        let raw = self.take(n * 12)?;
        Ok(raw
            .chunks_exact(12)
            .map(|c| Edge {
                u: u32::from_le_bytes(c[0..4].try_into().unwrap()),
                v: u32::from_le_bytes(c[4..8].try_into().unwrap()),
                w: f32::from_le_bytes(c[8..12].try_into().unwrap()),
            })
            .collect())
    }

    fn vectors(&mut self, rows: usize, d: usize) -> Result<(Vec<u32>, Dataset)> {
        let ids = self.u32s(rows)?;
        let data = self.f32s(rows * d)?;
        Ok((ids, Dataset::new(rows, d, data)))
    }

    fn done(&self, what: &str) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{what}: {} trailing bytes after payload", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Id count of a vectors-only payload (`Job` / `LocalJob`): the payload is
/// `ids·4 + ids·d·4` bytes, so `ids = payload / (4 + 4d)`.
fn derive_rows(payload: usize, d: usize, what: &str) -> Result<usize> {
    let per = 4 + 4 * d;
    if payload % per != 0 {
        bail!("{what}: payload {payload} not a multiple of per-row {per} (d = {d})");
    }
    Ok(payload / per)
}

/// Decode one complete frame back into a [`Message`]. `ctx` (the
/// handshake-announced partition layout) is required for `PairAssign`
/// frames, whose section lengths are derived rather than embedded.
pub fn decode(frame: &[u8], ctx: Option<&WireCtx>) -> Result<Message> {
    if frame.len() < HEADER_BYTES as usize {
        bail!("short frame: {} bytes", frame.len());
    }
    let r0 = Reader::new(frame);
    let payload_len = r0.u32_at(0) as usize;
    if frame.len() != HEADER_BYTES as usize + payload_len {
        bail!(
            "frame length {} != header-declared {}",
            frame.len(),
            HEADER_BYTES as usize + payload_len
        );
    }
    let tag = r0.u8_at(4);
    let mut r = Reader::new(&frame[HEADER_BYTES as usize..]);
    let msg = match tag {
        TAG_JOB => {
            let d = r0.u16_at(6) as usize;
            let rows = derive_rows(payload_len, d, "Job")?;
            let (global_ids, points) = r.vectors(rows, d)?;
            Message::Job {
                job: PairJob {
                    id: r0.u32_at(8),
                    i: r0.u16_at(12) as u32,
                    j: r0.u16_at(14) as u32,
                },
                global_ids,
                points,
            }
        }
        TAG_LOCAL_JOB => {
            let d = r0.u16_at(6) as usize;
            let rows = derive_rows(payload_len, d, "LocalJob")?;
            let (global_ids, points) = r.vectors(rows, d)?;
            Message::LocalJob { part: r0.u32_at(8), global_ids, points }
        }
        TAG_PAIR_ASSIGN => {
            let ctx = ctx.ok_or_else(|| anyhow!("PairAssign frame needs a decode context"))?;
            let flags = r0.u8_at(5);
            let d = r0.u16_at(6) as usize;
            let job = PairJob {
                id: r0.u32_at(8),
                i: r0.u16_at(12) as u32,
                j: r0.u16_at(14) as u32,
            };
            let slots: &[u32] = if job.i == job.j { &[job.i] } else { &[job.i, job.j] };
            let mut ships = Vec::new();
            for (bit, &part) in slots.iter().enumerate() {
                let size = *ctx
                    .part_sizes
                    .get(part as usize)
                    .ok_or_else(|| anyhow!("PairAssign subset {part} outside partition"))?
                    as usize;
                let vectors = if flags & (1 << bit) != 0 {
                    Some(r.vectors(size, d)?)
                } else {
                    None
                };
                let tree = if flags & (1 << (2 + bit)) != 0 {
                    Some(r.edges(size.saturating_sub(1))?)
                } else {
                    None
                };
                let routed = flags & (1 << (4 + bit)) != 0;
                if routed && tree.is_some() {
                    bail!("PairAssign subset {part} both routed and tree-carrying");
                }
                if vectors.is_some() || tree.is_some() || routed {
                    ships.push(SubsetShip { part, vectors, tree, routed });
                }
            }
            r.done("PairAssign")?;
            Message::PairAssign { job, ships }
        }
        TAG_LOCAL_DONE => Message::LocalDone {
            part: r0.u32_at(12),
            compute: r0.dur48_at(6),
            edges: r.edges(derive_edges(payload_len, "LocalDone")?)?,
        },
        TAG_RESULT => Message::Result {
            job_id: r0.u32_at(12),
            worker: r0.u8_at(5) as usize,
            compute: r0.dur48_at(6),
            edges: r.edges(derive_edges(payload_len, "Result")?)?,
        },
        TAG_ACK => match r0.u8_at(5) {
            ACK_OK => Message::Ack { job_id: r0.u32_at(8) },
            ACK_PAIR_FAIL => Message::PairFail { job_id: r0.u32_at(8) },
            ACK_FOLD_OK => Message::FoldDone { ok: true },
            ACK_FOLD_FAIL => Message::FoldDone { ok: false },
            other => bail!("unknown ack status {other}"),
        },
        TAG_LOCAL_ASSIGN => Message::LocalAssign { part: r0.u32_at(8) },
        TAG_PEER_HELLO => {
            if r0.u32_at(8) != MAGIC {
                bail!("peer-hello magic mismatch: peer is not a demst worker");
            }
            Message::PeerHello { from: r0.u16_at(6) }
        }
        TAG_TREE_FETCH => Message::TreeFetch { part: r0.u32_at(8) },
        TAG_TREE_SHIP => Message::TreeShip {
            part: r0.u32_at(8),
            fold: r0.u8_at(5) & 1 != 0,
            edges: r.edges(derive_edges(payload_len, "TreeShip")?)?,
        },
        TAG_FOLD_SHIP => Message::FoldShip { to: r0.u16_at(6), expect: r0.u16_at(8) },
        TAG_PEER_BOOK => {
            let n_peers = r0.u16_at(6) as usize;
            let n_builders = r0.u16_at(8) as usize;
            let mut peers = Vec::with_capacity(n_peers);
            for _ in 0..n_peers {
                let entry = r.take(PEER_ENTRY_BYTES as usize)?;
                let port = u16::from_le_bytes(entry[2..4].try_into().unwrap());
                let ip: std::net::IpAddr = match entry[0] {
                    4 => {
                        let o: [u8; 4] = entry[4..8].try_into().unwrap();
                        std::net::Ipv4Addr::from(o).into()
                    }
                    6 => {
                        let o: [u8; 16] = entry[4..20].try_into().unwrap();
                        std::net::Ipv6Addr::from(o).into()
                    }
                    other => bail!("peer-book entry has unknown address family {other}"),
                };
                peers.push(crate::coordinator::messages::PeerAddr { ip, port });
            }
            let mut builders = Vec::with_capacity(n_builders);
            for _ in 0..n_builders {
                let raw = r.take(2)?;
                builders.push(u16::from_le_bytes(raw.try_into().unwrap()));
            }
            Message::PeerBook { peers, builders }
        }
        TAG_WORKER_DONE => {
            let has_tree = r0.u8_at(5) & 1 != 0;
            let worker = r0.u16_at(6) as usize;
            let dist_evals = r.u64()?;
            let busy = Duration::from_nanos(r.u64()?);
            let jobs_run = r.u32()?;
            let jobs_stolen = r.u32()?;
            let panel_hits = r.u64()?;
            let panel_misses = r.u64()?;
            let panel_flops = r.u64()?;
            let panel_time = Duration::from_nanos(r.u64()?);
            let panel_threads = r.u32()?;
            let panel_isa = u8::try_from(r.u32()?)
                .map_err(|_| anyhow!("WorkerDone panel_isa out of u8 range"))?;
            let peer_tx_bytes = r.u64()?;
            let peer_ships = r.u32()?;
            let span_count = r.u32()? as usize;
            let now_ns = r.u64()?;
            let chaos_faults = r.u32()?;
            let metrics_bytes = r.u32()? as usize;
            // Bound the span + metrics blocks against the declared payload
            // *before* allocating anything sized by the (possibly hostile)
            // counts.
            let tree_bytes = payload_len
                .checked_sub(STATS_BYTES as usize)
                .and_then(|rest| {
                    span_count.checked_mul(SPAN_BYTES as usize).and_then(|b| rest.checked_sub(b))
                })
                .and_then(|rest| rest.checked_sub(metrics_bytes))
                .ok_or_else(|| {
                    anyhow!(
                        "WorkerDone payload {payload_len} < stats block + {span_count} spans \
                         + {metrics_bytes} metrics bytes"
                    )
                })?;
            let mut spans = Vec::with_capacity(span_count);
            for _ in 0..span_count {
                let rec = r.take(SPAN_BYTES as usize)?;
                spans.push(crate::obs::Span {
                    kind_code: rec[0],
                    worker: u16::from_le_bytes(rec[2..4].try_into().unwrap()),
                    id: u32::from_le_bytes(rec[4..8].try_into().unwrap()),
                    arg: u64::from_le_bytes(rec[8..16].try_into().unwrap()),
                    start_ns: u64::from_le_bytes(rec[16..24].try_into().unwrap()),
                    end_ns: u64::from_le_bytes(rec[24..32].try_into().unwrap()),
                });
            }
            let metrics = if metrics_bytes > 0 {
                Some(crate::obs::metrics::Snapshot::decode(r.take(metrics_bytes)?)?)
            } else {
                None
            };
            let local_tree = if has_tree {
                Some(r.edges(derive_edges(tree_bytes, "WorkerDone tree")?)?)
            } else {
                None
            };
            Message::WorkerDone {
                worker,
                local_tree,
                dist_evals,
                busy,
                jobs_run,
                jobs_stolen,
                panel_hits,
                panel_misses,
                panel_flops,
                panel_time,
                panel_threads,
                panel_isa,
                peer_tx_bytes,
                peer_ships,
                spans,
                now_ns,
                chaos_faults,
                metrics,
            }
        }
        TAG_METRICS_PUSH => Message::MetricsPush {
            worker: r0.u16_at(6),
            snap: crate::obs::metrics::Snapshot::decode(r.rest())?,
        },
        TAG_HEARTBEAT => Message::Heartbeat,
        TAG_SHUTDOWN => Message::Shutdown,
        other => bail!("unknown frame tag {other}"),
    };
    r.done("frame")?;
    Ok(msg)
}

/// Edge count of an edges-only payload section (12 bytes per edge).
fn derive_edges(bytes: usize, what: &str) -> Result<usize> {
    if bytes % Edge::WIRE_BYTES != 0 {
        bail!("{what}: {bytes} bytes is not a whole number of {}-byte edges", Edge::WIRE_BYTES);
    }
    Ok(bytes / Edge::WIRE_BYTES)
}

// ----------------------------------------------------------- enum codes

/// Stable wire codes for the run-shaping enums carried by [`Setup`]. These
/// are protocol constants — reordering a Rust enum must not change them.
pub fn metric_code(kind: MetricKind) -> u8 {
    match kind {
        MetricKind::SqEuclid => 0,
        MetricKind::Euclid => 1,
        MetricKind::Cosine => 2,
        MetricKind::Manhattan => 3,
    }
}

pub fn metric_from_code(code: u8) -> Result<MetricKind> {
    Ok(match code {
        0 => MetricKind::SqEuclid,
        1 => MetricKind::Euclid,
        2 => MetricKind::Cosine,
        3 => MetricKind::Manhattan,
        other => bail!("unknown metric wire code {other}"),
    })
}

pub fn kernel_code(kernel: &KernelChoice) -> u8 {
    match kernel {
        KernelChoice::PrimDense => 0,
        KernelChoice::BoruvkaRust => 1,
        KernelChoice::BoruvkaXla => 2,
    }
}

pub fn kernel_from_code(code: u8) -> Result<KernelChoice> {
    Ok(match code {
        0 => KernelChoice::PrimDense,
        1 => KernelChoice::BoruvkaRust,
        2 => KernelChoice::BoruvkaXla,
        other => bail!("unknown kernel wire code {other}"),
    })
}

pub fn pair_kernel_code(pk: PairKernelChoice) -> u8 {
    match pk {
        PairKernelChoice::Dense => 0,
        PairKernelChoice::BipartiteMerge => 1,
    }
}

pub fn pair_kernel_from_code(code: u8) -> Result<PairKernelChoice> {
    Ok(match code {
        0 => PairKernelChoice::Dense,
        1 => PairKernelChoice::BipartiteMerge,
        other => bail!("unknown pair-kernel wire code {other}"),
    })
}

// --------------------------------------------------------------- handshake

/// First frame on every connection, worker → leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub version: u16,
    /// Port of the worker's peer (worker↔worker) listener, 0 when the
    /// worker exposes none. The leader pairs this with the connection's
    /// observed source address to assemble the fleet's `PeerBook`.
    pub peer_port: u16,
}

/// Leader → worker: everything a remote rank needs to decode job frames and
/// run them — identity, the run's shape, kernels, the partition layout, and
/// the artifacts directory (so a `boruvka-xla` worker resolves the same AOT
/// artifacts the leader validated, instead of silently falling back against
/// its own cwd).
#[derive(Clone, Debug, PartialEq)]
pub struct Setup {
    pub version: u16,
    pub worker_id: u16,
    pub n: u32,
    pub d: u16,
    pub metric: u8,
    pub kernel: u8,
    pub pair_kernel: u8,
    pub reduce_tree: bool,
    /// true when this worker is being admitted into an **already-running**
    /// fleet: the worker must answer with [`Join`] (not [`SetupAck`]) and
    /// wait for the leader's [`AdmitAck`] before serving
    pub mid_run: bool,
    /// true when the leader wants telemetry spans recorded and shipped
    /// back in the final `WorkerDone`; off keeps the worker's job hot
    /// path allocation-free and the byte model span-free
    pub trace: bool,
    /// true when the leader wants metrics recorded: the worker ships a
    /// snapshot block in its final `WorkerDone` and periodic
    /// [`MetricsPush`](Message::MetricsPush) frames at the push cadence;
    /// off ships zero metrics bytes, so metrics-off byte models are exact
    pub metrics: bool,
    /// shard-manifest fingerprint of a sharded run, 0 when unsharded; a
    /// worker whose loaded manifest fingerprints differently must refuse
    /// the run (its shard files were cut from another partition)
    pub manifest: u64,
    /// fleet-wide per-link read deadline in milliseconds (0 = no deadline);
    /// also derives the worker's fold-inbox wait (`liveness / 2`) so fold
    /// replies always beat the leader's own deadline
    pub liveness_ms: u32,
    /// minimum milliseconds between two `MetricsPush` frames from this
    /// worker (ignored unless `metrics` is set)
    pub metrics_push_ms: u32,
    pub part_sizes: Vec<u32>,
    /// leader-side artifacts dir, UTF-8 (trailing variable-length section)
    pub artifacts_dir: String,
}

/// Worker → leader: handshake complete, ready for job frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SetupAck {
    pub worker_id: u16,
}

/// Worker → leader reply to a `mid_run` [`Setup`]: the worker asks to be
/// admitted into the running fleet. Versioned and magic-checked like
/// [`Hello`] so an admission attempt from a skewed build fails loudly at
/// the handshake instead of corrupting a run in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Join {
    pub worker_id: u16,
    pub version: u16,
}

/// Leader → worker: admission confirmed — the deck is open, job frames may
/// follow. Sent only after the leader has validated the joiner's shard
/// advertisement exactly like a startup handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmitAck {
    pub worker_id: u16,
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut f = FrameBuf::new(TAG_HELLO, 0).expect("fixed frame");
    f.set_u16(6, h.version);
    f.set_u32(8, MAGIC);
    f.set_u16(12, h.peer_port);
    f.buf
}

pub fn decode_hello(frame: &[u8]) -> Result<Hello> {
    expect_tag(frame, TAG_HELLO, "Hello")?;
    let r = Reader::new(frame);
    if r.u32_at(8) != MAGIC {
        bail!("handshake magic mismatch: peer is not a demst worker");
    }
    let version = r.u16_at(6);
    if version != WIRE_VERSION {
        bail!("wire protocol version mismatch: peer v{version}, this build v{WIRE_VERSION}");
    }
    Ok(Hello { version, peer_port: r.u16_at(12) })
}

pub fn encode_setup(s: &Setup) -> Result<Vec<u8>> {
    let parts = need_u16(s.part_sizes.len(), "partition count")?;
    let dir = s.artifacts_dir.as_bytes();
    let payload = 24 + 4 * s.part_sizes.len() as u64 + dir.len() as u64;
    let mut f = FrameBuf::new(TAG_SETUP, payload)?;
    f.set_u8(
        5,
        s.reduce_tree as u8
            | (s.mid_run as u8) << 1
            | (s.trace as u8) << 2
            | (s.metrics as u8) << 3,
    );
    f.set_u16(6, s.version);
    f.set_u16(8, s.worker_id);
    f.set_u16(10, s.d);
    f.set_u16(12, parts);
    f.set_u8(14, s.metric);
    f.set_u8(15, s.pair_kernel);
    f.buf.push(s.kernel);
    f.buf.extend_from_slice(&[0u8; 3]);
    f.push_u32s(&[s.n]);
    f.push_u64(s.manifest);
    f.push_u32s(&[s.liveness_ms, s.metrics_push_ms]);
    f.push_u32s(&s.part_sizes);
    f.buf.extend_from_slice(dir);
    Ok(f.buf)
}

pub fn decode_setup(frame: &[u8]) -> Result<Setup> {
    expect_tag(frame, TAG_SETUP, "Setup")?;
    let r0 = Reader::new(frame);
    let version = r0.u16_at(6);
    if version != WIRE_VERSION {
        bail!("wire protocol version mismatch: leader v{version}, this build v{WIRE_VERSION}");
    }
    let parts = r0.u16_at(12) as usize;
    let mut r = Reader::new(&frame[HEADER_BYTES as usize..]);
    let kernel = r.take(4)?[0];
    let n = r.u32()?;
    let manifest = r.u64()?;
    let liveness_ms = r.u32()?;
    let metrics_push_ms = r.u32()?;
    let part_sizes = r.u32s(parts)?;
    let artifacts_dir = String::from_utf8(r.rest().to_vec())
        .map_err(|_| anyhow!("Setup artifacts_dir is not UTF-8"))?;
    r.done("Setup")?;
    Ok(Setup {
        version,
        worker_id: r0.u16_at(8),
        n,
        d: r0.u16_at(10),
        metric: r0.u8_at(14),
        kernel,
        pair_kernel: r0.u8_at(15),
        reduce_tree: r0.u8_at(5) & 1 != 0,
        mid_run: r0.u8_at(5) & 2 != 0,
        trace: r0.u8_at(5) & 4 != 0,
        metrics: r0.u8_at(5) & 8 != 0,
        manifest,
        liveness_ms,
        metrics_push_ms,
        part_sizes,
        artifacts_dir,
    })
}

pub fn encode_setup_ack(a: &SetupAck) -> Vec<u8> {
    let mut f = FrameBuf::new(TAG_SETUP_ACK, 0).expect("fixed frame");
    f.set_u16(8, a.worker_id);
    f.buf
}

pub fn decode_setup_ack(frame: &[u8]) -> Result<SetupAck> {
    expect_tag(frame, TAG_SETUP_ACK, "SetupAck")?;
    Ok(SetupAck { worker_id: Reader::new(frame).u16_at(8) })
}

pub fn encode_join(j: &Join) -> Vec<u8> {
    let mut f = FrameBuf::new(TAG_JOIN, 0).expect("fixed frame");
    f.set_u16(6, j.version);
    f.set_u32(8, MAGIC);
    f.set_u16(12, j.worker_id);
    f.buf
}

pub fn decode_join(frame: &[u8]) -> Result<Join> {
    expect_tag(frame, TAG_JOIN, "Join")?;
    let r = Reader::new(frame);
    if r.u32_at(8) != MAGIC {
        bail!("join magic mismatch: peer is not a demst worker");
    }
    let version = r.u16_at(6);
    if version != WIRE_VERSION {
        bail!("wire protocol version mismatch: joiner v{version}, this build v{WIRE_VERSION}");
    }
    Ok(Join { version, worker_id: r.u16_at(12) })
}

pub fn encode_admit_ack(a: &AdmitAck) -> Vec<u8> {
    let mut f = FrameBuf::new(TAG_ADMIT_ACK, 0).expect("fixed frame");
    f.set_u16(8, a.worker_id);
    f.buf
}

pub fn decode_admit_ack(frame: &[u8]) -> Result<AdmitAck> {
    expect_tag(frame, TAG_ADMIT_ACK, "AdmitAck")?;
    Ok(AdmitAck { worker_id: Reader::new(frame).u16_at(8) })
}

/// Final handshake frame, worker → leader: the partition subset ids this
/// worker loaded from local shard files (empty on unsharded workers). This
/// is what seeds the leader's resident-set model and its capability-aware
/// scheduling on a sharded run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardAdvertise {
    pub worker_id: u16,
    pub shard_ids: Vec<u32>,
}

pub fn encode_shard_advertise(a: &ShardAdvertise) -> Result<Vec<u8>> {
    let mut f = FrameBuf::new(TAG_SHARD_ADVERTISE, 4 * a.shard_ids.len() as u64)?;
    f.set_u16(6, a.worker_id);
    f.push_u32s(&a.shard_ids);
    Ok(f.buf)
}

pub fn decode_shard_advertise(frame: &[u8]) -> Result<ShardAdvertise> {
    expect_tag(frame, TAG_SHARD_ADVERTISE, "ShardAdvertise")?;
    let payload = frame.len() - HEADER_BYTES as usize;
    if payload % 4 != 0 {
        bail!("ShardAdvertise payload {payload} is not a whole number of u32 ids");
    }
    let r0 = Reader::new(frame);
    let mut r = Reader::new(&frame[HEADER_BYTES as usize..]);
    let shard_ids = r.u32s(payload / 4)?;
    r.done("ShardAdvertise")?;
    Ok(ShardAdvertise { worker_id: r0.u16_at(6), shard_ids })
}

fn expect_tag(frame: &[u8], tag: u8, what: &str) -> Result<()> {
    if frame.len() < HEADER_BYTES as usize {
        bail!("short {what} frame: {} bytes", frame.len());
    }
    let got = frame[4];
    if got != tag {
        bail!("expected {what} frame (tag {tag}), got tag {got}");
    }
    let declared = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
    if frame.len() != HEADER_BYTES as usize + declared {
        bail!("{what} frame length {} != declared {}", frame.len(), HEADER_BYTES as usize + declared);
    }
    Ok(())
}

// ------------------------------------------------------------------ framed IO

/// Write one already-encoded frame.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

/// Read one complete frame (16-byte header + declared payload).
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    read_frame_io(r).context("reading frame")
}

/// [`read_frame`] with the raw [`std::io::Error`] preserved, so callers
/// with a read deadline on the socket can tell a liveness timeout
/// (`WouldBlock` / `TimedOut`) from a dead link. A forged length field maps
/// to `InvalidData` before any allocation beyond the cap.
pub fn read_frame_io(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    read_frame_capped_io(r, MAX_PAYLOAD)
}

/// [`read_frame_io`] with a tighter payload cap — handshake-phase reads use
/// [`MAX_HANDSHAKE_PAYLOAD`] so an unauthenticated peer's forged length
/// field can never drive a large allocation.
pub fn read_frame_capped_io(r: &mut impl Read, cap: u32) -> std::io::Result<Vec<u8>> {
    let mut head = [0u8; HEADER_BYTES as usize];
    r.read_exact(&mut head)?;
    let payload_len = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if payload_len > cap {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("peer declared a {payload_len}-byte payload (limit {cap}); refusing"),
        ));
    }
    let mut frame = vec![0u8; HEADER_BYTES as usize + payload_len as usize];
    frame[..HEADER_BYTES as usize].copy_from_slice(&head);
    r.read_exact(&mut frame[HEADER_BYTES as usize..])?;
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Message, ctx: Option<&WireCtx>) -> Message {
        let frame = encode(msg).unwrap();
        assert_eq!(frame.len() as u64, msg.wire_bytes(), "encode length == wire_bytes");
        decode(&frame, ctx).unwrap()
    }

    #[test]
    fn job_roundtrips_and_matches_model() {
        let msg = Message::Job {
            job: PairJob { id: 9, i: 1, j: 3 },
            global_ids: vec![2, 5, 7],
            points: Dataset::new(3, 2, vec![0.5, -1.0, 2.25, 3.5, f32::MIN_POSITIVE, 0.0]),
        };
        assert_eq!(roundtrip(&msg, None), msg);
    }

    #[test]
    fn pair_assign_roundtrips_via_ctx() {
        let ctx = WireCtx { d: 2, part_sizes: vec![3, 2, 4] };
        let ship_i = SubsetShip {
            part: 0,
            vectors: Some((vec![0, 4, 8], Dataset::new(3, 2, vec![1.0; 6]))),
            tree: Some(vec![Edge::new(0, 4, 1.5), Edge::new(4, 8, 0.25)]),
            routed: false,
        };
        let ship_j = SubsetShip {
            part: 2,
            vectors: None,
            tree: Some(vec![Edge::new(1, 2, 0.5), Edge::new(2, 3, 1.0), Edge::new(3, 5, 2.0)]),
            routed: false,
        };
        for ships in [vec![], vec![ship_i.clone()], vec![ship_j.clone()], vec![ship_i, ship_j]] {
            let msg = Message::PairAssign { job: PairJob { id: 4, i: 0, j: 2 }, ships };
            assert_eq!(roundtrip(&msg, Some(&ctx)), msg);
        }
    }

    #[test]
    fn self_pair_assign_tree_only() {
        let ctx = WireCtx { d: 3, part_sizes: vec![2] };
        let msg = Message::PairAssign {
            job: PairJob { id: 0, i: 0, j: 0 },
            ships: vec![SubsetShip {
                part: 0,
                vectors: None,
                tree: Some(vec![Edge::new(0, 1, 4.0)]),
                routed: false,
            }],
        };
        assert_eq!(msg.wire_bytes(), 16 + 12);
        assert_eq!(roundtrip(&msg, Some(&ctx)), msg);
    }

    #[test]
    fn result_and_done_roundtrip() {
        let msg = Message::Result {
            job_id: 17,
            worker: 200,
            edges: vec![Edge::new(3, 9, 0.125)],
            compute: Duration::from_nanos(123_456_789),
        };
        assert_eq!(roundtrip(&msg, None), msg);
        let done = Message::WorkerDone {
            worker: 60000,
            local_tree: Some(vec![]),
            dist_evals: u64::MAX,
            busy: Duration::from_nanos(42),
            jobs_run: 7,
            jobs_stolen: 2,
            panel_hits: 11,
            panel_misses: 3,
            panel_flops: 1 << 40,
            panel_time: Duration::from_nanos(987_654_321),
            panel_threads: 8,
            panel_isa: 2,
            peer_tx_bytes: 123_456,
            peer_ships: 5,
            spans: vec![],
            now_ns: 0xdead_beef_0000_0001,
            chaos_faults: 3,
            metrics: None,
        };
        assert_eq!(done.wire_bytes(), HEADER_BYTES + STATS_BYTES, "stats block is 96 bytes");
        assert_eq!(roundtrip(&done, None), done);
        // None vs Some(vec![]) is preserved by the has-tree flag
        let bare = Message::WorkerDone {
            worker: 0,
            local_tree: None,
            dist_evals: 0,
            busy: Duration::ZERO,
            jobs_run: 0,
            jobs_stolen: 0,
            panel_hits: 0,
            panel_misses: 0,
            panel_flops: 0,
            panel_time: Duration::ZERO,
            panel_threads: 0,
            panel_isa: 0,
            peer_tx_bytes: 0,
            peer_ships: 0,
            spans: vec![],
            now_ns: 0,
            chaos_faults: 0,
            metrics: None,
        };
        assert_eq!(roundtrip(&bare, None), bare);
    }

    #[test]
    fn worker_done_metrics_block_roundtrips_and_rejects_forgery() {
        use crate::obs::metrics::{Ctr, Hist, Registry};
        let reg = Registry::new();
        reg.observe_job(1_234_567, 2, 5);
        reg.observe(Hist::Fold, 999);
        reg.add(Ctr::DistEvals, 42);
        let snap = reg.snapshot();
        let done = Message::WorkerDone {
            worker: 1,
            local_tree: Some(vec![Edge::new(0, 1, 0.5)]),
            dist_evals: 42,
            busy: Duration::from_millis(1),
            jobs_run: 1,
            jobs_stolen: 0,
            panel_hits: 0,
            panel_misses: 0,
            panel_flops: 0,
            panel_time: Duration::ZERO,
            panel_threads: 0,
            panel_isa: 0,
            peer_tx_bytes: 0,
            peer_ships: 0,
            spans: vec![crate::obs::Span::default()],
            now_ns: 5,
            chaos_faults: 0,
            metrics: Some(snap.clone()),
        };
        assert_eq!(
            done.wire_bytes(),
            HEADER_BYTES + STATS_BYTES + SPAN_BYTES + snap.wire_bytes() + EDGE_BYTES,
            "metrics block rides between spans and tree"
        );
        assert_eq!(roundtrip(&done, None), done);
        // a forged metrics length larger than the payload is refused before
        // the tree parse can misalign
        let mut frame = encode(&done).unwrap();
        let metrics_at = HEADER_BYTES as usize + 92; // chaos_faults u32, then metrics_bytes
        frame[metrics_at..metrics_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame, None).is_err(), "hostile metrics length rejected");
        // the push frame carries the same snapshot standalone
        let push = Message::MetricsPush { worker: 9, snap: snap.clone() };
        assert_eq!(push.wire_bytes(), HEADER_BYTES + snap.wire_bytes());
        assert_eq!(roundtrip(&push, None), push);
    }

    #[test]
    fn worker_done_span_block_roundtrips_bit_identically() {
        use crate::obs::{Span, SpanKind};
        let spans = vec![
            Span {
                kind_code: SpanKind::Job.code(),
                worker: 2,
                id: 41,
                arg: 12_345,
                start_ns: 1_000_000,
                end_ns: 1_500_000,
            },
            Span {
                kind_code: SpanKind::Chaos.code(),
                worker: 2,
                id: 0,
                arg: 17,
                start_ns: 2_000_000,
                end_ns: 2_000_000,
            },
            // a kind code this build doesn't know must survive the wire
            Span { kind_code: 250, worker: 2, id: 9, arg: u64::MAX, start_ns: 3, end_ns: 4 },
        ];
        let done = Message::WorkerDone {
            worker: 2,
            local_tree: Some(vec![Edge::new(0, 1, 0.5), Edge::new(1, 2, 1.5)]),
            dist_evals: 99,
            busy: Duration::from_millis(5),
            jobs_run: 3,
            jobs_stolen: 0,
            panel_hits: 1,
            panel_misses: 1,
            panel_flops: 64,
            panel_time: Duration::from_micros(10),
            panel_threads: 1,
            panel_isa: 0,
            peer_tx_bytes: 0,
            peer_ships: 0,
            spans: spans.clone(),
            now_ns: 7_777_777,
            chaos_faults: 1,
            metrics: None,
        };
        assert_eq!(
            done.wire_bytes(),
            HEADER_BYTES + STATS_BYTES + 3 * SPAN_BYTES + 2 * EDGE_BYTES,
            "span block rides between stats and tree"
        );
        assert_eq!(roundtrip(&done, None), done);
        // a forged span count larger than the payload is refused before
        // any count-sized allocation
        let mut frame = encode(&done).unwrap();
        let count_at = HEADER_BYTES as usize + 76; // peer_ships u32, then span_count
        frame[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame, None).is_err(), "hostile span count rejected");
    }

    #[test]
    fn control_frames_roundtrip() {
        assert_eq!(roundtrip(&Message::Shutdown, None), Message::Shutdown);
        assert_eq!(roundtrip(&Message::Ack { job_id: 3 }, None), Message::Ack { job_id: 3 });
        let la = Message::LocalAssign { part: 9 };
        assert_eq!(la.wire_bytes(), 16, "LocalAssign ships no vectors");
        assert_eq!(roundtrip(&la, None), la);
        let ld = Message::LocalDone {
            part: 5,
            edges: vec![Edge::new(0, 1, 1.0)],
            compute: Duration::from_micros(77),
        };
        assert_eq!(roundtrip(&ld, None), ld);
    }

    #[test]
    fn encode_rejects_out_of_range() {
        let msg = Message::Result { job_id: 0, worker: 256, edges: vec![], compute: Duration::ZERO };
        assert!(encode(&msg).is_err(), "worker > 255 must not encode");
        let msg = Message::Job {
            job: PairJob { id: 0, i: 70_000, j: 70_001 },
            global_ids: vec![0],
            points: Dataset::zeros(1, 1),
        };
        assert!(encode(&msg).is_err(), "subset index > 65535 must not encode");
    }

    #[test]
    fn decode_rejects_corrupt_frames() {
        let good = encode(&Message::Ack { job_id: 1 }).unwrap();
        assert!(decode(&good[..10], None).is_err(), "short frame");
        let mut bad_tag = good.clone();
        bad_tag[4] = 200;
        assert!(decode(&bad_tag, None).is_err(), "unknown tag");
        let mut bad_len = good;
        bad_len[0] = 99;
        assert!(decode(&bad_len, None).is_err(), "length mismatch");
        // PairAssign without a context is refused, not mis-parsed
        let pa = encode(&Message::PairAssign {
            job: PairJob { id: 0, i: 0, j: 1 },
            ships: vec![],
        })
        .unwrap();
        assert!(decode(&pa, None).is_err());
    }

    #[test]
    fn handshake_roundtrip_and_version_check() {
        let hello = Hello { version: WIRE_VERSION, peer_port: 40123 };
        assert_eq!(decode_hello(&encode_hello(&hello)).unwrap(), hello);
        let no_peer = Hello { version: WIRE_VERSION, peer_port: 0 };
        assert_eq!(decode_hello(&encode_hello(&no_peer)).unwrap(), no_peer);
        let mut wrong = encode_hello(&hello);
        wrong[6] = WIRE_VERSION as u8 + 1;
        assert!(decode_hello(&wrong).is_err(), "version mismatch rejected");
        let mut not_demst = encode_hello(&hello);
        not_demst[8] = 0;
        assert!(decode_hello(&not_demst).is_err(), "magic mismatch rejected");

        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: 3,
            n: 1000,
            d: 128,
            metric: 2,
            kernel: 1,
            pair_kernel: 1,
            reduce_tree: true,
            mid_run: false,
            trace: true,
            metrics: true,
            manifest: 0xfeed_beef_cafe_f00d,
            liveness_ms: 30_000,
            metrics_push_ms: 500,
            part_sizes: vec![250, 250, 300, 200],
            artifacts_dir: "/opt/aot artifacts".into(),
        };
        assert_eq!(decode_setup(&encode_setup(&setup).unwrap()).unwrap(), setup);
        let bare = Setup { artifacts_dir: String::new(), manifest: 0, ..setup.clone() };
        assert_eq!(decode_setup(&encode_setup(&bare).unwrap()).unwrap(), bare);
        // mid-run admission Setup: flag bit 1 rides next to reduce_tree
        let admit = Setup { mid_run: true, reduce_tree: false, liveness_ms: 0, ..setup.clone() };
        assert_eq!(decode_setup(&encode_setup(&admit).unwrap()).unwrap(), admit);
        // metrics off clears flag bit 3 and leaves the cadence inert
        let quiet = Setup { metrics: false, metrics_push_ms: 0, ..setup.clone() };
        assert_eq!(decode_setup(&encode_setup(&quiet).unwrap()).unwrap(), quiet);
        let ack = SetupAck { worker_id: 3 };
        assert_eq!(decode_setup_ack(&encode_setup_ack(&ack)).unwrap(), ack);
    }

    #[test]
    fn heartbeat_is_header_only_and_roundtrips() {
        let hb = Message::Heartbeat;
        assert_eq!(hb.wire_bytes(), HEADER_BYTES, "Heartbeat must stay header-only");
        assert_eq!(roundtrip(&hb, None), hb);
    }

    #[test]
    fn join_and_admit_ack_roundtrip_with_version_check() {
        let join = Join { worker_id: 7, version: WIRE_VERSION };
        let frame = encode_join(&join);
        assert_eq!(frame.len() as u64, HEADER_BYTES, "Join is header-only");
        assert_eq!(decode_join(&frame).unwrap(), join);
        let mut skewed = encode_join(&join);
        skewed[6] = WIRE_VERSION as u8 + 1;
        assert!(decode_join(&skewed).is_err(), "version-skewed joiner rejected");
        let mut not_demst = encode_join(&join);
        not_demst[8] = 0;
        assert!(decode_join(&not_demst).is_err(), "magic mismatch rejected");

        let ack = AdmitAck { worker_id: 7 };
        let frame = encode_admit_ack(&ack);
        assert_eq!(frame.len() as u64, HEADER_BYTES, "AdmitAck is header-only");
        assert_eq!(decode_admit_ack(&frame).unwrap(), ack);
        // a non-admit frame is refused, not mis-parsed
        let setup_ack = encode_setup_ack(&SetupAck { worker_id: 7 });
        assert!(decode_admit_ack(&setup_ack).is_err());
        assert!(decode_join(&setup_ack).is_err());
    }

    #[test]
    fn capped_read_refuses_forged_handshake_lengths() {
        // a forged 512 MiB length field must be refused by the handshake
        // cap *before* any allocation, with a clean InvalidData error
        let mut forged = vec![0u8; HEADER_BYTES as usize];
        forged[0..4].copy_from_slice(&(512u32 << 20).to_le_bytes());
        forged[4] = 1; // Hello tag
        let mut cursor = &forged[..];
        let err = read_frame_capped_io(&mut cursor, MAX_HANDSHAKE_PAYLOAD).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // the same frame passes the general cap (and then fails on EOF,
        // not a panic or oversized allocation)
        let mut cursor = &forged[..];
        assert!(read_frame_io(&mut cursor).is_err());
    }

    #[test]
    fn shard_advertise_roundtrip() {
        for ids in [vec![], vec![0u32], vec![3, 1, 7, 65000]] {
            let adv = ShardAdvertise { worker_id: 9, shard_ids: ids };
            let frame = encode_shard_advertise(&adv).unwrap();
            assert_eq!(frame.len(), 16 + 4 * adv.shard_ids.len());
            assert_eq!(decode_shard_advertise(&frame).unwrap(), adv);
        }
        // a non-advertise frame is refused
        let ack = encode(&Message::Ack { job_id: 0 }).unwrap();
        assert!(decode_shard_advertise(&ack).is_err());
    }

    #[test]
    fn enum_codes_roundtrip_and_reject_unknown() {
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            assert_eq!(metric_from_code(metric_code(kind)).unwrap(), kind);
        }
        for kernel in
            [KernelChoice::PrimDense, KernelChoice::BoruvkaRust, KernelChoice::BoruvkaXla]
        {
            assert_eq!(kernel_from_code(kernel_code(&kernel)).unwrap(), kernel);
        }
        for pk in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            assert_eq!(pair_kernel_from_code(pair_kernel_code(pk)).unwrap(), pk);
        }
        assert!(metric_from_code(200).is_err());
        assert!(kernel_from_code(200).is_err());
        assert!(pair_kernel_from_code(200).is_err());
    }

    #[test]
    fn framed_io_roundtrip() {
        let msg = Message::Result {
            job_id: 1,
            worker: 0,
            edges: vec![Edge::new(0, 1, 2.0); 3],
            compute: Duration::ZERO,
        };
        let frame = encode(&msg).unwrap();
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut cursor = &buf[..];
        let back = read_frame(&mut cursor).unwrap();
        assert_eq!(back, frame);
        assert_eq!(decode(&back, None).unwrap(), msg);
        // truncated stream errors instead of hanging or mis-framing
        let mut short = &buf[..buf.len() - 1];
        assert!(read_frame(&mut short).is_err());
    }

    #[test]
    fn routed_pair_assign_ships_zero_payload() {
        let ctx = WireCtx { d: 2, part_sizes: vec![3, 2, 4] };
        let msg = Message::PairAssign {
            job: PairJob { id: 4, i: 0, j: 2 },
            ships: vec![
                SubsetShip { part: 0, vectors: None, tree: None, routed: true },
                SubsetShip { part: 2, vectors: None, tree: None, routed: true },
            ],
        };
        assert_eq!(msg.wire_bytes(), HEADER_BYTES, "routed sections are header-only");
        assert_eq!(roundtrip(&msg, Some(&ctx)), msg);
        // one section routed, the other carried inline
        let mixed = Message::PairAssign {
            job: PairJob { id: 5, i: 0, j: 2 },
            ships: vec![
                SubsetShip { part: 0, vectors: None, tree: None, routed: true },
                SubsetShip {
                    part: 2,
                    vectors: None,
                    tree: Some(vec![
                        Edge::new(1, 2, 0.5),
                        Edge::new(2, 3, 1.0),
                        Edge::new(3, 5, 2.0),
                    ]),
                    routed: false,
                },
            ],
        };
        assert_eq!(mixed.wire_bytes(), HEADER_BYTES + 3 * EDGE_BYTES);
        assert_eq!(roundtrip(&mixed, Some(&ctx)), mixed);
        // routed + inline tree on the same section is a protocol error
        let bad = Message::PairAssign {
            job: PairJob { id: 6, i: 0, j: 0 },
            ships: vec![SubsetShip {
                part: 0,
                vectors: None,
                tree: Some(vec![Edge::new(0, 1, 1.0)]),
                routed: true,
            }],
        };
        assert!(encode(&bad).is_err());
    }

    #[test]
    fn peer_plane_frames_roundtrip() {
        use crate::coordinator::messages::FOLD_KEEP;
        let hello = Message::PeerHello { from: 7 };
        assert_eq!(hello.wire_bytes(), HEADER_BYTES, "PeerHello is header-only");
        assert_eq!(roundtrip(&hello, None), hello);
        let fetch = Message::TreeFetch { part: 300_000 };
        assert_eq!(fetch.wire_bytes(), HEADER_BYTES);
        assert_eq!(roundtrip(&fetch, None), fetch);
        for fold in [false, true] {
            let ship = Message::TreeShip {
                part: 2,
                fold,
                edges: vec![Edge::new(0, 9, 1.25), Edge::new(9, 17, 0.5)],
            };
            assert_eq!(ship.wire_bytes(), HEADER_BYTES + 2 * EDGE_BYTES);
            assert_eq!(roundtrip(&ship, None), ship);
        }
        // empty fold ship: a worker with no partial still participates
        let empty = Message::TreeShip { part: 0, fold: true, edges: vec![] };
        assert_eq!(empty.wire_bytes(), HEADER_BYTES);
        assert_eq!(roundtrip(&empty, None), empty);
        for to in [0u16, 3, FOLD_KEEP] {
            let fs = Message::FoldShip { to, expect: 2 };
            assert_eq!(fs.wire_bytes(), HEADER_BYTES, "FoldShip is header-only");
            assert_eq!(roundtrip(&fs, None), fs);
        }
    }

    #[test]
    fn ack_status_family_roundtrips() {
        let fail = Message::PairFail { job_id: 41 };
        assert_eq!(fail.wire_bytes(), HEADER_BYTES);
        assert_eq!(roundtrip(&fail, None), fail);
        for ok in [false, true] {
            let done = Message::FoldDone { ok };
            assert_eq!(done.wire_bytes(), HEADER_BYTES);
            assert_eq!(roundtrip(&done, None), done);
        }
        // the plain Ack still decodes as Ack (status 0)
        assert_eq!(roundtrip(&Message::Ack { job_id: 9 }, None), Message::Ack { job_id: 9 });
    }

    #[test]
    fn peer_book_roundtrip() {
        use crate::coordinator::messages::PeerAddr;
        use std::net::IpAddr;
        let book = Message::PeerBook {
            peers: vec![
                PeerAddr { ip: IpAddr::V4([127, 0, 0, 1].into()), port: 40001 },
                PeerAddr { ip: IpAddr::V6([0xfe80, 0, 0, 0, 0, 0, 0, 0x17].into()), port: 65535 },
                PeerAddr { ip: IpAddr::V4([10, 1, 2, 3].into()), port: 0 },
            ],
            builders: vec![0, 2, 1, FOLD_KEEP_SENTINEL],
        };
        assert_eq!(book.wire_bytes(), HEADER_BYTES + 3 * PEER_ENTRY_BYTES + 4 * 2);
        assert_eq!(roundtrip(&book, None), book);
        let empty = Message::PeerBook { peers: vec![], builders: vec![] };
        assert_eq!(empty.wire_bytes(), HEADER_BYTES);
        assert_eq!(roundtrip(&empty, None), empty);
    }

    const FOLD_KEEP_SENTINEL: u16 = u16::MAX;
}
