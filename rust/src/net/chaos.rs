//! Deterministic fault injection for the worker's leader link.
//!
//! The PR-5/7 chaos hooks (`DEMST_CHAOS_EXIT_AFTER_JOBS`,
//! `DEMST_CHAOS_EXIT_ON_FOLD`) can only kill a worker outright. This module
//! generalizes them into a **fault plan**: a comma-separated list of
//! `<dir><frame>:<fault>[:<arg>]` entries in `DEMST_CHAOS_PLAN`, applied to
//! the Nth frame (1-based, counted per direction from the first handshake
//! frame) crossing the worker's leader link. Because the worker serves the
//! link single-threadedly and frames are counted, every injection lands on
//! the same frame of the same run every time — chaos tests are replayable
//! bit-for-bit.
//!
//! ```text
//! tx5:stall          block forever before sending tx frame 5 (no death —
//!                    the leader's liveness deadline must catch it)
//! rx3:stall          block forever instead of delivering rx frame 3
//! tx7:delay:250      sleep 250 ms before sending tx frame 7
//! tx4:drop           swallow tx frame 4 whole (framing stays intact)
//! rx4:drop           read and discard rx frame 4, deliver the next one
//! tx6:truncate:8     send only the first 8 bytes of tx frame 6, then cut
//!                    the link (all later IO on it fails)
//! tx2:garbage        XOR frame 2's payload with a `DEMST_CHAOS_SEED`ed
//!                    keystream (framing length stays valid; the peer's
//!                    decoder must error cleanly, never panic)
//! tx6:exit:113       `std::process::exit(113)` instead of sending frame 6
//! ```
//!
//! `DEMST_CHAOS_PEER_DENY=<n>` is a separate knob: the first `n` peer-tree
//! fetches in this process fail before connecting, driving the `PairFail`
//! demotion path (routed job → inline shipping → return lane) without any
//! timing dependence.
//!
//! Everything here is env-gated and costs one branch per frame when unset;
//! production runs never construct a plan.

use crate::net::wire;
use crate::util::prng::Pcg64;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// Env var holding the fault plan (see the module docs for the grammar).
pub const PLAN_ENV: &str = "DEMST_CHAOS_PLAN";
/// Env var seeding the `garbage` fault's XOR keystream (default 0xC4A05).
pub const SEED_ENV: &str = "DEMST_CHAOS_SEED";
/// Env var arming the peer-fetch denial counter.
pub const PEER_DENY_ENV: &str = "DEMST_CHAOS_PEER_DENY";

/// Frame direction, from the worker's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// worker → leader
    Tx,
    /// leader → worker
    Rx,
}

/// One injectable fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// sleep this long, then proceed normally
    Delay(Duration),
    /// block forever (the process stays alive — only a liveness deadline
    /// on the other end can detect this)
    Stall,
    /// swallow the frame whole; framing stays intact
    Drop,
    /// emit only the first N bytes, then kill the link for good
    Truncate(usize),
    /// XOR the payload bytes with a seeded keystream (length untouched)
    Garbage,
    /// `std::process::exit(code)` instead of touching the frame
    Exit(i32),
}

/// A parsed `DEMST_CHAOS_PLAN`: which fault fires on which frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    entries: Vec<(Dir, u64, Fault)>,
}

impl FaultPlan {
    /// Parse the `<dir><frame>:<fault>[:<arg>]` grammar. Errors name the
    /// offending entry so a typo'd CI matrix leg fails loudly.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let mut fields = raw.split(':');
            let head = fields.next().unwrap_or("");
            let (dir, frame_str) = if let Some(n) = head.strip_prefix("tx") {
                (Dir::Tx, n)
            } else if let Some(n) = head.strip_prefix("rx") {
                (Dir::Rx, n)
            } else {
                bail!("chaos plan entry {raw:?}: expected tx<N> or rx<N>");
            };
            let frame: u64 = frame_str
                .parse()
                .ok()
                .filter(|&f| f >= 1)
                .with_context(|| format!("chaos plan entry {raw:?}: frame must be >= 1"))?;
            let kind = fields.next().unwrap_or("");
            let arg = fields.next();
            let fault = match (kind, arg) {
                ("stall", None) => Fault::Stall,
                ("drop", None) => Fault::Drop,
                ("garbage", None) => Fault::Garbage,
                ("delay", Some(ms)) => Fault::Delay(Duration::from_millis(
                    ms.parse().with_context(|| format!("chaos plan entry {raw:?}: bad delay"))?,
                )),
                ("truncate", Some(n)) => Fault::Truncate(
                    n.parse().with_context(|| format!("chaos plan entry {raw:?}: bad length"))?,
                ),
                ("exit", Some(code)) => Fault::Exit(
                    code.parse().with_context(|| format!("chaos plan entry {raw:?}: bad code"))?,
                ),
                _ => bail!(
                    "chaos plan entry {raw:?}: unknown fault (want stall|drop|garbage|delay:<ms>|truncate:<n>|exit:<code>)"
                ),
            };
            if fields.next().is_some() {
                bail!("chaos plan entry {raw:?}: trailing fields");
            }
            entries.push((dir, frame, fault));
        }
        Ok(Self { entries })
    }

    fn lookup(&self, dir: Dir, frame: u64) -> Option<Fault> {
        self.entries.iter().find(|&&(d, f, _)| d == dir && f == frame).map(|&(_, _, f)| f)
    }
}

/// Frame-counting fault injector for one link. Wraps the worker's
/// leader-link frame IO: [`ChaosLink::read_frame`] / [`ChaosLink::write_frame`]
/// count frames per direction and fire the plan's fault when a count
/// matches. `None` from [`from_env`](ChaosLink::from_env) means no plan is
/// set and the worker uses plain [`wire`] IO.
#[derive(Debug)]
pub struct ChaosLink {
    plan: FaultPlan,
    rng: Pcg64,
    tx_frames: u64,
    rx_frames: u64,
    /// set after a truncate fault: the link is cut, all further IO errors
    dead: bool,
    /// faults actually fired on this link (reported in `WorkerDone` and
    /// summed into `RunMetrics::chaos_faults_injected`)
    fired: u64,
}

impl ChaosLink {
    /// Build from `DEMST_CHAOS_PLAN` (+ `DEMST_CHAOS_SEED`); `None` when
    /// unset. A malformed plan is a hard error — a chaos run that silently
    /// injects nothing would pass for the wrong reason.
    pub fn from_env() -> Result<Option<Self>> {
        let Ok(spec) = std::env::var(PLAN_ENV) else { return Ok(None) };
        let plan = FaultPlan::parse(&spec)?;
        let seed = std::env::var(SEED_ENV)
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(0xC4A05);
        Ok(Some(Self::new(plan, seed)))
    }

    pub fn new(plan: FaultPlan, seed: u64) -> Self {
        Self { plan, rng: Pcg64::seeded(seed), tx_frames: 0, rx_frames: 0, dead: false, fired: 0 }
    }

    /// Faults this link has actually fired so far.
    pub fn faults_fired(&self) -> u64 {
        self.fired
    }

    /// Witness one firing: count it, log it, and drop a telemetry instant.
    /// Called *before* the fault executes, because `stall` and `exit` never
    /// return. The span's worker id is rewritten once the worker learns its
    /// rank (chaos can fire during the handshake, before `Setup` arrives).
    fn fire(&mut self, dir: Dir, frame: u64, fault: Fault) {
        self.fired += 1;
        crate::obs::log!(
            warn,
            "chaos: firing {fault:?} on {} frame {frame}",
            match dir {
                Dir::Tx => "tx",
                Dir::Rx => "rx",
            }
        );
        crate::obs::instant(crate::obs::SpanKind::Chaos, 0, self.fired as u32, frame);
    }

    /// Send one already-encoded frame, applying any fault planned for it.
    pub fn write_frame(&mut self, w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
        if self.dead {
            return Err(cut_link());
        }
        self.tx_frames += 1;
        let fault = self.plan.lookup(Dir::Tx, self.tx_frames);
        if let Some(f) = fault {
            self.fire(Dir::Tx, self.tx_frames, f);
        }
        match fault {
            None => wire::write_frame(w, frame),
            Some(Fault::Delay(d)) => {
                std::thread::sleep(d);
                wire::write_frame(w, frame)
            }
            Some(Fault::Stall) => stall(),
            Some(Fault::Drop) => Ok(()),
            Some(Fault::Truncate(n)) => {
                let n = n.min(frame.len());
                w.write_all(&frame[..n])?;
                w.flush()?;
                self.dead = true;
                Err(cut_link())
            }
            Some(Fault::Garbage) => {
                let mut garbled = frame.to_vec();
                self.garble(&mut garbled);
                wire::write_frame(w, &garbled)
            }
            Some(Fault::Exit(code)) => std::process::exit(code),
        }
    }

    /// Read one frame, applying any fault planned for it.
    pub fn read_frame(&mut self, r: &mut impl Read) -> std::io::Result<Vec<u8>> {
        loop {
            if self.dead {
                return Err(cut_link());
            }
            self.rx_frames += 1;
            let fault = self.plan.lookup(Dir::Rx, self.rx_frames);
            if let Some(f) = fault {
                self.fire(Dir::Rx, self.rx_frames, f);
            }
            if let Some(Fault::Exit(code)) = fault {
                std::process::exit(code);
            }
            if let Some(Fault::Stall) = fault {
                stall();
            }
            if let Some(Fault::Delay(d)) = fault {
                std::thread::sleep(d);
            }
            let mut frame = wire::read_frame_io(r)?;
            match fault {
                Some(Fault::Drop) => continue, // discard, deliver the next frame
                Some(Fault::Truncate(n)) => {
                    frame.truncate(n);
                    self.dead = true;
                    return Ok(frame);
                }
                Some(Fault::Garbage) => {
                    self.garble(&mut frame);
                    return Ok(frame);
                }
                _ => return Ok(frame),
            }
        }
    }

    /// XOR the payload (everything after the 16-byte header) with the
    /// seeded keystream. The length prefix and tag stay valid so the frame
    /// still *frames* — the corruption must be caught by `decode`, which is
    /// exactly the hardening the wire proptests pin.
    fn garble(&mut self, frame: &mut [u8]) {
        let start = (crate::coordinator::messages::HEADER_BYTES as usize).min(frame.len());
        for b in &mut frame[start..] {
            *b ^= (self.rng.next_u32() & 0xff) as u8;
        }
        if frame.len() == start && start > 5 {
            // header-only frame: flip the per-tag fields instead (bytes
            // 5.. — never the length prefix or tag, framing must survive)
            for b in &mut frame[5..start] {
                *b ^= (self.rng.next_u32() & 0xff) as u8;
            }
        }
    }
}

fn stall() -> ! {
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cut_link() -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::BrokenPipe, "chaos: link cut by truncate fault")
}

/// True for the first `DEMST_CHAOS_PEER_DENY` calls in this process, then
/// false forever (and always false when the env var is unset). The worker
/// consults this before dialing a peer-tree fetch; a denial surfaces as the
/// ordinary fetch-failure path: reply `PairFail`, let the leader demote the
/// route and return the job to the exactly-once lane.
pub fn peer_fetch_denied() -> bool {
    static LEFT: OnceLock<AtomicI64> = OnceLock::new();
    let left = LEFT.get_or_init(|| {
        let n = std::env::var(PEER_DENY_ENV).ok().and_then(|s| s.parse::<i64>().ok()).unwrap_or(0);
        AtomicI64::new(n)
    });
    left.fetch_sub(1, Ordering::Relaxed) > 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::Message;

    #[test]
    fn plan_parses_every_fault_kind() {
        let plan =
            FaultPlan::parse("tx5:stall, rx3:drop, tx7:delay:250, tx4:truncate:8, tx2:garbage, rx6:exit:113")
                .unwrap();
        assert_eq!(plan.lookup(Dir::Tx, 5), Some(Fault::Stall));
        assert_eq!(plan.lookup(Dir::Rx, 3), Some(Fault::Drop));
        assert_eq!(plan.lookup(Dir::Tx, 7), Some(Fault::Delay(Duration::from_millis(250))));
        assert_eq!(plan.lookup(Dir::Tx, 4), Some(Fault::Truncate(8)));
        assert_eq!(plan.lookup(Dir::Tx, 2), Some(Fault::Garbage));
        assert_eq!(plan.lookup(Dir::Rx, 6), Some(Fault::Exit(113)));
        assert_eq!(plan.lookup(Dir::Rx, 5), None, "tx plan must not fire on rx");
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
    }

    #[test]
    fn plan_rejects_malformed_entries() {
        for bad in ["5:stall", "tx0:stall", "txfive:stall", "tx5:fry", "tx5:delay", "tx5:stall:9:9"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn drop_fault_swallows_exactly_the_planned_frame() {
        let plan = FaultPlan::parse("tx2:drop").unwrap();
        let mut link = ChaosLink::new(plan, 1);
        let mut buf = Vec::new();
        let frames: Vec<Vec<u8>> = (0..3)
            .map(|id| wire::encode(&Message::Ack { job_id: id }).unwrap())
            .collect();
        for f in &frames {
            link.write_frame(&mut buf, f).unwrap();
        }
        // frame 2 (job_id 1) vanished; framing of the rest is intact
        let mut cursor = &buf[..];
        assert_eq!(wire::read_frame(&mut cursor).unwrap(), frames[0]);
        assert_eq!(wire::read_frame(&mut cursor).unwrap(), frames[2]);
        assert!(cursor.is_empty());
        assert_eq!(link.faults_fired(), 1, "exactly the planned fault counted");
    }

    #[test]
    fn truncate_fault_cuts_the_link_for_good() {
        let plan = FaultPlan::parse("tx1:truncate:8").unwrap();
        let mut link = ChaosLink::new(plan, 1);
        let mut buf = Vec::new();
        let frame = wire::encode(&Message::Ack { job_id: 7 }).unwrap();
        assert!(link.write_frame(&mut buf, &frame).is_err());
        assert_eq!(buf.len(), 8, "only the truncated prefix went out");
        // every later write fails too — the link is dead, like a real cut
        assert!(link.write_frame(&mut buf, &frame).is_err());
        assert_eq!(buf.len(), 8);
        assert_eq!(link.faults_fired(), 1, "dead-link errors are not new faults");
    }

    #[test]
    fn garbage_fault_is_deterministic_and_caught_by_decode() {
        let msg = Message::Result {
            job_id: 9,
            worker: 1,
            edges: vec![crate::graph::Edge::new(0, 1, 1.0); 4],
            compute: Duration::ZERO,
        };
        let frame = wire::encode(&msg).unwrap();
        let garble_once = |seed| {
            let mut link = ChaosLink::new(FaultPlan::parse("tx1:garbage").unwrap(), seed);
            let mut buf = Vec::new();
            link.write_frame(&mut buf, &frame).unwrap();
            buf
        };
        let a = garble_once(42);
        assert_eq!(a, garble_once(42), "same seed, same corruption");
        assert_ne!(a, garble_once(43), "different seed, different corruption");
        assert_ne!(a, frame, "payload actually corrupted");
        assert_eq!(a.len(), frame.len(), "framing length untouched");
        // the corrupted frame still reads as one frame, and decode must
        // return a clean error or a (wrong) message — never panic
        let mut cursor = &a[..];
        let read = wire::read_frame(&mut cursor).unwrap();
        let _ = wire::decode(&read, None);
    }

    #[test]
    fn rx_drop_delivers_the_following_frame() {
        let plan = FaultPlan::parse("rx1:drop").unwrap();
        let mut link = ChaosLink::new(plan, 1);
        let first = wire::encode(&Message::Ack { job_id: 1 }).unwrap();
        let second = wire::encode(&Message::Ack { job_id: 2 }).unwrap();
        let mut stream = Vec::new();
        stream.extend_from_slice(&first);
        stream.extend_from_slice(&second);
        let mut cursor = &stream[..];
        assert_eq!(link.read_frame(&mut cursor).unwrap(), second);
    }

    #[test]
    fn peer_deny_unset_is_always_false() {
        // the env var is not set in the test process, so the counter arms
        // at 0 and the hook must never fire
        assert!(!peer_fetch_denied());
        assert!(!peer_fetch_denied());
    }
}
