//! The leader-side remote proxy solver: one [`RemoteSolver`] per pool
//! thread, shipping the jobs that thread claims to its remote worker
//! process over the [`TcpTransport`] link.
//!
//! The engine stays unmodified above this type: affinity decks, idle
//! stealing, the resident-set byte model, and streaming reduction all run
//! at the leader exactly as under the simulated transport — the proxy just
//! realizes the engine's computed [`Shipment`] as a `PairAssign` frame
//! (whose encoded length *is* the engine's modeled scatter charge) and
//! turns the worker's `Result`/`Ack` replies back into solver returns. The
//! shutdown rendezvous ([`PairSolver::finish`]) collects the worker
//! process's final `WorkerDone` stats: remotely measured busy time,
//! distance evaluations, panel-cache hits, and — in reduce mode — the
//! remotely ⊕-folded worker tree.

use super::tcp::TcpTransport;
use super::Direction;
use crate::coordinator::messages::{Message, SubsetShip};
use crate::data::Dataset;
use crate::decomp::PairJob;
use crate::exec::plan::ExecPlan;
use crate::exec::{LocalMstCache, PairSolver, Shipment, Solved, SolverFinal};
use crate::graph::Edge;
use anyhow::bail;

/// Proxy solver for one leader↔worker link (strict request→response
/// rendezvous; the link's mutex is never contended because exactly one pool
/// thread drives each worker).
pub struct RemoteSolver<'a> {
    tcp: &'a TcpTransport,
    worker: usize,
    ds: &'a Dataset,
    cache: Option<&'a LocalMstCache>,
    /// reduce mode: the worker ⊕-folds pair trees locally and replies `Ack`
    reduce: bool,
}

impl<'a> RemoteSolver<'a> {
    pub fn new(
        tcp: &'a TcpTransport,
        worker: usize,
        ds: &'a Dataset,
        cache: Option<&'a LocalMstCache>,
        reduce: bool,
    ) -> Self {
        Self { tcp, worker, ds, cache, reduce }
    }

    /// Materialize the engine's shipment decision for one subset slot.
    fn ship_subset(&self, plan: &ExecPlan, part: u32, vectors: bool, tree: bool) -> SubsetShip {
        let ids = &plan.parts[part as usize];
        SubsetShip {
            part,
            vectors: if vectors { Some((ids.clone(), self.ds.gather(ids))) } else { None },
            tree: if tree {
                Some(self.cache.expect("tree ship requires the local-MST cache").trees
                    [part as usize]
                    .clone())
            } else {
                None
            },
        }
    }
}

impl PairSolver for RemoteSolver<'_> {
    /// The engine's pooled path always goes through [`Self::solve_shipped`];
    /// a bare `solve` means "ship everything" — exactly the engine's dense
    /// model, shared so the two cannot drift.
    fn solve(&mut self, plan: &ExecPlan, job: &PairJob) -> Vec<Edge> {
        let full = crate::exec::engine::dense_shipment(job, self.cache.is_some());
        self.solve_shipped(plan, job, &full)
            .expect("remote solve failed (use solve_shipped for recoverable errors)")
            .edges
    }

    fn solve_shipped(
        &mut self,
        plan: &ExecPlan,
        job: &PairJob,
        ship: &Shipment,
    ) -> anyhow::Result<Solved> {
        let mut ships = Vec::new();
        if ship.vec_i || ship.tree_i {
            ships.push(self.ship_subset(plan, job.i, ship.vec_i, ship.tree_i));
        }
        if job.j != job.i && (ship.vec_j || ship.tree_j) {
            ships.push(self.ship_subset(plan, job.j, ship.vec_j, ship.tree_j));
        }
        let msg = Message::PairAssign { job: *job, ships };
        self.tcp.send_to(self.worker, &msg, Direction::Scatter)?;
        match self.tcp.recv_from(self.worker)? {
            Message::Result { job_id, edges, compute, .. } if job_id == job.id => {
                Ok(Solved { edges, compute: Some(compute) })
            }
            Message::Ack { job_id } if self.reduce && job_id == job.id => {
                // folded into the worker-local tree; collected at finish()
                Ok(Solved { edges: Vec::new(), compute: None })
            }
            other => bail!(
                "worker {} replied {:?} to pair job {} (reduce = {})",
                self.worker,
                other,
                job.id,
                self.reduce
            ),
        }
    }

    fn folds_remotely(&self) -> bool {
        self.reduce
    }

    /// Per-job evaluation counts live in the worker process; they arrive
    /// with the final `WorkerDone` (see [`Self::finish`]).
    fn dist_evals(&self) -> u64 {
        0
    }

    fn finish(&mut self) -> anyhow::Result<SolverFinal> {
        self.tcp.send_to(self.worker, &Message::Shutdown, Direction::Control)?;
        match self.tcp.recv_from(self.worker)? {
            Message::WorkerDone {
                local_tree, dist_evals, busy, panel_hits, panel_misses, ..
            } => Ok(SolverFinal {
                dist_evals,
                panel_hits,
                panel_misses,
                busy: Some(busy),
                local_tree,
            }),
            other => bail!("worker {} replied {other:?} to Shutdown", self.worker),
        }
    }
}
