//! The leader-side remote link driver: one [`RemoteLink`] per pool thread,
//! shipping the pair jobs that thread claims to its remote worker process
//! over the [`TcpTransport`].
//!
//! The engine stays unmodified above this type: affinity decks, the
//! resident-set byte model, and streaming reduction all run at the leader
//! exactly as under the simulated transport — the link just realizes the
//! engine's computed [`Shipment`] as a `PairAssign` frame (whose encoded
//! length *is* the engine's modeled scatter charge) and turns the worker's
//! `Result`/`Ack` replies back into [`Solved`] values.
//!
//! Unlike the pre-pipelining rendezvous proxy, send and receive are
//! **decoupled**: the engine's remote driver keeps up to `pipeline_window`
//! `PairAssign` frames outstanding per link before reading the matching
//! replies, overlapping scatter with remote compute. Workers serve frames
//! strictly in order, so replies are FIFO per link and
//! [`RemoteLink::recv_pair_reply`] always checks against the oldest
//! in-flight job. The shutdown rendezvous ([`RemoteLink::finish`]) drains
//! the link and collects the worker process's final `WorkerDone` stats:
//! remotely measured busy time, distance evaluations, panel-cache hits,
//! and — in reduce mode — the remotely ⊕-folded worker tree.

use super::tcp::TcpTransport;
use super::Direction;
use crate::coordinator::messages::{Message, SubsetShip};
use crate::data::Dataset;
use crate::decomp::PairJob;
use crate::exec::plan::ExecPlan;
use crate::exec::{LocalMstCache, PanelPerf, Shipment, Solved, SolverFinal};
use anyhow::{bail, Result};

/// Driver for one leader↔worker link (frames strictly FIFO; the link's
/// mutex is never contended because exactly one pool thread drives each
/// worker).
pub struct RemoteLink<'a> {
    tcp: &'a TcpTransport,
    worker: usize,
    /// the leader's vectors — `None` on sharded runs, where every vector
    /// is worker-resident and shipping one would be a scheduling bug
    ds: Option<&'a Dataset>,
    cache: Option<&'a LocalMstCache>,
    /// reduce mode: the worker ⊕-folds pair trees locally and replies `Ack`
    reduce: bool,
}

impl<'a> RemoteLink<'a> {
    pub fn new(
        tcp: &'a TcpTransport,
        worker: usize,
        ds: Option<&'a Dataset>,
        cache: Option<&'a LocalMstCache>,
        reduce: bool,
    ) -> Self {
        Self { tcp, worker, ds, cache, reduce }
    }

    /// Materialize the engine's shipment decision for one subset slot.
    /// `routed` replaces an inline tree with a zero-payload routed section:
    /// the worker pulls the tree from its building anchor over a peer link.
    fn ship_subset(
        &self,
        plan: &ExecPlan,
        part: u32,
        vectors: bool,
        tree: bool,
        routed: bool,
    ) -> Result<SubsetShip> {
        let vectors = if vectors {
            let ids = &plan.parts[part as usize];
            let ds = match self.ds {
                Some(ds) => ds,
                None => bail!(
                    "subset {part}: vectors requested from a sharded leader that holds none (resident-set seeding bug)"
                ),
            };
            Some((ids.clone(), ds.gather(ids)))
        } else {
            None
        };
        let tree = if tree {
            Some(
                self.cache.expect("tree ship requires the local-MST cache").trees
                    [part as usize]
                    .clone(),
            )
        } else {
            None
        };
        Ok(SubsetShip { part, vectors, tree, routed })
    }

    /// Put one pair job on the wire (does **not** wait for the reply —
    /// that is [`Self::recv_pair_reply`]'s job, window frames later).
    pub fn send_pair(&self, plan: &ExecPlan, job: &PairJob, ship: &Shipment) -> Result<()> {
        let mut ships = Vec::new();
        if ship.vec_i || ship.tree_i || ship.route_i {
            ships.push(self.ship_subset(plan, job.i, ship.vec_i, ship.tree_i, ship.route_i)?);
        }
        if job.j != job.i && (ship.vec_j || ship.tree_j || ship.route_j) {
            ships.push(self.ship_subset(plan, job.j, ship.vec_j, ship.tree_j, ship.route_j)?);
        }
        let msg = Message::PairAssign { job: *job, ships };
        self.tcp.send_to(self.worker, &msg, Direction::Scatter)?;
        Ok(())
    }

    /// Read the reply of the **oldest** outstanding pair job (`expect` —
    /// FIFO per link). Gather mode returns the pair tree; reduce mode
    /// returns an empty `Solved` once the worker's `Ack` confirms the fold.
    /// `Ok(None)` means the worker's peer-routed tree fetch failed and the
    /// job was **not** executed — the caller must return it to the
    /// exactly-once lane and re-plan it with the tree shipped inline.
    pub fn recv_pair_reply(&self, expect: &PairJob) -> Result<Option<Solved>> {
        match self.tcp.recv_from(self.worker)? {
            Message::Result { job_id, edges, compute, .. } if job_id == expect.id => {
                Ok(Some(Solved { edges, compute: Some(compute) }))
            }
            Message::Ack { job_id } if self.reduce && job_id == expect.id => {
                // folded into the worker-local tree; collected at finish()
                Ok(Some(Solved { edges: Vec::new(), compute: None }))
            }
            Message::PairFail { job_id } if job_id == expect.id => Ok(None),
            other => bail!(
                "worker {} replied {:?} while pair job {} was the oldest in flight (reduce = {})",
                self.worker,
                other,
                expect.id,
                self.reduce
            ),
        }
    }

    /// Drive one ⊕-fold hop of a tree/ring reduction schedule: tell the
    /// worker to wait for `expect` peer partials, fold them into its own,
    /// and ship the result to worker `to` (or keep it, when
    /// `to == FOLD_KEEP`). Returns the worker's `FoldDone.ok` — `false`
    /// means a peer never delivered and the worker kept its partial for
    /// the leader-assisted fallback. Must only be called with no pair jobs
    /// in flight on this link.
    pub fn fold(&self, to: u16, expect: u16) -> Result<bool> {
        self.tcp.send_to(self.worker, &Message::FoldShip { to, expect }, Direction::Control)?;
        match self.tcp.recv_from(self.worker)? {
            Message::FoldDone { ok } => Ok(ok),
            other => bail!("worker {} replied {other:?} to FoldShip", self.worker),
        }
    }

    /// Shutdown rendezvous: ask the worker process to drain and report.
    /// Must only be called with no pair jobs in flight.
    pub fn finish(&self) -> Result<SolverFinal> {
        self.tcp.send_to(self.worker, &Message::Shutdown, Direction::Control)?;
        match self.tcp.recv_from(self.worker)? {
            Message::WorkerDone {
                local_tree,
                dist_evals,
                busy,
                panel_hits,
                panel_misses,
                panel_flops,
                panel_time,
                panel_threads,
                panel_isa,
                peer_tx_bytes,
                peer_ships,
                spans,
                now_ns,
                chaos_faults,
                metrics,
                ..
            } => Ok(SolverFinal {
                dist_evals,
                panel_hits,
                panel_misses,
                panel_perf: PanelPerf {
                    flops: panel_flops,
                    time: panel_time,
                    threads: panel_threads,
                    isa: panel_isa,
                },
                busy: Some(busy),
                local_tree,
                peer_tx_bytes,
                peer_ships,
                spans,
                now_ns,
                chaos_faults,
                metrics,
            }),
            other => bail!("worker {} replied {other:?} to Shutdown", self.worker),
        }
    }
}
