//! The network layer: one charge/send interface, two transports.
//!
//! Everything the exec engine knows about communication is the [`Transport`]
//! trait: *charge* bytes to a per-direction counter and *deliver* a
//! [`Message`] into the leader's channel. Two implementations sit behind it:
//!
//! - [`sim::NetSim`] — the in-process simulated fabric (threads share
//!   memory; bytes are modeled, optionally with a latency + bandwidth sleep).
//!   Byte model and counters are unchanged from when it lived in
//!   `coordinator::netsim`; every pinned counter test still holds
//!   byte-for-byte.
//! - [`tcp::TcpTransport`] — a real multi-process transport: one blocking
//!   TCP socket per leader↔worker link, length-prefixed binary frames
//!   ([`wire`]) with a versioned handshake, and counters populated from the
//!   **actual encoded frame sizes** as they cross the socket. Because the
//!   wire codec is the single source of truth for [`Message::wire_bytes`],
//!   the simulated and measured byte counts agree exactly for the
//!   deterministic configurations (see `tests/transport_tcp.rs`).
//!
//! The remaining modules put the wire to work: [`remote`] is the
//! leader-side link driver that ships pair jobs to a remote worker for the
//! unmodified exec engine (affinity decks, resident-set model, panel
//! cache, and streaming reduction all inherited) with a bounded in-flight
//! window per link, [`worker`] is the `demst worker` process loop on the
//! other end (optionally serving subsets it loaded from local shard
//! files), and [`launch`] binds, spawns, handshakes, and awaits the worker
//! set around one engine run — keeping the listener open afterwards so a
//! replacement worker can be **admitted mid-run** (`Join`/`AdmitAck`).
//!
//! Liveness: post-handshake reads on every link (leader↔worker and
//! worker↔worker) run under a configurable read deadline
//! (`[net] liveness_timeout_ms`), with the leader heartbeating idle links
//! so deadlines only trip on genuinely stalled peers; a tripped deadline
//! is tagged with [`STALL_MARK`] and demoted through the same exactly-once
//! return lane as a dead link. [`chaos`] is the deterministic
//! fault-injection wrapper (seeded delays/drops/truncation/garbage on
//! frame N) that makes every one of those failure paths reproducibly
//! testable.

pub mod chaos;
pub mod launch;
pub mod remote;
pub mod sim;
pub mod tcp;
pub mod wire;
pub mod worker;

use crate::coordinator::messages::Message;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

pub use sim::NetSim;
pub use tcp::TcpTransport;

/// Marker substring tagged onto every error raised by a tripped liveness
/// read deadline. The vendored `anyhow` carries string frames only (no
/// downcasting), so stall classification is by marker: [`is_stall`] scans
/// the error chain for this string. Keep it stable — metrics
/// (`stalls_detected`) and tests key off it.
pub const STALL_MARK: &str = "liveness timeout";

/// True when `kind` is how this platform reports a socket read deadline
/// expiring: Unix returns `WouldBlock` for `SO_RCVTIMEO`, Windows
/// `TimedOut` — both mean "peer silent past the deadline", not "link dead".
pub fn is_timeout_kind(kind: std::io::ErrorKind) -> bool {
    matches!(kind, std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// True when `err` (anywhere in its chain) was raised by a tripped
/// liveness deadline — a **stalled** peer, as opposed to a dead one. The
/// engine counts these separately (`RunMetrics::stalls_detected`) but
/// demotes both through the same exactly-once return lane.
pub fn is_stall(err: &anyhow::Error) -> bool {
    err.chain().any(|frame| frame.contains(STALL_MARK))
}

/// Traffic direction, for the per-phase accounting the paper's cost model
/// distinguishes (scatter of vectors vs gather of tree edges). `Peer` is
/// worker↔worker traffic that never crosses a leader link (routed tree
/// ships and ⊕-fold hops).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Scatter,
    Gather,
    Control,
    Peer,
}

/// Shared traffic counters.
#[derive(Debug, Default)]
pub struct NetCounters {
    pub scatter_bytes: AtomicU64,
    pub gather_bytes: AtomicU64,
    pub control_bytes: AtomicU64,
    pub peer_bytes: AtomicU64,
    pub messages: AtomicU64,
}

impl NetCounters {
    /// Leader-link bytes (scatter + gather + control). Peer bytes are kept
    /// out on purpose: they are the traffic that *left* the leader.
    pub fn total_bytes(&self) -> u64 {
        self.scatter_bytes.load(Ordering::Relaxed)
            + self.gather_bytes.load(Ordering::Relaxed)
            + self.control_bytes.load(Ordering::Relaxed)
    }

    /// Leader-link snapshot (scatter, gather, control, messages) — the
    /// 4-tuple every reconciliation test pins. Peer traffic is read
    /// separately via [`NetCounters::peer`].
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.scatter_bytes.load(Ordering::Relaxed),
            self.gather_bytes.load(Ordering::Relaxed),
            self.control_bytes.load(Ordering::Relaxed),
            self.messages.load(Ordering::Relaxed),
        )
    }

    /// Worker↔worker bytes (not part of [`NetCounters::total_bytes`]).
    pub fn peer(&self) -> u64 {
        self.peer_bytes.load(Ordering::Relaxed)
    }

    /// Add one message of `bytes` to the direction's counter.
    pub fn add(&self, bytes: u64, dir: Direction) {
        self.add_bytes(bytes, dir);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `bytes` to the direction's counter **without** counting a
    /// message — used when a modeled transfer's bytes accrue to a frame
    /// that is already counted (e.g. the root worker's fold result riding
    /// inside its `WorkerDone`).
    pub fn add_bytes(&self, bytes: u64, dir: Direction) {
        let ctr = match dir {
            Direction::Scatter => &self.scatter_bytes,
            Direction::Gather => &self.gather_bytes,
            Direction::Control => &self.control_bytes,
            Direction::Peer => &self.peer_bytes,
        };
        ctr.fetch_add(bytes, Ordering::Relaxed);
    }
}

/// The charge/send interface the exec engine runs against.
///
/// `charge` accounts for a *modeled* transfer: the simulated fabric adds it
/// to the counters (and optionally sleeps for the link model); a real
/// transport **no-ops**, because its counters are fed by actual frames at
/// the socket boundary — the engine's model calls would double-count them.
/// The two stay consistent because [`Message::wire_bytes`] is computed from
/// the real [`wire`] encoding, so "modeled" and "measured" are the same
/// number.
pub trait Transport: Sync {
    /// This transport's shared traffic counters.
    fn counters(&self) -> Arc<NetCounters>;

    /// Account for a modeled transfer of `bytes` (no delivery).
    fn charge(&self, bytes: u64, dir: Direction);

    /// Account for `msg` and deliver it into an in-process channel.
    /// Returns `Err` if the receiving endpoint hung up.
    fn send(
        &self,
        tx: &Sender<Message>,
        msg: Message,
        dir: Direction,
    ) -> Result<(), std::sync::mpsc::SendError<Message>> {
        self.charge(msg.wire_bytes(), dir);
        tx.send(msg)
    }
}
