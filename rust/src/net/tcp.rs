//! The real transport: one blocking TCP socket per leader↔worker link.
//!
//! [`TcpTransport`] is the leader-side fabric: it owns every accepted link
//! and implements [`Transport`] so the unmodified exec engine can run over
//! it. Its byte counters are populated from the **actual encoded frame
//! sizes** as frames cross the socket — which is why
//! [`Transport::charge`] no-ops here: the engine's modeled charges would
//! double-count the frames the proxy solvers really send. The two
//! accountings agree because [`wire::encoded_len`] is the single source of
//! truth for both.
//!
//! Direction attribution mirrors the simulated fabric: frames the leader
//! writes are `Scatter` (jobs) or `Control` (shutdown/handshake); frames it
//! reads are `Gather` (results, trees, final stats) or `Control` (acks).
//! The handshake itself is control-plane traffic the simulation does not
//! model, so `control_bytes` differs between transports by design while
//! scatter/gather match exactly.
//!
//! Liveness: when the run's `Setup` carries a nonzero `liveness_ms`, every
//! link keeps that read deadline **after** the handshake too (instead of
//! clearing it) — a worker silent past the deadline surfaces as an error
//! tagged [`super::STALL_MARK`], which the engine demotes like a dead link
//! but counts separately. The deadline therefore bounds the leader's wait
//! for any single reply; configure it above the worst-case single-job
//! compute time. Heartbeats over idle links (sent by the engine's pulse
//! thread) keep the *worker-side* deadline from tripping while the leader
//! is merely quiet.
//!
//! Admission: the transport's link table can **grow mid-run** —
//! [`TcpTransport::admit_worker`] runs the versioned `Join`/`AdmitAck`
//! handshake on a freshly accepted connection and appends the new link, so
//! the engine can open a deck for it while the run is in flight.

use super::wire::{self, Setup};
use super::{Direction, NetCounters, Transport};
use crate::coordinator::messages::{Message, PeerAddr};
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One accepted, handshaken leader↔worker link.
struct Link {
    stream: TcpStream,
}

/// The leader-side multi-process fabric: `links[w]` is worker `w`'s socket.
/// Each link is driven by exactly one proxy thread (the engine's pooled
/// worker for that rank); frames on a link are strictly FIFO, with up to
/// `pipeline_window` requests outstanding before their replies are read.
/// The table is append-only behind an `RwLock`: startup workers are
/// accepted in bulk, mid-run admissions push new links while existing
/// drivers keep running.
pub struct TcpTransport {
    links: RwLock<Vec<Arc<Mutex<Link>>>>,
    /// shard ids advertised by each worker during the versioned handshake
    /// (empty on unsharded workers)
    advertised: RwLock<Vec<Vec<u32>>>,
    /// each worker's peer-plane listener address: the IP its leader
    /// connection arrived from + the port its `Hello` advertised (port 0 =
    /// no listener — the worker could not bind one)
    peer_addrs: RwLock<Vec<PeerAddr>>,
    /// per-link read deadline (None = wait forever, pre-liveness behavior)
    liveness: Option<Duration>,
    counters: Arc<NetCounters>,
    /// where `MetricsPush` snapshots absorbed off any link land (set by the
    /// engine once its per-run hub exists; None = pushes are counted and
    /// dropped)
    metrics_sink: RwLock<Option<Arc<crate::obs::metrics::MetricsHub>>>,
}

impl Transport for TcpTransport {
    fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// No-op: real frames are counted at the socket boundary.
    fn charge(&self, _bytes: u64, _dir: Direction) {}
}

impl TcpTransport {
    /// Number of worker links (including any admitted mid-run).
    pub fn len(&self) -> usize {
        self.links.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The per-link read deadline this fabric was set up with (None =
    /// liveness disabled). The engine derives its heartbeat interval from
    /// this (`deadline / 3`).
    pub fn liveness(&self) -> Option<Duration> {
        self.liveness
    }

    fn link(&self, w: usize) -> Result<Arc<Mutex<Link>>> {
        let links = self.links.read().unwrap();
        match links.get(w) {
            Some(link) => Ok(Arc::clone(link)),
            None => bail!("no link for worker {w} ({} links)", links.len()),
        }
    }

    /// Accept, verify, and set up `n` worker connections on `listener`.
    /// Worker ids are assigned in accept order; `setup` is completed with
    /// each worker's id. `deadline` bounds the whole accept+handshake phase
    /// so a missing worker fails the run instead of hanging it. A
    /// connection that fails the handshake (port scanner, health check,
    /// version-mismatched worker) is logged and dropped — it must not kill
    /// the accept phase while the real workers are still connecting.
    pub fn accept_workers(
        listener: &TcpListener,
        n: usize,
        setup: &Setup,
        deadline: Duration,
    ) -> Result<Self> {
        let counters = Arc::new(NetCounters::default());
        let liveness = liveness_of(setup);
        let t0 = Instant::now();
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let mut links = Vec::with_capacity(n);
        let mut advertised = Vec::with_capacity(n);
        let mut peer_addrs = Vec::with_capacity(n);
        while links.len() < n {
            // Checked every iteration, not only when the queue is empty: a
            // stream of connecting-but-stalling peers (each burning its
            // handshake read timeout) must not extend the phase forever.
            if t0.elapsed() > deadline {
                bail!(
                    "accepted {}/{} workers within {deadline:?} — are the `demst worker --connect` processes running?",
                    links.len(),
                    n
                );
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    let w = links.len();
                    match handshake_leader(&stream, w, setup, liveness, &counters) {
                        Ok((shard_ids, peer_port)) => {
                            links.push(Arc::new(Mutex::new(Link { stream })));
                            advertised.push(shard_ids);
                            // the observed source IP reaches the worker's
                            // host from here; pair it with the advertised
                            // listener port for the fleet's PeerBook
                            peer_addrs.push(PeerAddr { ip: peer.ip(), port: peer_port });
                        }
                        Err(e) => {
                            crate::obs::log!(
                                warn,
                                "leader: rejected connection from {peer}: {e:#}"
                            );
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
        Ok(Self {
            links: RwLock::new(links),
            advertised: RwLock::new(advertised),
            peer_addrs: RwLock::new(peer_addrs),
            liveness,
            counters,
            metrics_sink: RwLock::new(None),
        })
    }

    /// Point absorbed `MetricsPush` frames at the run's fleet hub. Until
    /// this is called pushes are byte-counted and dropped, which is correct
    /// for runs that never arm metrics.
    pub fn set_metrics_sink(&self, hub: Arc<crate::obs::metrics::MetricsHub>) {
        *self.metrics_sink.write().unwrap() = Some(hub);
    }

    /// Run the mid-run admission handshake on a freshly accepted connection
    /// and append it to the link table: expect `Hello`, answer with the run
    /// `Setup` stamped `mid_run` and the next free worker id, expect the
    /// versioned `Join` + `ShardAdvertise`, confirm with `AdmitAck`. The
    /// worker id is final once this returns — the caller (launch's
    /// admission thread, which serializes admissions) hands it to the
    /// engine to open a deck and spawn a link driver. The manifest check is
    /// worker-side, exactly like startup: a worker whose shard manifest
    /// does not match `setup.manifest` hangs up instead of sending `Join`.
    pub fn admit_worker(
        &self,
        stream: TcpStream,
        peer_ip: std::net::IpAddr,
        setup: &Setup,
    ) -> Result<usize> {
        let w = self.links.read().unwrap().len();
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .context("setting admission handshake timeout")?;
        let mut s = &stream;

        let hello_frame = wire::read_frame_capped_io(&mut s, wire::MAX_HANDSHAKE_PAYLOAD)
            .context("reading Hello")?;
        let hello = wire::decode_hello(&hello_frame)?;
        self.counters.add(hello_frame.len() as u64, Direction::Control);

        let setup = Setup { worker_id: w as u16, mid_run: true, ..setup.clone() };
        let setup_frame = wire::encode_setup(&setup)?;
        wire::write_frame(&mut s, &setup_frame).context("sending mid-run Setup")?;
        self.counters.add(setup_frame.len() as u64, Direction::Control);

        let join_frame = wire::read_frame_capped_io(&mut s, wire::MAX_HANDSHAKE_PAYLOAD)
            .context("reading Join")?;
        let join = wire::decode_join(&join_frame)?;
        if join.worker_id != w as u16 {
            bail!("joining worker acked id {} but was assigned {w}", join.worker_id);
        }
        self.counters.add(join_frame.len() as u64, Direction::Control);

        let adv_frame = wire::read_frame_capped_io(&mut s, wire::MAX_HANDSHAKE_PAYLOAD)
            .context("reading ShardAdvertise")?;
        let adv = wire::decode_shard_advertise(&adv_frame)?;
        if adv.worker_id != w as u16 {
            bail!("joining worker advertised as id {} but was assigned {w}", adv.worker_id);
        }
        self.counters.add(adv_frame.len() as u64, Direction::Control);

        let ack_frame = wire::encode_admit_ack(&wire::AdmitAck { worker_id: w as u16 });
        wire::write_frame(&mut s, &ack_frame).context("sending AdmitAck")?;
        self.counters.add(ack_frame.len() as u64, Direction::Control);

        stream.set_read_timeout(self.liveness).context("setting link read deadline")?;
        // advertised/peer_addrs first so `advertised(w)` is valid the
        // moment `len()` covers w
        self.advertised.write().unwrap().push(adv.shard_ids);
        self.peer_addrs.write().unwrap().push(PeerAddr { ip: peer_ip, port: hello.peer_port });
        self.links.write().unwrap().push(Arc::new(Mutex::new(Link { stream })));
        Ok(w)
    }

    /// Shard ids worker `w` advertised during the handshake (subsets it
    /// loaded from local shard files; empty for unsharded workers).
    pub fn advertised(&self, w: usize) -> Vec<u32> {
        self.advertised.read().unwrap()[w].clone()
    }

    /// The fleet's peer-plane listener addresses, indexed by worker id
    /// (port 0 = that worker bound no listener).
    pub fn peer_addrs(&self) -> Vec<PeerAddr> {
        self.peer_addrs.read().unwrap().clone()
    }

    /// Send one message frame to worker `w`, counting its actual encoded
    /// size under `dir`. Returns the frame length.
    pub fn send_to(&self, w: usize, msg: &Message, dir: Direction) -> Result<u64> {
        let frame = wire::encode(msg)?;
        let link = self.link(w)?;
        let mut link = link.lock().unwrap();
        wire::write_frame(&mut link.stream, &frame)
            .with_context(|| format!("sending to worker {w}"))?;
        self.counters.add(frame.len() as u64, dir);
        Ok(frame.len() as u64)
    }

    /// Receive one message frame from worker `w`, counting its actual size
    /// under the direction implied by its type (results/trees/stats =
    /// gather, acks = control). Heartbeats are counted as control and
    /// skipped — they exist to keep deadlines from tripping, not to carry
    /// state. A read deadline expiring here is reported as a stall
    /// ([`super::STALL_MARK`]), distinct from a closed link.
    pub fn recv_from(&self, w: usize) -> Result<Message> {
        let link = self.link(w)?;
        loop {
            let frame = {
                let mut link = link.lock().unwrap();
                match wire::read_frame_io(&mut link.stream) {
                    Ok(frame) => frame,
                    Err(e) if super::is_timeout_kind(e.kind()) => {
                        bail!(
                            "worker {w} {}: no frame within the {:?} read deadline",
                            super::STALL_MARK,
                            self.liveness.unwrap_or_default()
                        );
                    }
                    Err(e) => {
                        return Err(e).with_context(|| format!("receiving from worker {w}"));
                    }
                }
            };
            let msg = wire::decode(&frame, None)
                .with_context(|| format!("decoding frame from worker {w}"))?;
            let dir = match &msg {
                Message::Result { .. } | Message::WorkerDone { .. } | Message::LocalDone { .. } => {
                    Direction::Gather
                }
                Message::Heartbeat => {
                    self.counters.add(frame.len() as u64, Direction::Control);
                    continue;
                }
                // Unsolicited like heartbeats: absorb and keep waiting for
                // the reply the driver is actually blocked on. Consumes no
                // pipeline-window credit.
                Message::MetricsPush { worker, snap } => {
                    self.counters.add(frame.len() as u64, Direction::Control);
                    if let Some(hub) = self.metrics_sink.read().unwrap().as_ref() {
                        hub.absorb(*worker, snap.clone());
                    }
                    continue;
                }
                Message::Ack { .. } | Message::PairFail { .. } | Message::FoldDone { .. } => {
                    Direction::Control
                }
                other => bail!("worker {w} sent an unexpected {other:?}"),
            };
            self.counters.add(frame.len() as u64, dir);
            return Ok(msg);
        }
    }

    /// Blocking rendezvous: send `msg`, then read the worker's reply.
    pub fn request(&self, w: usize, msg: &Message, dir: Direction) -> Result<Message> {
        self.send_to(w, msg, dir)?;
        self.recv_from(w)
    }

    /// One heartbeat round over the whole link table: write a header-only
    /// `Heartbeat` frame to every link whose mutex is immediately free. A
    /// held mutex means the link is mid-exchange — its driver is writing,
    /// or blocked awaiting a reply from a *computing* worker — and a
    /// worker that is computing is not watching its read deadline, so
    /// skipping it is safe and keeps the pulse from blocking behind slow
    /// links. Send errors are ignored: a dead link surfaces on its own
    /// driver's next frame. Returns the number of frames sent.
    pub fn pulse(&self) -> u64 {
        let frame = wire::encode(&Message::Heartbeat).expect("header-only frame encodes");
        let links: Vec<Arc<Mutex<Link>>> =
            self.links.read().unwrap().iter().map(Arc::clone).collect();
        let mut sent = 0;
        for link in links {
            if let Ok(mut link) = link.try_lock() {
                if wire::write_frame(&mut link.stream, &frame).is_ok() {
                    self.counters.add(frame.len() as u64, Direction::Control);
                    sent += 1;
                }
            }
        }
        sent
    }
}

/// The per-link read deadline a run's `Setup` asks for (`liveness_ms == 0`
/// disables it).
fn liveness_of(setup: &Setup) -> Option<Duration> {
    (setup.liveness_ms > 0).then(|| Duration::from_millis(u64::from(setup.liveness_ms)))
}

/// Leader side of the per-connection handshake: expect `Hello`, answer with
/// the run `Setup` (stamped with this link's worker id), confirm the ack,
/// then read the worker's `ShardAdvertise` (its locally loaded subset ids —
/// empty for unsharded workers). Handshake frames are counted as control
/// traffic and read under the tighter [`wire::MAX_HANDSHAKE_PAYLOAD`] cap —
/// nothing pre-trust may declare a giant payload. Returns the advertised
/// shard ids. On success the link's read deadline becomes `liveness`
/// (None = wait forever).
fn handshake_leader(
    stream: &TcpStream,
    worker_id: usize,
    setup: &Setup,
    liveness: Option<Duration>,
    counters: &NetCounters,
) -> Result<(Vec<u32>, u16)> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake timeout")?;
    let mut stream = stream;
    let hello_frame = wire::read_frame_capped_io(&mut stream, wire::MAX_HANDSHAKE_PAYLOAD)
        .context("reading Hello")?;
    let hello = wire::decode_hello(&hello_frame)?;
    counters.add(hello_frame.len() as u64, Direction::Control);

    let setup = Setup { worker_id: worker_id as u16, ..setup.clone() };
    let setup_frame = wire::encode_setup(&setup)?;
    wire::write_frame(&mut stream, &setup_frame).context("sending Setup")?;
    counters.add(setup_frame.len() as u64, Direction::Control);

    let ack_frame = wire::read_frame_capped_io(&mut stream, wire::MAX_HANDSHAKE_PAYLOAD)
        .context("reading SetupAck")?;
    let ack = wire::decode_setup_ack(&ack_frame)?;
    if ack.worker_id != worker_id as u16 {
        bail!("worker acked id {} but was assigned {worker_id}", ack.worker_id);
    }
    counters.add(ack_frame.len() as u64, Direction::Control);

    let adv_frame = wire::read_frame_capped_io(&mut stream, wire::MAX_HANDSHAKE_PAYLOAD)
        .context("reading ShardAdvertise")?;
    let adv = wire::decode_shard_advertise(&adv_frame)?;
    if adv.worker_id != worker_id as u16 {
        bail!("worker advertised as id {} but was assigned {worker_id}", adv.worker_id);
    }
    counters.add(adv_frame.len() as u64, Direction::Control);
    // Job frames can take arbitrarily long to produce answers; the liveness
    // deadline (when enabled) bounds that wait — heartbeats keep it from
    // tripping on merely idle links.
    stream.set_read_timeout(liveness).context("setting link read deadline")?;
    Ok((adv.shard_ids, hello.peer_port))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{Hello, SetupAck, WIRE_VERSION};
    use std::net::TcpStream as ClientStream;

    fn test_setup() -> Setup {
        Setup {
            version: WIRE_VERSION,
            worker_id: 0,
            n: 10,
            d: 2,
            metric: 0,
            kernel: 0,
            pair_kernel: 0,
            reduce_tree: false,
            mid_run: false,
            trace: false,
            metrics: false,
            manifest: 0,
            liveness_ms: 0,
            metrics_push_ms: 0,
            part_sizes: vec![5, 5],
            artifacts_dir: String::new(),
        }
    }

    /// A minimal in-test worker endpoint: handshake (advertising shard 1),
    /// then echo one frame.
    fn fake_worker(addr: std::net::SocketAddr) -> std::thread::JoinHandle<Message> {
        std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 34567 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![1],
                })
                .unwrap(),
            )
            .unwrap();
            let frame = wire::read_frame(&mut s).unwrap();
            let msg = wire::decode(&frame, None).unwrap();
            let reply = Message::Ack { job_id: 42 };
            wire::write_frame(&mut s, &wire::encode(&reply).unwrap()).unwrap();
            msg
        })
    }

    #[test]
    fn accept_handshake_and_rendezvous_count_real_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = fake_worker(addr);
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();
        assert_eq!(fab.len(), 1);
        assert_eq!(fab.advertised(0), &[1], "handshake captured the shard advertisement");
        assert_eq!(fab.peer_addrs().len(), 1);
        assert_eq!(fab.peer_addrs()[0].port, 34567, "Hello's peer port captured");
        assert!(fab.peer_addrs()[0].ip.is_loopback(), "IP observed from the socket");
        let (_, _, c_after_handshake, m) = fab.counters().snapshot();
        assert!(c_after_handshake > 0, "handshake counted as control");
        assert_eq!(m, 4, "hello + setup + ack + shard advertise");

        let msg = Message::Shutdown;
        let reply = fab.request(0, &msg, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 42 });
        assert_eq!(worker.join().unwrap(), Message::Shutdown);
        let (s, g, c, m) = fab.counters().snapshot();
        assert_eq!(s, 0);
        assert_eq!(g, 0, "ack is control, not gather");
        assert_eq!(c, c_after_handshake + 16 + 16, "both 16-byte frames counted");
        assert_eq!(m, 6);
        // charge() must not touch real-transport counters
        fab.charge(1_000_000, Direction::Scatter);
        assert_eq!(fab.counters().snapshot().0, 0);
    }

    /// An unsolicited `MetricsPush` between request and reply is absorbed
    /// into the sink (control bytes, no window credit) and `recv_from`
    /// still returns the reply the driver was blocked on.
    #[test]
    fn metrics_push_is_absorbed_and_does_not_satisfy_recv() {
        use crate::obs::metrics::{Ctr, MetricsHub, Registry};

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 0 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![],
                })
                .unwrap(),
            )
            .unwrap();
            let _req = wire::read_frame(&mut s).unwrap();
            let reg = Registry::new();
            reg.add(Ctr::DistEvals, 77);
            let push = Message::MetricsPush { worker: 0, snap: reg.snapshot() };
            wire::write_frame(&mut s, &wire::encode(&push).unwrap()).unwrap();
            wire::write_frame(&mut s, &wire::encode(&Message::Ack { job_id: 7 }).unwrap())
                .unwrap();
        });
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();
        let hub = Arc::new(MetricsHub::new());
        fab.set_metrics_sink(Arc::clone(&hub));
        let reply = fab.request(0, &Message::Shutdown, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 7 }, "push did not satisfy the rendezvous");
        worker.join().unwrap();
        assert_eq!(hub.workers_reporting(), 1);
        assert_eq!(hub.merged().counter(Ctr::DistEvals), 77);
    }

    /// A stray connection speaking garbage must be rejected without
    /// aborting the accept phase: the real worker behind it still gets in.
    #[test]
    fn stray_connection_does_not_kill_accept_phase() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stray = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            // a complete frame with a bogus tag — decode_hello rejects it
            let mut junk = vec![0u8; 16];
            junk[4] = 200;
            use std::io::Write;
            s.write_all(&junk).unwrap();
            s
        });
        let _stray_stream = stray.join().unwrap();
        let worker = fake_worker(addr);
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(20))
                .unwrap();
        assert_eq!(fab.len(), 1, "real worker accepted after the stray was dropped");
        let reply = fab.request(0, &Message::Shutdown, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 42 });
        worker.join().unwrap();
    }

    #[test]
    fn accept_times_out_with_actionable_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = TcpTransport::accept_workers(
            &listener,
            2,
            &test_setup(),
            Duration::from_millis(80),
        )
        .unwrap_err();
        assert!(err.to_string().contains("0/2 workers"), "{err:#}");
    }

    /// A worker joining mid-run gets the next free id, its advertisement is
    /// recorded, and the appended link carries frames like any other.
    #[test]
    fn admission_handshake_appends_a_usable_link() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = fake_worker(addr);
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();

        let joiner = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 0 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            assert!(setup.mid_run, "admission Setup must be stamped mid_run");
            wire::write_frame(
                &mut s,
                &wire::encode_join(&wire::Join {
                    worker_id: setup.worker_id,
                    version: WIRE_VERSION,
                }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![0, 3],
                })
                .unwrap(),
            )
            .unwrap();
            let ack =
                wire::decode_admit_ack(&wire::read_frame(&mut s).unwrap()).unwrap();
            assert_eq!(ack.worker_id, setup.worker_id);
            // serve one rendezvous over the admitted link
            let frame = wire::read_frame(&mut s).unwrap();
            let msg = wire::decode(&frame, None).unwrap();
            wire::write_frame(&mut s, &wire::encode(&Message::Ack { job_id: 7 }).unwrap())
                .unwrap();
            msg
        });
        // re-accept on the same (still nonblocking) listener
        let stream = loop {
            match listener.accept() {
                Ok((stream, peer)) => break (stream, peer),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => panic!("accept: {e}"),
            }
        };
        let w = fab.admit_worker(stream.0, stream.1.ip(), &test_setup()).unwrap();
        assert_eq!(w, 1, "admitted worker takes the next free id");
        assert_eq!(fab.len(), 2);
        assert_eq!(fab.advertised(1), &[0, 3], "admission captured the advertisement");
        assert_eq!(fab.peer_addrs()[1].port, 0, "joiner bound no peer listener");

        let reply = fab.request(1, &Message::Shutdown, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 7 });
        assert_eq!(joiner.join().unwrap(), Message::Shutdown);
        // the original worker is still reachable on link 0
        let reply = fab.request(0, &Message::Shutdown, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 42 });
        worker.join().unwrap();
    }

    /// With liveness enabled, a worker that goes silent trips the read
    /// deadline and the error is classified as a stall, not a dead link.
    #[test]
    fn silent_worker_is_reported_as_a_stall() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 0 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![],
                })
                .unwrap(),
            )
            .unwrap();
            // stall: keep the socket open but never write another frame
            std::thread::sleep(Duration::from_millis(600));
        });
        let setup = Setup { liveness_ms: 100, ..test_setup() };
        let fab =
            TcpTransport::accept_workers(&listener, 1, &setup, Duration::from_secs(10)).unwrap();
        assert_eq!(fab.liveness(), Some(Duration::from_millis(100)));
        let err = fab.recv_from(0).unwrap_err();
        assert!(crate::net::is_stall(&err), "deadline trip must classify as stall: {err:#}");
        worker.join().unwrap();
    }

    /// A pulse round writes one heartbeat per idle link; a link whose
    /// mutex is held is skipped rather than waited on.
    #[test]
    fn pulse_heartbeats_idle_links_and_skips_held_ones() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 0 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![],
                })
                .unwrap(),
            )
            .unwrap();
            // the pulse's heartbeat arrives as a plain frame
            let frame = wire::read_frame(&mut s).unwrap();
            wire::decode(&frame, None).unwrap()
        });
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();
        assert_eq!(fab.pulse(), 1, "one idle link, one heartbeat");
        assert_eq!(worker.join().unwrap(), Message::Heartbeat);
        // a held link mutex is skipped, not waited on
        let held = fab.link(0).unwrap();
        let _guard = held.lock().unwrap();
        assert_eq!(fab.pulse(), 0, "busy link skipped");
    }

    /// Heartbeat frames are skipped (counted as control) — the next real
    /// frame is what `recv_from` returns.
    #[test]
    fn heartbeats_are_transparent_to_recv() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 0 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![],
                })
                .unwrap(),
            )
            .unwrap();
            wire::write_frame(&mut s, &wire::encode(&Message::Heartbeat).unwrap()).unwrap();
            wire::write_frame(&mut s, &wire::encode(&Message::Heartbeat).unwrap()).unwrap();
            wire::write_frame(&mut s, &wire::encode(&Message::Ack { job_id: 9 }).unwrap())
                .unwrap();
        });
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();
        let (_, _, c_before, _) = fab.counters().snapshot();
        let msg = fab.recv_from(0).unwrap();
        assert_eq!(msg, Message::Ack { job_id: 9 }, "heartbeats skipped, ack delivered");
        let (_, _, c_after, _) = fab.counters().snapshot();
        assert_eq!(c_after, c_before + 16 + 16 + 16, "2 heartbeats + ack all counted control");
        worker.join().unwrap();
    }
}
