//! The real transport: one blocking TCP socket per leader↔worker link.
//!
//! [`TcpTransport`] is the leader-side fabric: it owns every accepted link
//! and implements [`Transport`] so the unmodified exec engine can run over
//! it. Its byte counters are populated from the **actual encoded frame
//! sizes** as frames cross the socket — which is why
//! [`Transport::charge`] no-ops here: the engine's modeled charges would
//! double-count the frames the proxy solvers really send. The two
//! accountings agree because [`wire::encoded_len`] is the single source of
//! truth for both.
//!
//! Direction attribution mirrors the simulated fabric: frames the leader
//! writes are `Scatter` (jobs) or `Control` (shutdown/handshake); frames it
//! reads are `Gather` (results, trees, final stats) or `Control` (acks).
//! The handshake itself is control-plane traffic the simulation does not
//! model, so `control_bytes` differs between transports by design while
//! scatter/gather match exactly.

use super::wire::{self, Setup};
use super::{Direction, NetCounters, Transport};
use crate::coordinator::messages::{Message, PeerAddr};
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One accepted, handshaken leader↔worker link.
struct Link {
    stream: TcpStream,
}

/// The leader-side multi-process fabric: `links[w]` is worker `w`'s socket.
/// Each link is driven by exactly one proxy thread (the engine's pooled
/// worker for that rank); frames on a link are strictly FIFO, with up to
/// `pipeline_window` requests outstanding before their replies are read.
pub struct TcpTransport {
    links: Vec<Mutex<Link>>,
    /// shard ids advertised by each worker during the versioned handshake
    /// (empty on unsharded workers)
    advertised: Vec<Vec<u32>>,
    /// each worker's peer-plane listener address: the IP its leader
    /// connection arrived from + the port its `Hello` advertised (port 0 =
    /// no listener — the worker could not bind one)
    peer_addrs: Vec<PeerAddr>,
    counters: Arc<NetCounters>,
}

impl Transport for TcpTransport {
    fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// No-op: real frames are counted at the socket boundary.
    fn charge(&self, _bytes: u64, _dir: Direction) {}
}

impl TcpTransport {
    /// Number of worker links.
    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Accept, verify, and set up `n` worker connections on `listener`.
    /// Worker ids are assigned in accept order; `setup` is completed with
    /// each worker's id. `deadline` bounds the whole accept+handshake phase
    /// so a missing worker fails the run instead of hanging it. A
    /// connection that fails the handshake (port scanner, health check,
    /// version-mismatched worker) is logged and dropped — it must not kill
    /// the accept phase while the real workers are still connecting.
    pub fn accept_workers(
        listener: &TcpListener,
        n: usize,
        setup: &Setup,
        deadline: Duration,
    ) -> Result<Self> {
        let counters = Arc::new(NetCounters::default());
        let t0 = Instant::now();
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let mut links = Vec::with_capacity(n);
        let mut advertised = Vec::with_capacity(n);
        let mut peer_addrs = Vec::with_capacity(n);
        while links.len() < n {
            // Checked every iteration, not only when the queue is empty: a
            // stream of connecting-but-stalling peers (each burning its
            // handshake read timeout) must not extend the phase forever.
            if t0.elapsed() > deadline {
                bail!(
                    "accepted {}/{} workers within {deadline:?} — are the `demst worker --connect` processes running?",
                    links.len(),
                    n
                );
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    let w = links.len();
                    match handshake_leader(&stream, w, setup, &counters) {
                        Ok((shard_ids, peer_port)) => {
                            links.push(Mutex::new(Link { stream }));
                            advertised.push(shard_ids);
                            // the observed source IP reaches the worker's
                            // host from here; pair it with the advertised
                            // listener port for the fleet's PeerBook
                            peer_addrs.push(PeerAddr { ip: peer.ip(), port: peer_port });
                        }
                        Err(e) => {
                            eprintln!("leader: rejected connection from {peer}: {e:#}");
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
        Ok(Self { links, advertised, peer_addrs, counters })
    }

    /// Shard ids worker `w` advertised during the handshake (subsets it
    /// loaded from local shard files; empty for unsharded workers).
    pub fn advertised(&self, w: usize) -> &[u32] {
        &self.advertised[w]
    }

    /// The fleet's peer-plane listener addresses, indexed by worker id
    /// (port 0 = that worker bound no listener).
    pub fn peer_addrs(&self) -> &[PeerAddr] {
        &self.peer_addrs
    }

    /// Send one message frame to worker `w`, counting its actual encoded
    /// size under `dir`. Returns the frame length.
    pub fn send_to(&self, w: usize, msg: &Message, dir: Direction) -> Result<u64> {
        let frame = wire::encode(msg)?;
        let mut link = self.links[w].lock().unwrap();
        wire::write_frame(&mut link.stream, &frame)
            .with_context(|| format!("sending to worker {w}"))?;
        self.counters.add(frame.len() as u64, dir);
        Ok(frame.len() as u64)
    }

    /// Receive one message frame from worker `w`, counting its actual size
    /// under the direction implied by its type (results/trees/stats =
    /// gather, acks = control).
    pub fn recv_from(&self, w: usize) -> Result<Message> {
        let frame = {
            let mut link = self.links[w].lock().unwrap();
            wire::read_frame(&mut link.stream)
                .with_context(|| format!("receiving from worker {w}"))?
        };
        let msg = wire::decode(&frame, None)
            .with_context(|| format!("decoding frame from worker {w}"))?;
        let dir = match &msg {
            Message::Result { .. } | Message::WorkerDone { .. } | Message::LocalDone { .. } => {
                Direction::Gather
            }
            Message::Ack { .. } | Message::PairFail { .. } | Message::FoldDone { .. } => {
                Direction::Control
            }
            other => bail!("worker {w} sent an unexpected {other:?}"),
        };
        self.counters.add(frame.len() as u64, dir);
        Ok(msg)
    }

    /// Blocking rendezvous: send `msg`, then read the worker's reply.
    pub fn request(&self, w: usize, msg: &Message, dir: Direction) -> Result<Message> {
        self.send_to(w, msg, dir)?;
        self.recv_from(w)
    }
}

/// Leader side of the per-connection handshake: expect `Hello`, answer with
/// the run `Setup` (stamped with this link's worker id), confirm the ack,
/// then read the worker's `ShardAdvertise` (its locally loaded subset ids —
/// empty for unsharded workers). Handshake frames are counted as control
/// traffic. Returns the advertised shard ids.
fn handshake_leader(
    stream: &TcpStream,
    worker_id: usize,
    setup: &Setup,
    counters: &NetCounters,
) -> Result<(Vec<u32>, u16)> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake timeout")?;
    let mut stream = stream;
    let hello_frame = wire::read_frame(&mut stream).context("reading Hello")?;
    let hello = wire::decode_hello(&hello_frame)?;
    counters.add(hello_frame.len() as u64, Direction::Control);

    let setup = Setup { worker_id: worker_id as u16, ..setup.clone() };
    let setup_frame = wire::encode_setup(&setup)?;
    wire::write_frame(&mut stream, &setup_frame).context("sending Setup")?;
    counters.add(setup_frame.len() as u64, Direction::Control);

    let ack_frame = wire::read_frame(&mut stream).context("reading SetupAck")?;
    let ack = wire::decode_setup_ack(&ack_frame)?;
    if ack.worker_id != worker_id as u16 {
        bail!("worker acked id {} but was assigned {worker_id}", ack.worker_id);
    }
    counters.add(ack_frame.len() as u64, Direction::Control);

    let adv_frame = wire::read_frame(&mut stream).context("reading ShardAdvertise")?;
    let adv = wire::decode_shard_advertise(&adv_frame)?;
    if adv.worker_id != worker_id as u16 {
        bail!("worker advertised as id {} but was assigned {worker_id}", adv.worker_id);
    }
    counters.add(adv_frame.len() as u64, Direction::Control);
    // Job frames can take arbitrarily long to produce answers.
    stream.set_read_timeout(None).context("clearing handshake timeout")?;
    Ok((adv.shard_ids, hello.peer_port))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{Hello, SetupAck, WIRE_VERSION};
    use std::net::TcpStream as ClientStream;

    fn test_setup() -> Setup {
        Setup {
            version: WIRE_VERSION,
            worker_id: 0,
            n: 10,
            d: 2,
            metric: 0,
            kernel: 0,
            pair_kernel: 0,
            reduce_tree: false,
            manifest: 0,
            part_sizes: vec![5, 5],
            artifacts_dir: String::new(),
        }
    }

    /// A minimal in-test worker endpoint: handshake (advertising shard 1),
    /// then echo one frame.
    fn fake_worker(addr: std::net::SocketAddr) -> std::thread::JoinHandle<Message> {
        std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 34567 }),
            )
            .unwrap();
            let setup = wire::decode_setup(&wire::read_frame(&mut s).unwrap()).unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_setup_ack(&SetupAck { worker_id: setup.worker_id }),
            )
            .unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_shard_advertise(&wire::ShardAdvertise {
                    worker_id: setup.worker_id,
                    shard_ids: vec![1],
                })
                .unwrap(),
            )
            .unwrap();
            let frame = wire::read_frame(&mut s).unwrap();
            let msg = wire::decode(&frame, None).unwrap();
            let reply = Message::Ack { job_id: 42 };
            wire::write_frame(&mut s, &wire::encode(&reply).unwrap()).unwrap();
            msg
        })
    }

    #[test]
    fn accept_handshake_and_rendezvous_count_real_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = fake_worker(addr);
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(10))
                .unwrap();
        assert_eq!(fab.len(), 1);
        assert_eq!(fab.advertised(0), &[1], "handshake captured the shard advertisement");
        assert_eq!(fab.peer_addrs().len(), 1);
        assert_eq!(fab.peer_addrs()[0].port, 34567, "Hello's peer port captured");
        assert!(fab.peer_addrs()[0].ip.is_loopback(), "IP observed from the socket");
        let (_, _, c_after_handshake, m) = fab.counters().snapshot();
        assert!(c_after_handshake > 0, "handshake counted as control");
        assert_eq!(m, 4, "hello + setup + ack + shard advertise");

        let msg = Message::Shutdown;
        let reply = fab.request(0, &msg, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 42 });
        assert_eq!(worker.join().unwrap(), Message::Shutdown);
        let (s, g, c, m) = fab.counters().snapshot();
        assert_eq!(s, 0);
        assert_eq!(g, 0, "ack is control, not gather");
        assert_eq!(c, c_after_handshake + 16 + 16, "both 16-byte frames counted");
        assert_eq!(m, 6);
        // charge() must not touch real-transport counters
        fab.charge(1_000_000, Direction::Scatter);
        assert_eq!(fab.counters().snapshot().0, 0);
    }

    /// A stray connection speaking garbage must be rejected without
    /// aborting the accept phase: the real worker behind it still gets in.
    #[test]
    fn stray_connection_does_not_kill_accept_phase() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stray = std::thread::spawn(move || {
            let mut s = ClientStream::connect(addr).unwrap();
            // a complete frame with a bogus tag — decode_hello rejects it
            let mut junk = vec![0u8; 16];
            junk[4] = 200;
            use std::io::Write;
            s.write_all(&junk).unwrap();
            s
        });
        let _stray_stream = stray.join().unwrap();
        let worker = fake_worker(addr);
        let fab =
            TcpTransport::accept_workers(&listener, 1, &test_setup(), Duration::from_secs(20))
                .unwrap();
        assert_eq!(fab.len(), 1, "real worker accepted after the stray was dropped");
        let reply = fab.request(0, &Message::Shutdown, Direction::Control).unwrap();
        assert_eq!(reply, Message::Ack { job_id: 42 });
        worker.join().unwrap();
    }

    #[test]
    fn accept_times_out_with_actionable_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = TcpTransport::accept_workers(
            &listener,
            2,
            &test_setup(),
            Duration::from_millis(80),
        )
        .unwrap_err();
        assert!(err.to_string().contains("0/2 workers"), "{err:#}");
    }
}
