//! Leader-side lifecycle of a multi-process run: bind the listener,
//! optionally spawn the local worker processes, accept + handshake the
//! worker set, run the unmodified exec engine over the [`TcpTransport`],
//! and shut everything down with errors propagated.
//!
//! Two entry points:
//! - [`run_leader`] — the `demst run --transport tcp` path: binds
//!   `cfg.listen`, spawns `demst worker --connect <addr>` children when
//!   `cfg.spawn_workers` is set (otherwise awaits externally started
//!   workers), runs, and reaps the children with exit-status checks.
//! - [`serve`] — the library path over an already-bound listener (used by
//!   tests and benches, whose workers are in-process threads driving
//!   [`super::worker::serve`] over loopback connections).
//!
//! Both wrap the engine run in [`run_elastic`]'s two side-car threads:
//! an **admission** thread that keeps accepting on the listener so a
//! `demst worker --connect` arriving mid-run is handshaken
//! (`Join`/`AdmitAck`) and appended for the engine to activate, and a
//! **pulse** thread that heartbeats every idle link each `liveness/3` so
//! worker-side read deadlines only trip on a genuinely stalled leader.

use super::tcp::TcpTransport;
use super::wire::{self, Setup, WIRE_VERSION};
use super::Direction;
use crate::config::RunConfig;
use crate::coordinator::messages::Message;
use crate::data::Dataset;
use crate::exec::{
    execute_pooled_remote, execute_pooled_sharded, resolve_workers, ExecPlan, PooledRun,
};
use crate::shard::Manifest;
use anyhow::{bail, Context, Result};
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

/// How long the leader waits for the full worker set to connect and
/// handshake before failing the run.
pub const ACCEPT_DEADLINE: Duration = Duration::from_secs(60);

/// Run one distributed EMST over real TCP links: bind, (maybe) spawn,
/// accept, execute, reap. This is what `coordinator::run_distributed`
/// dispatches to for `transport = tcp`.
pub fn run_leader(ds: &Dataset, cfg: &RunConfig) -> Result<PooledRun> {
    // Library callers reach this without the CLI's pre-flight check; the
    // tcp-specific invariants (listen set, explicit workers, parts >= 2,
    // wire v5 limits) must still fail as one-liners, not mid-run.
    cfg.validate()?;
    let listen = cfg
        .listen
        .as_deref()
        .context("transport tcp requires --listen <addr> on the leader")?;
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("binding leader listener on {listen}"))?;
    let addr = listener.local_addr().context("resolving the bound leader address")?;
    let n_workers = resolve_workers(cfg);
    let children = if cfg.spawn_workers {
        let spawned = spawn_worker_processes(&addr.to_string(), n_workers)?;
        println!("leader: listening on {addr}; spawned {n_workers} local `demst worker` processes");
        spawned
    } else {
        println!(
            "leader: listening on {addr}; awaiting {n_workers} x `demst worker --connect {addr}`"
        );
        Vec::new()
    };
    let result = serve(ds, cfg, &listener);
    reap(children, result)
}

/// Run one **sharded** distributed EMST: load the manifest, bind, await
/// the shard-resident workers, execute with zero leader-held vectors.
/// Workers are always external here (`--spawn-workers` is rejected by
/// validation: a spawned local fleet would need per-worker `--shard-ids`,
/// which only the operator can place on the right hosts).
pub fn run_leader_sharded(cfg: &RunConfig) -> Result<PooledRun> {
    cfg.validate()?;
    let manifest_path = cfg
        .shard_manifest
        .as_deref()
        .context("sharded run requires --shard <manifest>")?;
    let manifest = Manifest::load(manifest_path)?;
    let listen = cfg
        .listen
        .as_deref()
        .context("transport tcp requires --listen <addr> on the leader")?;
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("binding leader listener on {listen}"))?;
    let addr = listener.local_addr().context("resolving the bound leader address")?;
    let n_workers = resolve_workers(&RunConfig { parts: manifest.parts(), ..cfg.clone() });
    println!(
        "leader: listening on {addr} (sharded, manifest {:#018x}); awaiting {n_workers} x `demst worker --connect {addr} --shard <manifest> --shard-ids ...`",
        manifest.fingerprint()
    );
    serve_sharded(&manifest, cfg, &listener)
}

/// Accept + handshake `resolve_workers(cfg)` connections on an
/// already-bound listener, then drive the exec engine over them. On engine
/// failure, healthy workers are released with a best-effort `Shutdown` so
/// they exit instead of blocking on a dead socket.
pub fn serve(ds: &Dataset, cfg: &RunConfig, listener: &TcpListener) -> Result<PooledRun> {
    let n_workers = resolve_workers(cfg);
    // Partition exactly once: this plan is announced to every worker in
    // its Setup frame (part_sizes drive PairAssign section decoding) and
    // then handed to the engine, so the wire layout and the executed jobs
    // cannot drift.
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    let setup = make_setup(cfg, ds.n, ds.d, 0, &plan)?;
    let tcp = TcpTransport::accept_workers(listener, n_workers, &setup, ACCEPT_DEADLINE)?;
    let run = run_elastic(&tcp, listener, &setup, || execute_pooled_remote(ds, cfg, &tcp, plan));
    release_on_error(&tcp, run)
}

/// The sharded twin of [`serve`]: the leader holds **no dataset** — the
/// plan (and `n`, `d`, the metric) come from the shard manifest, workers
/// load their subsets from local shard files and advertise them in the
/// handshake, and the engine runs with vectors never passing through this
/// process (`RunMetrics::leader_ingest_bytes == 0`).
pub fn serve_sharded(
    manifest: &Manifest,
    cfg: &RunConfig,
    listener: &TcpListener,
) -> Result<PooledRun> {
    let mut cfg = cfg.clone();
    // The manifest is authoritative for the data shape: the shard files
    // were cut under its metric and layout.
    cfg.metric = manifest.metric;
    cfg.parts = manifest.parts();
    cfg.data.n = manifest.n;
    cfg.data.d = manifest.d;
    cfg.validate()?;
    // The shape-dependent tcp checks deferred by `validate` on sharded
    // configs, now against the shape that will actually execute.
    cfg.validate_tcp_shape()?;
    let n_workers = resolve_workers(&cfg);
    let plan = ExecPlan::from_layout(manifest.layout());
    let setup = make_setup(&cfg, manifest.n, manifest.d, manifest.fingerprint(), &plan)?;
    let tcp = TcpTransport::accept_workers(listener, n_workers, &setup, ACCEPT_DEADLINE)?;
    let run = run_elastic(&tcp, listener, &setup, || {
        execute_pooled_sharded(&cfg, &tcp, plan, manifest.n, manifest.d)
    });
    release_on_error(&tcp, run)
}

/// Drive one engine run with its two liveness side-cars, stopped when the
/// engine returns:
///
/// - **pulse** (only when liveness is enabled): every `liveness / 3`, one
///   heartbeat round over every idle link ([`TcpTransport::pulse`]), so a
///   worker waiting through a leader-quiet phase (another worker's phase-1
///   build, a reduce-mode settle) never trips its read deadline. The
///   interval sleeps *first*: short runs finish without a single heartbeat.
/// - **admission**: keep accepting on `listener` and run the mid-run
///   `Join`/`AdmitAck` handshake on every late connection; the engine's
///   gather loop activates appended links. A link admitted too late to be
///   activated is released with a best-effort `Shutdown`.
fn run_elastic<F>(
    tcp: &TcpTransport,
    listener: &TcpListener,
    setup: &Setup,
    engine: F,
) -> Result<PooledRun>
where
    F: FnOnce() -> Result<PooledRun>,
{
    let stop = AtomicBool::new(false);
    let heartbeats = AtomicU64::new(0);
    let n_start = tcp.len();
    let mut run = std::thread::scope(|s| {
        if let Some(liveness) = tcp.liveness() {
            let interval = (liveness / 3).max(Duration::from_millis(10));
            let stop = &stop;
            let heartbeats = &heartbeats;
            s.spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let mut waited = Duration::ZERO;
                    while waited < interval && !stop.load(Ordering::SeqCst) {
                        let step = Duration::from_millis(10).min(interval - waited);
                        std::thread::sleep(step);
                        waited += step;
                    }
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    heartbeats.fetch_add(tcp.pulse(), Ordering::Relaxed);
                }
            });
        }
        {
            let stop = &stop;
            s.spawn(move || admission_loop(listener, tcp, setup, stop));
        }
        let out = engine();
        // The side-cars poll their flags; scope join is bounded by one
        // poll interval.
        stop.store(true, Ordering::SeqCst);
        out
    })?;
    run.metrics.heartbeats_sent = heartbeats.load(Ordering::Relaxed);
    // Links admitted after the gather loop drained were never driven:
    // release them so the late worker exits cleanly instead of timing out.
    let driven = n_start + run.metrics.workers_admitted as usize;
    for w in driven..tcp.len() {
        let _ = tcp.send_to(w, &Message::Shutdown, Direction::Control);
    }
    Ok(run)
}

/// Accept loop for mid-run admissions, on the (nonblocking since the
/// startup accept phase) listener. Admissions are serialized here, so the
/// worker id [`TcpTransport::admit_worker`] assigns is final. A failed
/// handshake (port scan, manifest mismatch hang-up, version skew) drops
/// the connection and keeps serving.
fn admission_loop(
    listener: &TcpListener,
    tcp: &TcpTransport,
    setup: &Setup,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => match tcp.admit_worker(stream, peer.ip(), setup) {
                Ok(w) => crate::obs::log!(info, "leader: admitted worker {w} mid-run from {peer}"),
                Err(e) => crate::obs::log!(
                    warn,
                    "leader: rejected mid-run connection from {peer}: {e:#}"
                ),
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                crate::obs::log!(warn, "leader: admission accept failed: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

fn make_setup(cfg: &RunConfig, n: usize, d: usize, manifest: u64, plan: &ExecPlan) -> Result<Setup> {
    Ok(Setup {
        version: WIRE_VERSION,
        worker_id: 0, // stamped per accepted link
        n: u32::try_from(n).context("n exceeds the u32 wire limit")?,
        d: u16::try_from(d).context("d exceeds the u16 wire limit")?,
        metric: wire::metric_code(cfg.metric),
        kernel: wire::kernel_code(&cfg.kernel),
        pair_kernel: wire::pair_kernel_code(cfg.pair_kernel),
        reduce_tree: cfg.reduce_tree,
        mid_run: false, // admission re-stamps this per joining link
        trace: cfg.obs.trace,
        metrics: cfg.obs.metrics_armed(),
        manifest,
        liveness_ms: u32::try_from(cfg.net.liveness_timeout_ms)
            .context("liveness timeout exceeds the u32 wire limit (ms)")?,
        metrics_push_ms: u32::try_from(cfg.obs.metrics_push_ms)
            .context("metrics push cadence exceeds the u32 wire limit (ms)")?,
        part_sizes: plan.parts.iter().map(|p| p.len() as u32).collect(),
        artifacts_dir: cfg.artifacts_dir.display().to_string(),
    })
}

fn release_on_error(tcp: &TcpTransport, run: Result<PooledRun>) -> Result<PooledRun> {
    if run.is_err() {
        // The engine aborts without draining every link (e.g. a phase-1
        // failure); release whoever is still serving.
        for w in 0..tcp.len() {
            let _ = tcp.send_to(w, &Message::Shutdown, Direction::Control);
        }
    }
    run
}

/// Spawn `n` local `demst worker --connect <addr>` processes. The worker
/// binary defaults to the current executable; `DEMST_WORKER_EXE` overrides
/// it (tests and non-CLI embedders).
fn spawn_worker_processes(addr: &str, n: usize) -> Result<Vec<Child>> {
    let exe = match std::env::var_os("DEMST_WORKER_EXE") {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe()
            .context("resolving the demst executable for --spawn-workers")?,
    };
    (0..n)
        .map(|w| {
            Command::new(&exe)
                .args(["worker", "--connect", addr])
                .stdin(Stdio::null())
                .stdout(Stdio::null()) // keep the leader's stdout clean
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawning worker process {w} ({})", exe.display()))
        })
        .collect()
}

/// Await the spawned worker set. A clean engine run hands every worker a
/// `Shutdown`, so nonzero exits are real failures and surface even when the
/// leader's own result was fine; after an engine error the children are
/// killed rather than awaited (they may be blocked on a dead link).
fn reap(children: Vec<Child>, result: Result<PooledRun>) -> Result<PooledRun> {
    let engine_failed = result.is_err();
    let mut failures = Vec::new();
    for (w, mut child) in children.into_iter().enumerate() {
        if engine_failed {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() || engine_failed => {}
            Ok(status) => failures.push(format!("worker process {w} exited with {status}")),
            Err(e) => failures.push(format!("worker process {w} could not be reaped: {e}")),
        }
    }
    let run = result?;
    if !failures.is_empty() {
        bail!("run completed but {}", failures.join("; "));
    }
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{KernelChoice, PairKernelChoice, TransportChoice};
    use crate::data::generators::uniform;
    use crate::mst::normalize_tree;
    use crate::net::worker;
    use crate::util::prng::Pcg64;

    /// End-to-end over loopback with in-thread workers: `serve` must return
    /// the identical tree as the simulated transport.
    #[test]
    fn serve_matches_sim_transport() {
        let ds = uniform(72, 5, 1.0, Pcg64::seeded(700));
        let mut cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            ..Default::default()
        };
        let sim = crate::coordinator::run_distributed(&ds, &cfg).unwrap();

        cfg.transport = TransportChoice::Tcp;
        cfg.listen = Some("127.0.0.1:0".into());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let workers: Vec<_> = (0..2)
            .map(|_| {
                std::thread::spawn(move || {
                    worker::run(&addr.to_string(), Duration::from_secs(10))
                })
            })
            .collect();
        let tcp = serve(&ds, &cfg, &listener).unwrap();
        for h in workers {
            h.join().unwrap().unwrap();
        }
        assert_eq!(normalize_tree(&sim.mst), normalize_tree(&tcp.mst));
        assert_eq!(tcp.metrics.transport, "tcp");
    }
}
