//! Simulated network: byte/message accounting plus an optional latency +
//! bandwidth delay model. This is the `sim` implementation of
//! [`Transport`]; the byte model and counters are unchanged from its
//! pre-`net/` life as `coordinator::netsim`.
//!
//! Every leader↔worker send goes through [`Transport::send`], which (a) adds
//! the message's wire size to the right direction counter and (b) if
//! `simulate_delays` is set, sleeps `latency + bytes/bandwidth` *in the
//! sending thread* before delivery — modelling a blocking rendezvous send on
//! a full-duplex link, good enough to surface the `O(|V||P|)` vs `O(|V|)`
//! gather asymmetry as wallclock, not just counters.

use super::{Direction, NetCounters, Transport};
use crate::config::NetConfig;
use std::sync::Arc;
use std::time::Duration;

/// The simulated network fabric (shared by all endpoints).
#[derive(Clone)]
pub struct NetSim {
    cfg: NetConfig,
    counters: Arc<NetCounters>,
}

impl NetSim {
    pub fn new(cfg: NetConfig) -> Self {
        Self { cfg, counters: Arc::new(NetCounters::default()) }
    }

    /// Transfer delay for `bytes` under the configured link model.
    pub fn model_delay(&self, bytes: u64) -> Duration {
        Duration::from_micros(self.cfg.latency_us)
            + Duration::from_secs_f64(bytes as f64 / self.cfg.bandwidth)
    }
}

impl Transport for NetSim {
    fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// Account for (and, with `simulate_delays`, sleep for) a message of
    /// `bytes` that is *modeled* but not physically delivered — used by the
    /// pull-based exec scheduler, where workers claim jobs from a shared
    /// queue instead of receiving them over a channel, yet the scatter of
    /// the job payload must still be charged to the link.
    fn charge(&self, bytes: u64, dir: Direction) {
        self.counters.add(bytes, dir);
        if self.cfg.simulate_delays {
            std::thread::sleep(self.model_delay(bytes));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::Message;
    use crate::data::Dataset;
    use crate::decomp::PairJob;
    use std::sync::mpsc::channel;

    fn job_msg(n: usize, d: usize) -> Message {
        Message::Job {
            job: PairJob { id: 0, i: 0, j: 1 },
            global_ids: (0..n as u32).collect(),
            points: Dataset::zeros(n, d),
        }
    }

    #[test]
    fn counters_accumulate_by_direction() {
        let net = NetSim::new(NetConfig::default());
        let (tx, rx) = channel();
        net.send(&tx, job_msg(10, 4), Direction::Scatter).unwrap();
        net.send(&tx, Message::Shutdown, Direction::Control).unwrap();
        let (s, g, c, m) = net.counters().snapshot();
        assert_eq!(s, 16 + 40 + 160);
        assert_eq!(g, 0);
        assert_eq!(c, 16);
        assert_eq!(m, 2);
        drop(rx);
    }

    #[test]
    fn delay_model_scales_with_bytes() {
        let cfg = NetConfig { simulate_delays: false, latency_us: 100, bandwidth: 1e6 };
        let net = NetSim::new(cfg);
        let d1 = net.model_delay(0);
        let d2 = net.model_delay(1_000_000);
        assert_eq!(d1, Duration::from_micros(100));
        assert_eq!(d2, Duration::from_micros(100) + Duration::from_secs(1));
    }

    #[test]
    fn send_to_dropped_receiver_errors() {
        let net = NetSim::new(NetConfig::default());
        let (tx, rx) = channel();
        drop(rx);
        assert!(net.send(&tx, Message::Shutdown, Direction::Control).is_err());
    }

    #[test]
    fn simulated_delay_actually_sleeps() {
        let cfg = NetConfig { simulate_delays: true, latency_us: 2000, bandwidth: 1e12 };
        let net = NetSim::new(cfg);
        let (tx, _rx) = channel();
        let t = std::time::Instant::now();
        net.send(&tx, Message::Shutdown, Direction::Control).unwrap();
        assert!(t.elapsed() >= Duration::from_micros(1500));
    }
}
