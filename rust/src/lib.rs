//! # demst — Distributed Euclidean-MST / Single-Linkage Dendrograms via Distance Decomposition
//!
//! A production-oriented reproduction of
//! *"A Surprisingly Simple Method for Distributed Euclidean-Minimum Spanning Tree /
//! Single Linkage Dendrogram Construction from High Dimensional Embeddings via
//! Distance Decomposition"* (R. Lettich, LBNL, 2024).
//!
//! The library implements the paper's Algorithm 1: partition the vertex set
//! (vectors) into `|P|` subsets, compute a *dense* MST (`d-MST`) over each of the
//! `|P|(|P|-1)/2` pairwise unions in parallel, gather the edge union, and take a
//! sparse MST of the union to recover the **exact** global Euclidean MST
//! (Theorem 1). The MST converts to/from a single-linkage dendrogram in
//! `O(n α(n))` / `O(n)`.
//!
//! ## Architecture
//!
//! The crate builds as a Cargo workspace rooted at the repository top level
//! (`cargo build --release` just works, offline). Three layers; Python is
//! never on the request path:
//!
//! - **L3 (this crate)** — the [`exec`] pair-job engine plus its two thin
//!   front-ends: `decomp::decomposed_mst` (serial reference) and
//!   `coordinator::run_distributed` (worker ranks over a byte-accounted
//!   [`net::Transport`]). The engine owns
//!   partition → schedule → solve → reduce once: an [`exec::ExecPlan`]
//!   with `|S_i|·|S_j|` job costs, **subset-affinity scheduling** (each
//!   subset anchored to a worker by LPT over its total pair-job cost, jobs
//!   routed to their larger subset's anchor deck, idle stealing as
//!   fallback) with a **resident-set byte model** (NetSim charged only for
//!   payload the executing worker is missing; the dense model stays
//!   byte-for-byte behind `affinity = false`), two selectable pair
//!   kernels — the **dense** oracle (full d-MST per gathered union) and
//!   the **bipartite-merge** kernel (each partition's local MST cached
//!   once, pair jobs solved by filtered Prim over
//!   `MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i × S_j)` with the bipartite block
//!   computed as an `S_i × S_j` panel product from a per-worker
//!   [`exec::PanelCache`], exactly `n(n-1)/2` distance evaluations per
//!   run) — and gather-side reduction, optionally streaming (`⊕`-folding
//!   each arriving tree into a bounded running MSF by an O(|V|)-per-fold
//!   presorted merge-join). Plus partitioners, dendrogram construction,
//!   CLI/config/metrics.
//! - **network layer ([`net`])** — one charge/send [`net::Transport`]
//!   interface, two implementations: [`net::NetSim`] (in-process simulated
//!   fabric: threads share memory, bytes are modeled) and
//!   [`net::TcpTransport`] (real multi-process: one blocking TCP socket per
//!   leader↔worker link, length-prefixed [`net::wire`] frames with a
//!   versioned handshake, counters fed by actual encoded frame sizes).
//!   `Message::wire_bytes` is computed from the real wire encoding, so the
//!   simulated charges and the measured frames are the same number by
//!   construction. `run --transport tcp` drives the unmodified exec engine
//!   through windowed, elastic [`net::remote::RemoteLink`] drivers (up to
//!   `pipeline_window` jobs in flight per link; a link that dies mid-run
//!   hands its undelivered jobs back to the deck and the surviving fleet
//!   finishes the bit-identical tree) against `demst worker --connect`
//!   processes ([`net::worker`]), bound/spawned/awaited by [`net::launch`].
//!   A **liveness layer** keeps the fleet honest: the leader pulses
//!   header-only `Heartbeat` frames over idle links and enforces a
//!   per-link read deadline (`net.liveness_timeout_ms`), so a stalled
//!   worker is demoted through the same exactly-once return lane as a
//!   dead one; the listener stays open for the whole run and a late
//!   `demst worker --connect` is **admitted mid-run** via a versioned
//!   `Join`/`AdmitAck` handshake, given its own deck, and rebalanced onto
//!   (pure scheduling — the tree stays bit-identical). Every failure
//!   path is reproducibly injectable through the deterministic
//!   [`net::chaos`] transport wrapper (`DEMST_CHAOS_PLAN` /
//!   `DEMST_CHAOS_SEED`: delay, drop, truncate, garbage, stall, or exit
//!   on frame N).
//!   On top rides the **leaderless data plane**: every worker binds a
//!   worker↔worker listener (port advertised in the handshake, fleet
//!   addresses broadcast as a `PeerBook`), cached local MSTs travel
//!   builder→executor directly (`peer_route`: the leader sends a
//!   header-only routing flag, `PeerHello`/`TreeFetch`/`TreeShip` move
//!   the payload; `RunMetrics::{leader_control_bytes, leader_data_bytes,
//!   peer_bytes}` split the witness), and `reduce_topology ∈ {leader,
//!   tree, ring}` selects where partial MSFs ⊕-fold — at the leader, or
//!   among the workers along a deterministic binomial-tree or ring
//!   schedule so only the final ≤|V|−1-edge forest reaches the leader.
//!   A peer that dies mid-fold degrades to leader-assisted recovery:
//!   its folded-but-unshipped jobs return to the exactly-once lane.
//! - **observability ([`obs`])** — the flight recorder: per-thread span
//!   buffers (`job`/`local_mst`/`panel`/`fold`/`peer_fetch`/`handshake`
//!   intervals, `stall`/`admit`/`chaos`/`failover` instants) behind a
//!   run-token enable that costs one atomic load when off; workers ship
//!   their spans back piggybacked on `WorkerDone` (wire v6) and the
//!   leader re-bases them onto its clock, so `--trace-out` exports one
//!   fleet-wide Chrome-trace/Perfetto timeline and `--report-out` a
//!   versioned JSON run report (full `RunMetrics` + per-worker breakdown
//!   + config fingerprint). A `DEMST_LOG`-leveled `obs::log!` macro
//!   carries the diagnostics and a tty-gated live progress ticker shows
//!   jobs/bytes/stalls/admissions mid-run. Alongside the spans rides the
//!   **fleet metrics plane** ([`obs::metrics`]): relaxed-atomic counters,
//!   gauges, and log-linear-bucket histograms with an associative
//!   bucket-wise merge, recorded at the same instrumentation points;
//!   workers ship compact binary snapshots piggybacked on `WorkerDone`
//!   and periodic `MetricsPush` frames (wire v7), the leader's
//!   `MetricsHub` merges them fleet-wide, [`obs::expose`] serves the
//!   merged registry as live Prometheus text exposition
//!   (`--metrics-listen`, scrapeable mid-run), the run report gains a
//!   `histograms` section, and `demst report diff` turns two reports
//!   into a thresholded cross-run regression gate.
//! - **sharded residency ([`shard`])** — `demst partition` cuts a dataset
//!   into per-subset binary shard files (checksummed, FNV-1a 64) plus a
//!   TOML-lite manifest (run shape, partition layout as compact id
//!   ranges, per-shard digests, 64-bit fingerprint). `demst worker
//!   --shard` loads its subsets from local disk and advertises them in
//!   the versioned handshake; `demst run --shard` plans from the manifest alone
//!   and schedules each pair job onto a worker holding **both** subsets
//!   ([`exec::ExecPlan::affinity_for_holders`]) — so subset vectors never
//!   pass through the leader (`RunMetrics::leader_ingest_bytes == 0` on a
//!   sharded run; phase 1 is a header-only `LocalAssign`, pair scatter
//!   ships at most cached local trees). [`shard::suggest_assignment`]
//!   produces a pair-covering shard placement for a given fleet size.
//! - **compute backends ([`runtime`])** — kernels are selected through the
//!   [`runtime::ComputeBackend`] abstraction:
//!   - the default, always-available **Rust backend**: metric-generic
//!     blocked distance kernels ([`geometry::DistanceBlock`]) in the same
//!     Gram/dot form the Pallas kernel uses — squared Euclidean and cosine
//!     via precomputed norms, Manhattan via a tiled direct loop — feeding
//!     the blocked dense Prim and the Borůvka cheapest-edge step. The
//!     bipartite panel form dispatches at runtime to the register-tiled
//!     SIMD micro-kernels in [`geometry::simd`] (AVX2+FMA-class x86, NEON
//!     aarch64, canonical scalar fallback; `DEMST_SIMD=off` or
//!     `panel_simd = false` forces scalar), optionally banded across
//!     threads (`panel_threads`) — every path bit-identical to the scalar
//!     reference by a shared fixed-order 8-lane accumulation, so SIMD
//!     on/off never changes a tree;
//!   - the **PJRT/XLA backend** (`--features backend-xla`): loads the HLO
//!     artifacts through the PJRT CPU client (`xla` crate) and executes
//!     them from the Rust hot path. Off by default so the standard build is
//!     pure-Rust and offline-capable; a config requesting `boruvka-xla` in
//!     a default build falls back to the Rust backend and reports it in
//!     `RunMetrics::kernel_fallback`.
//! - **L2/L1 (python/, build time)** — JAX model + Pallas kernels for the
//!   `O(N²D)` cheapest-edge step of dense Borůvka, AOT-lowered to HLO text in
//!   `artifacts/` by `make artifacts`. Optional: the tests skip when
//!   jax/Pallas is unavailable, mirroring the `backend-xla` gate in Rust.
//!
//! ## Quickstart
//!
//! ```no_run
//! use demst::prelude::*;
//!
//! let ds = demst::data::generators::gaussian_blobs(
//!     &demst::data::generators::BlobSpec { n: 512, d: 32, k: 8, std: 0.4, spread: 8.0 },
//!     demst::util::prng::Pcg64::seeded(42),
//! );
//! let cfg = DecompConfig { parts: 4, ..Default::default() };
//! let out = demst::decomp::decomposed_mst(&ds, &cfg, &demst::dense::PrimDense::sq_euclid());
//! let dendro = demst::slink::mst_to_dendrogram(ds.n, &out.mst);
//! let labels = dendro.cut_to_k(8);
//! assert_eq!(labels.len(), ds.n);
//! ```

pub mod util;
pub mod config;
pub mod cli;
pub mod data;
pub mod geometry;
pub mod graph;
pub mod mst;
pub mod dense;
pub mod slink;
pub mod exec;
pub mod decomp;
pub mod net;
pub mod obs;
pub mod shard;
pub mod coordinator;
pub mod runtime;
pub mod baselines;
pub mod report;
pub mod bench_util;

/// Common imports for downstream users.
pub mod prelude {
    pub use crate::data::Dataset;
    pub use crate::decomp::{decomposed_mst, DecompConfig, PartitionStrategy};
    pub use crate::dense::{DenseMst, PrimDense};
    pub use crate::geometry::Metric;
    pub use crate::graph::{Edge, UnionFind};
    pub use crate::mst::{kruskal, total_weight};
    pub use crate::slink::{mst_to_dendrogram, Dendrogram};
}
