//! kNN-graph MST baseline (approximate EMST).
//!
//! Builds the exact k-nearest-neighbor graph by brute force (`O(n²d)` once),
//! then runs a sparse MST on it. If the kNN graph is connected and contains
//! all EMST edges, the result is exact; otherwise it is a forest and/or
//! heavier than the true EMST. Experiment E6 sweeps `k` and dimension to map
//! where that happens.

use crate::data::Dataset;
use crate::geometry::blocked::distance_block;
use crate::geometry::MetricKind;
use crate::graph::Edge;
use crate::mst::kruskal;

/// Result of the kNN-MST baseline with accuracy diagnostics.
#[derive(Clone, Debug)]
pub struct KnnResult {
    /// MSF of the kNN graph
    pub forest: Vec<Edge>,
    /// connected components of the kNN graph (1 = possibly exact)
    pub components: usize,
    /// distance evaluations used (n*n for brute-force kNN)
    pub dist_evals: u64,
    /// k used
    pub k: usize,
}

/// Exact (brute-force) kNN edge list: for each point its k nearest others,
/// deduplicated as undirected edges. Squared Euclidean weights.
pub fn knn_graph(ds: &Dataset, k: usize) -> Vec<Edge> {
    knn_graph_metric(ds, k, MetricKind::SqEuclid)
}

/// Metric-generic exact kNN edge list via the blocked
/// [`DistanceBlock`](crate::geometry::DistanceBlock) kernels: for each point
/// its k nearest others under `metric`, deduplicated as undirected edges.
pub fn knn_graph_metric(ds: &Dataset, k: usize, metric: MetricKind) -> Vec<Edge> {
    assert!(k >= 1 && k < ds.n, "k={k} out of range for n={}", ds.n);
    let n = ds.n;
    let d = ds.d;
    let blk = distance_block(metric);
    let sqrt_at_emit = blk.compare_form_is_squared();
    let aux = blk.prepare(ds.as_slice(), n, d);
    let all: Vec<u32> = (0..n as u32).collect();
    let block = 128usize;
    let mut edges: Vec<Edge> = Vec::with_capacity(n * k);
    let mut tile = vec![0.0f32; block * n];
    // per row: partial-select the k smallest (excluding self)
    let mut cand: Vec<(f32, u32)> = Vec::with_capacity(n);
    for i0 in (0..n).step_by(block) {
        let im = (i0 + block).min(n) - i0;
        blk.block(ds.as_slice(), d, &aux, &all[i0..i0 + im], &all, &mut tile[..im * n]);
        for ii in 0..im {
            let i = i0 + ii;
            cand.clear();
            for (j, &w) in tile[ii * n..(ii + 1) * n].iter().enumerate() {
                if j != i {
                    cand.push((w, j as u32));
                }
            }
            // partial selection of k smallest by (w, j)
            cand.select_nth_unstable_by(k - 1, |a, b| {
                a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
            });
            for &(w, j) in &cand[..k] {
                let w = if sqrt_at_emit { w.sqrt() } else { w };
                edges.push(Edge::new(i as u32, j, w));
            }
        }
    }
    crate::graph::edge::dedup_edges(&edges)
}

/// kNN-graph MST baseline (squared Euclidean).
pub fn knn_boruvka(ds: &Dataset, k: usize) -> KnnResult {
    knn_boruvka_metric(ds, k, MetricKind::SqEuclid)
}

/// Metric-generic kNN-graph MST baseline.
pub fn knn_boruvka_metric(ds: &Dataset, k: usize, metric: MetricKind) -> KnnResult {
    let graph = knn_graph_metric(ds, k, metric);
    let forest = kruskal(ds.n, &graph);
    let components = ds.n - forest.len();
    KnnResult { forest, components, dist_evals: (ds.n * ds.n) as u64, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs, uniform, BlobSpec};
    use crate::dense::{DenseMst, PrimDense};
    use crate::mst::{normalize_tree, total_weight};
    use crate::util::prng::Pcg64;

    #[test]
    fn knn_graph_degrees() {
        let ds = uniform(40, 3, 1.0, Pcg64::seeded(500));
        let k = 5;
        let g = knn_graph(&ds, k);
        // undirected dedup: between nk/2 and nk edges
        assert!(g.len() >= ds.n * k / 2 && g.len() <= ds.n * k);
        // every vertex has degree >= k (its own k neighbors at least)
        let mut deg = vec![0usize; ds.n];
        for e in &g {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        assert!(deg.iter().all(|&x| x >= k));
    }

    #[test]
    fn large_k_recovers_exact_mst() {
        // Integer coordinates: the kNN path computes matmul-form distances;
        // integer coords make them bit-exact vs PrimDense's direct form.
        let mut rng = Pcg64::seeded(501);
        let data: Vec<f32> = (0..30 * 4).map(|_| rng.next_bounded(32) as f32 - 16.0).collect();
        let ds = crate::data::Dataset::new(30, 4, data);
        let exact = PrimDense::sq_euclid().mst(&ds);
        let r = knn_boruvka(&ds, 29); // complete graph
        assert_eq!(r.components, 1);
        assert_eq!(normalize_tree(&exact), normalize_tree(&r.forest));
    }

    #[test]
    fn small_k_on_separated_blobs_disconnects() {
        // Tight, far-apart blobs: with k smaller than blob size, no
        // cross-blob edge exists in the kNN graph => forest.
        let spec = BlobSpec { n: 60, d: 8, k: 3, std: 0.05, spread: 50.0 };
        let ds = gaussian_blobs(&spec, Pcg64::seeded(502));
        let r = knn_boruvka(&ds, 3);
        assert!(r.components > 1, "expected disconnection, got {} components", r.components);
        assert!(r.forest.len() < ds.n - 1);
    }

    #[test]
    fn knn_weight_never_below_exact() {
        // On its connected subgraph the kNN-MST weight >= exact MST weight
        // restricted appropriately; for connected cases compare directly.
        let ds = uniform(50, 6, 1.0, Pcg64::seeded(503));
        let exact_w = total_weight(&PrimDense::sq_euclid().mst(&ds));
        let r = knn_boruvka(&ds, 12);
        if r.components == 1 {
            let w = total_weight(&r.forest);
            assert!(w >= exact_w - 1e-5, "knn={w} exact={exact_w}");
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn k_bounds_checked() {
        let ds = uniform(10, 2, 1.0, Pcg64::seeded(504));
        knn_graph(&ds, 10);
    }

    #[test]
    fn metric_generic_knn_recovers_metric_mst_at_full_k() {
        // Integer coordinates: blocked and scalar paths are float-exact, so
        // kNN with k = n-1 (the complete graph) must reproduce the exact MST
        // under every metric.
        let mut rng = Pcg64::seeded(505);
        let (n, d) = (26, 5);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(13) as f32 - 6.0).collect();
        let ds = crate::data::Dataset::new(n, d, data);
        for kind in [
            crate::geometry::MetricKind::Cosine,
            crate::geometry::MetricKind::Manhattan,
        ] {
            let exact = crate::dense::PrimScalar::new(kind).mst(&ds);
            let r = knn_boruvka_metric(&ds, n - 1, kind);
            assert_eq!(r.components, 1, "{kind:?}");
            assert_eq!(
                normalize_tree(&exact),
                normalize_tree(&r.forest),
                "{kind:?}"
            );
        }
    }
}
