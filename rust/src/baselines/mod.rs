//! Baselines the paper's evaluation positions against:
//!
//! - [`single_node`] — the undecomposed monolithic d-MST: the work/bandwidth
//!   reference point of the paper's cost analysis.
//! - [`knn_boruvka`] — a kNN-graph + sparse-MST method in the spirit of
//!   Arefin et al.'s kNN-Borůvka (the GPU comparator the paper cites):
//!   asymptotically less distance work but **approximate** — it can return a
//!   disconnected forest or a heavier tree when `k` is too small for the
//!   data's structure, which is exactly the failure mode that motivates the
//!   paper's exact method for high-dimensional embeddings (E6).

pub mod knn;

pub use knn::{knn_boruvka, knn_graph, KnnResult};

use crate::data::Dataset;
use crate::dense::DenseMst;
use crate::graph::Edge;

/// Monolithic single-node d-MST over the whole dataset.
pub fn single_node(ds: &Dataset, kernel: &dyn DenseMst) -> Vec<Edge> {
    kernel.mst(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::dense::PrimDense;
    use crate::util::prng::Pcg64;

    #[test]
    fn single_node_is_kernel_passthrough() {
        let ds = uniform(30, 4, 1.0, Pcg64::seeded(1));
        let k = PrimDense::sq_euclid();
        let a = single_node(&ds, &k);
        let b = k.mst(&ds);
        assert_eq!(a, b);
    }
}
