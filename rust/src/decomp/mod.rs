//! The paper's contribution: distance-decomposed distributed EMST
//! (Algorithm 1) and its analysis counters.
//!
//! ```text
//! P = {S_i}          <- partition of vectors (vertices) V
//! TreeEdges <- ∅
//! for j in 2..=|P|, i in 1..j-1:
//!     TreeEdges <- TreeEdges ∪ d-MST(S_i ∪ S_j)
//! TreeEdges <- MST(TreeEdges)
//! ```
//!
//! Correctness (Theorem 1): the union of pairwise-subset MSTs is a superset
//! of the global MST because, per Lemma 1, `MSF(G)[S] ⊆ MSF(G[S])` — every
//! global tree edge with both endpoints in `S_i ∪ S_j` survives in that
//! subproblem's MST. Every global edge has its endpoints in *some* pair.
//!
//! This module contains the serial reference front-end plus the
//! partitioners, pair schedule, and ⊕-reduction primitives; the actual
//! partition → schedule → solve → reduce loop is the shared [`crate::exec`]
//! engine, and the multi-threaded distributed execution with communication
//! accounting is its other front-end, [`crate::coordinator`].

pub mod partition;
pub mod pairs;
pub mod algorithm;
pub mod reduction;

pub use algorithm::{decomposed_mst, DecompConfig, DecompOutput};
pub use pairs::{pair_count, PairJob, PairSchedule};
pub use partition::{partition_indices, PartitionStrategy};
