//! Partitioners: split the vertex (vector) set into `|P|` subsets.
//!
//! Theorem 1 holds for *any* partition; the choice only affects load balance
//! and constant factors. Strategies:
//! - `Block` — contiguous ranges (what a pre-sharded embedding table gives).
//! - `RoundRobin` — strided; balanced for ordered inputs.
//! - `RandomShuffle` — balanced in expectation regardless of input order.
//! - `KMeansLite` — a few Lloyd iterations then size-balanced assignment;
//!   locality-aware variant for the ablation bench (intra-subset edges get
//!   shorter, changing *which* pair finds each MST edge, never the result).

use crate::data::Dataset;
use crate::util::prng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionStrategy {
    Block,
    RoundRobin,
    RandomShuffle,
    KMeansLite,
}

impl PartitionStrategy {
    pub fn name(self) -> &'static str {
        match self {
            PartitionStrategy::Block => "block",
            PartitionStrategy::RoundRobin => "round-robin",
            PartitionStrategy::RandomShuffle => "random",
            PartitionStrategy::KMeansLite => "kmeans-lite",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "block" => Some(Self::Block),
            "round-robin" | "roundrobin" | "rr" => Some(Self::RoundRobin),
            "random" | "shuffle" => Some(Self::RandomShuffle),
            "kmeans-lite" | "kmeans" => Some(Self::KMeansLite),
            _ => None,
        }
    }

    pub const ALL: [PartitionStrategy; 4] = [
        PartitionStrategy::Block,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::RandomShuffle,
        PartitionStrategy::KMeansLite,
    ];
}

/// Split `0..ds.n` into `parts` non-empty subsets. Panics if `parts == 0` or
/// `parts > n`. Every index appears exactly once (a partition of V).
pub fn partition_indices(
    ds: &Dataset,
    parts: usize,
    strategy: PartitionStrategy,
    seed: u64,
) -> Vec<Vec<u32>> {
    assert!(parts >= 1, "need at least one part");
    assert!(parts <= ds.n, "more parts ({parts}) than points ({})", ds.n);
    match strategy {
        PartitionStrategy::Block => block(ds.n, parts),
        PartitionStrategy::RoundRobin => round_robin(ds.n, parts),
        PartitionStrategy::RandomShuffle => random_shuffle(ds.n, parts, seed),
        PartitionStrategy::KMeansLite => kmeans_lite(ds, parts, seed),
    }
}

fn block(n: usize, parts: usize) -> Vec<Vec<u32>> {
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut at = 0u32;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push((at..at + len as u32).collect());
        at += len as u32;
    }
    out
}

fn round_robin(n: usize, parts: usize) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::with_capacity(n / parts + 1); parts];
    for i in 0..n as u32 {
        out[i as usize % parts].push(i);
    }
    out
}

fn random_shuffle(n: usize, parts: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut idx: Vec<u32> = (0..n as u32).collect();
    Pcg64::seeded(seed).shuffle(&mut idx);
    let mut out = vec![Vec::with_capacity(n / parts + 1); parts];
    for (pos, &i) in idx.iter().enumerate() {
        out[pos % parts].push(i);
    }
    for part in &mut out {
        part.sort_unstable(); // canonical order within a part
    }
    out
}

/// A few Lloyd iterations, then greedy size-balanced assignment: points are
/// assigned to their nearest centroid among parts that still have room
/// (capacity ⌈n/parts⌉), processed in random order.
fn kmeans_lite(ds: &Dataset, parts: usize, seed: u64) -> Vec<Vec<u32>> {
    const ITERS: usize = 4;
    let n = ds.n;
    let d = ds.d;
    let mut rng = Pcg64::seeded(seed ^ KMEANS_SEED_SALT);
    // init: random distinct points
    let init = rng.sample_indices(n, parts);
    let mut centroids: Vec<f32> = Vec::with_capacity(parts * d);
    for &i in &init {
        centroids.extend_from_slice(ds.row(i));
    }
    let mut assign = vec![0u32; n];
    for _ in 0..ITERS {
        // assign
        for i in 0..n {
            let row = ds.row(i);
            let mut best = 0usize;
            let mut bd = f32::INFINITY;
            for c in 0..parts {
                let dist = crate::geometry::metric::sq_euclid(row, &centroids[c * d..(c + 1) * d]);
                if dist < bd {
                    bd = dist;
                    best = c;
                }
            }
            assign[i] = best as u32;
        }
        // update
        let mut sums = vec![0.0f64; parts * d];
        let mut counts = vec![0usize; parts];
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            for (j, &x) in ds.row(i).iter().enumerate() {
                sums[c * d + j] += x as f64;
            }
        }
        for c in 0..parts {
            if counts[c] > 0 {
                for j in 0..d {
                    centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    // balanced assignment: capacity ceil(n/parts), random processing order
    let cap = crate::util::div_ceil(n, parts);
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut out = vec![Vec::with_capacity(cap); parts];
    for &i in &order {
        let row = ds.row(i as usize);
        // nearest centroid with room
        let mut best = usize::MAX;
        let mut bd = f32::INFINITY;
        for c in 0..parts {
            if out[c].len() >= cap {
                continue;
            }
            let dist = crate::geometry::metric::sq_euclid(row, &centroids[c * d..(c + 1) * d]);
            if dist < bd {
                bd = dist;
                best = c;
            }
        }
        debug_assert_ne!(best, usize::MAX);
        out[best].push(i);
    }
    // Guard against empty parts (possible when n == parts and capacities
    // force it; greedy with cap=1 always fills, but keep the invariant).
    rebalance_empty(&mut out);
    for part in &mut out {
        part.sort_unstable();
    }
    out
}

/// Seed salt so k-means init differs from the shuffle stream ("kmeans").
const KMEANS_SEED_SALT: u64 = 0x6B6D_6561_6E73;

/// Move elements from the largest parts into any empty parts.
fn rebalance_empty(parts: &mut [Vec<u32>]) {
    loop {
        let Some(empty) = parts.iter().position(|p| p.is_empty()) else { return };
        let (donor, _) = parts
            .iter()
            .enumerate()
            .max_by_key(|(_, p)| p.len())
            .expect("non-empty slice");
        if parts[donor].len() <= 1 {
            return; // cannot rebalance further
        }
        let moved = parts[donor].pop().unwrap();
        parts[empty].push(moved);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs, BlobSpec};
    use crate::data::Dataset;

    fn check_is_partition(n: usize, parts: &[Vec<u32>]) {
        let mut seen = vec![false; n];
        for p in parts {
            assert!(!p.is_empty(), "empty part");
            for &i in p {
                assert!(!seen[i as usize], "duplicate index {i}");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "missing indices");
    }

    fn toy(n: usize, d: usize) -> Dataset {
        Dataset::new(n, d, (0..n * d).map(|i| (i % 13) as f32).collect())
    }

    #[test]
    fn all_strategies_produce_partitions() {
        let ds = gaussian_blobs(
            &BlobSpec { n: 101, d: 6, k: 5, std: 0.5, spread: 5.0 },
            crate::util::prng::Pcg64::seeded(1),
        );
        for strat in PartitionStrategy::ALL {
            for parts in [1, 2, 3, 7, 16] {
                let p = partition_indices(&ds, parts, strat, 42);
                assert_eq!(p.len(), parts, "{strat:?}");
                check_is_partition(ds.n, &p);
            }
        }
    }

    #[test]
    fn block_is_contiguous_and_balanced() {
        let ds = toy(10, 2);
        let p = partition_indices(&ds, 3, PartitionStrategy::Block, 0);
        assert_eq!(p[0], vec![0, 1, 2, 3]);
        assert_eq!(p[1], vec![4, 5, 6]);
        assert_eq!(p[2], vec![7, 8, 9]);
    }

    #[test]
    fn round_robin_strides() {
        let ds = toy(7, 2);
        let p = partition_indices(&ds, 3, PartitionStrategy::RoundRobin, 0);
        assert_eq!(p[0], vec![0, 3, 6]);
        assert_eq!(p[1], vec![1, 4]);
        assert_eq!(p[2], vec![2, 5]);
    }

    #[test]
    fn random_is_balanced_and_seed_deterministic() {
        let ds = toy(100, 2);
        let a = partition_indices(&ds, 8, PartitionStrategy::RandomShuffle, 7);
        let b = partition_indices(&ds, 8, PartitionStrategy::RandomShuffle, 7);
        let c = partition_indices(&ds, 8, PartitionStrategy::RandomShuffle, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        for p in &a {
            assert!(p.len() == 12 || p.len() == 13);
        }
    }

    #[test]
    fn kmeans_lite_balanced_within_one() {
        let ds = gaussian_blobs(
            &BlobSpec { n: 96, d: 4, k: 4, std: 0.3, spread: 6.0 },
            crate::util::prng::Pcg64::seeded(5),
        );
        let p = partition_indices(&ds, 4, PartitionStrategy::KMeansLite, 11);
        check_is_partition(ds.n, &p);
        for part in &p {
            assert!(part.len() <= 24, "capacity ceil(96/4)=24, got {}", part.len());
        }
    }

    #[test]
    fn parts_equal_n_gives_singletons() {
        let ds = toy(5, 2);
        for strat in PartitionStrategy::ALL {
            let p = partition_indices(&ds, 5, strat, 3);
            check_is_partition(5, &p);
            assert!(p.iter().all(|s| s.len() == 1), "{strat:?}");
        }
    }

    #[test]
    #[should_panic(expected = "more parts")]
    fn too_many_parts_panics() {
        let ds = toy(3, 2);
        partition_indices(&ds, 4, PartitionStrategy::Block, 0);
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(PartitionStrategy::parse("nope"), None);
    }
}
