//! Serial reference front-end of Algorithm 1: a thin wrapper over the
//! shared [`crate::exec`] engine ([`crate::exec::run_serial`] driving a
//! [`crate::exec::DensePairSolver`]). The distributed execution with real
//! worker threads + communication accounting is the *other* thin wrapper,
//! [`crate::coordinator::run_distributed`]; both must produce the identical
//! tree because they share one plan/solve/reduce implementation.

use super::partition::PartitionStrategy;
use crate::data::Dataset;
use crate::dense::DenseMst;
use crate::exec::{run_serial, DensePairSolver, ExecPlan};
use crate::graph::Edge;

/// Configuration for the decomposed EMST.
#[derive(Clone, Debug)]
pub struct DecompConfig {
    /// `|P|` — number of subsets in the partition
    pub parts: usize,
    pub strategy: PartitionStrategy,
    pub seed: u64,
    /// Also retain per-pair outputs (for analysis / benches).
    pub keep_pair_trees: bool,
}

impl Default for DecompConfig {
    fn default() -> Self {
        Self { parts: 4, strategy: PartitionStrategy::RandomShuffle, seed: 0, keep_pair_trees: false }
    }
}

/// Result of the decomposed algorithm, with the analysis counters the
/// paper's cost model talks about.
#[derive(Clone, Debug)]
pub struct DecompOutput {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    /// total edges gathered from all pair jobs before the final sparse MST —
    /// the `O(|V|·|P|)` gather payload
    pub union_edges: usize,
    /// d-MST kernel distance evaluations (work measure for E2)
    pub dist_evals: u64,
    /// number of pair jobs executed (`|P|(|P|-1)/2`)
    pub jobs: usize,
    /// per-pair trees in schedule order, if `keep_pair_trees`
    pub pair_trees: Vec<Vec<Edge>>,
    /// sizes of each subset
    pub part_sizes: Vec<usize>,
}

/// Run Algorithm 1 serially: partition, d-MST per pair, union, sparse MST.
///
/// The returned tree is the exact MSF of the complete graph over `ds` under
/// the kernel's metric (Theorem 1). Counters on `kernel` are reset first so
/// `dist_evals` reflects only this invocation.
pub fn decomposed_mst(ds: &Dataset, cfg: &DecompConfig, kernel: &dyn DenseMst) -> DecompOutput {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    kernel.reset_counters();
    let mut solver = DensePairSolver::borrowed(ds, kernel);
    let run = run_serial(ds.n, &plan, &mut solver, cfg.keep_pair_trees);
    DecompOutput {
        mst: run.mst,
        union_edges: run.union_edges,
        dist_evals: kernel.dist_evals(),
        jobs: run.jobs,
        pair_trees: run.pair_trees,
        part_sizes: plan.part_sizes(),
    }
}

/// d-MST over `S_i ∪ S_j`, reindexed back to global vertex ids.
///
/// This is the "reindexing the vertices ... to respect the global vector
/// indexing upon return of each d-MST" the paper notes an implementation
/// must do — with one strengthening: the union is sorted by **global id**
/// before the kernel runs, so the local index order is a strictly increasing
/// map of the global order. The dense kernels break distance ties by index,
/// hence sorted reindexing makes every subproblem agree with the global
/// strict `(w, u, v)` edge order, and the decomposition returns the unique
/// canonical MSF even when the true MSF is *not* unique (duplicate points /
/// tied distances) — a case the paper excludes by assumption.
pub fn run_pair(ds: &Dataset, si: &[u32], sj: &[u32], kernel: &dyn DenseMst) -> Vec<Edge> {
    let local_to_global = merge_sorted_ids(si, sj);
    let sub = ds.gather(&local_to_global);
    let local_tree = kernel.mst(&sub);
    local_tree
        .iter()
        .map(|e| Edge::new(local_to_global[e.u as usize], local_to_global[e.v as usize], e.w))
        .collect()
}

/// Merge two ascending id lists into one ascending list (the subsets of a
/// partition are disjoint and kept sorted by the partitioners).
pub fn merge_sorted_ids(si: &[u32], sj: &[u32]) -> Vec<u32> {
    debug_assert!(si.windows(2).all(|w| w[0] < w[1]));
    debug_assert!(sj.windows(2).all(|w| w[0] < w[1]));
    let mut out = Vec::with_capacity(si.len() + sj.len());
    let (mut a, mut b) = (0usize, 0usize);
    while a < si.len() && b < sj.len() {
        if si[a] < sj[b] {
            out.push(si[a]);
            a += 1;
        } else {
            out.push(sj[b]);
            b += 1;
        }
    }
    out.extend_from_slice(&si[a..]);
    out.extend_from_slice(&sj[b..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs, uniform, BlobSpec};
    use crate::dense::PrimDense;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::{normalize_tree, total_weight};
    use crate::util::prng::Pcg64;

    fn exact_mst(ds: &Dataset) -> Vec<Edge> {
        PrimDense::sq_euclid().mst(ds)
    }

    #[test]
    fn theorem1_exactness_small() {
        let ds = uniform(60, 5, 1.0, Pcg64::seeded(200));
        let expect = exact_mst(&ds);
        for parts in [1usize, 2, 3, 4, 6, 10] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            assert!(is_spanning_tree(ds.n, &out.mst), "parts={parts}");
            assert_eq!(
                normalize_tree(&expect),
                normalize_tree(&out.mst),
                "parts={parts}: Theorem 1 exactness"
            );
        }
    }

    #[test]
    fn exactness_across_strategies() {
        let ds = gaussian_blobs(
            &BlobSpec { n: 80, d: 10, k: 5, std: 0.4, spread: 6.0 },
            Pcg64::seeded(201),
        );
        let expect = exact_mst(&ds);
        for strategy in PartitionStrategy::ALL {
            let cfg = DecompConfig { parts: 5, strategy, seed: 9, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            assert_eq!(
                normalize_tree(&expect),
                normalize_tree(&out.mst),
                "strategy {strategy:?}"
            );
        }
    }

    #[test]
    fn union_is_superset_of_mst() {
        // Lemma 1 consequence: every global MST edge appears in the union.
        let ds = uniform(50, 3, 1.0, Pcg64::seeded(202));
        let cfg = DecompConfig { parts: 5, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let union: Vec<Edge> = out.pair_trees.iter().flatten().copied().collect();
        let union_norm = crate::graph::edge::dedup_edges(&union);
        for e in normalize_tree(&out.mst) {
            assert!(
                union_norm
                    .binary_search_by(|u| u.u.cmp(&e.u).then(u.v.cmp(&e.v)))
                    .is_ok(),
                "MST edge ({},{}) missing from union",
                e.u,
                e.v
            );
        }
    }

    #[test]
    fn union_edge_count_bound() {
        // Each pair tree has |S_i ∪ S_j| - 1 edges; total ≈ |V|(|P|-1) — the
        // O(|V||P|) gather the paper reports.
        let ds = uniform(96, 4, 1.0, Pcg64::seeded(203));
        for parts in [2usize, 4, 8] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            let expect: usize = {
                // sum over pairs of (|S_i| + |S_j| - 1)
                let sizes = &out.part_sizes;
                let mut s = 0usize;
                for j in 1..parts {
                    for i in 0..j {
                        s += sizes[i] + sizes[j] - 1;
                    }
                }
                s
            };
            assert_eq!(out.union_edges, expect, "parts={parts}");
            assert!(out.union_edges <= ds.n * parts, "O(|V||P|) bound");
        }
    }

    #[test]
    fn work_overhead_matches_formula() {
        // Even partition, PrimDense does exactly m(m-1)/2 evals for m points:
        // total = p(p-1)/2 * (2n/p)(2n/p - 1)/2. Ratio to n(n-1)/2 approaches
        // 2(p-1)/p.
        let n = 120usize;
        let ds = uniform(n, 3, 1.0, Pcg64::seeded(204));
        let base = PrimDense::sq_euclid();
        base.mst(&ds);
        let base_evals = base.dist_evals() as f64;
        for parts in [2usize, 3, 4, 6] {
            let cfg =
                DecompConfig { parts, strategy: PartitionStrategy::Block, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            let m = 2 * n / parts;
            let expected = (parts * (parts - 1) / 2 * (m * (m - 1) / 2)) as u64;
            assert_eq!(out.dist_evals, expected, "parts={parts}");
            let ratio = out.dist_evals as f64 / base_evals;
            let formula = 2.0 * (parts as f64 - 1.0) / parts as f64;
            // (m-1) vs n-1 second-order terms make it slightly below formula
            assert!(
                (ratio - formula).abs() < 0.05,
                "parts={parts}: ratio={ratio:.3} formula={formula:.3}"
            );
        }
    }

    /// Complete-graph edges via the scalar `PlainMetric` — the
    /// metric-generic brute oracle.
    fn complete_edges_metric(ds: &Dataset, kind: crate::geometry::MetricKind) -> Vec<Edge> {
        use crate::geometry::metric::PlainMetric;
        use crate::geometry::Metric;
        let m = PlainMetric(kind);
        let mut edges = Vec::with_capacity(ds.n * (ds.n - 1) / 2);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                edges.push(Edge::new(i as u32, j as u32, m.dist(ds.row(i), ds.row(j))));
            }
        }
        edges
    }

    /// Integer coordinates keep the blocked Gram-form kernels float-exact
    /// against the scalar metrics (sums below 2^24), so tree comparisons can
    /// be equality, not tolerance.
    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(15) as f32 - 7.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn theorem1_exactness_cosine_blocked_vs_scalar_oracle() {
        let ds = int_dataset(210, 64, 6);
        let kind = crate::geometry::MetricKind::Cosine;
        let expect = crate::mst::kruskal(ds.n, &complete_edges_metric(&ds, kind));
        for parts in [1usize, 2, 4, 6] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::new(kind));
            assert!(is_spanning_tree(ds.n, &out.mst), "parts={parts}");
            assert_eq!(
                normalize_tree(&expect),
                normalize_tree(&out.mst),
                "parts={parts}: cosine decomposition must match the scalar oracle"
            );
        }
    }

    #[test]
    fn theorem1_exactness_manhattan_blocked_vs_scalar_oracle() {
        let ds = int_dataset(211, 72, 5);
        let kind = crate::geometry::MetricKind::Manhattan;
        let expect = crate::mst::kruskal(ds.n, &complete_edges_metric(&ds, kind));
        for parts in [1usize, 3, 4, 8] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let out = decomposed_mst(&ds, &cfg, &PrimDense::new(kind));
            assert!(is_spanning_tree(ds.n, &out.mst), "parts={parts}");
            assert_eq!(
                normalize_tree(&expect),
                normalize_tree(&out.mst),
                "parts={parts}: manhattan decomposition must match the scalar oracle"
            );
        }
    }

    #[test]
    fn nonmetric_decomposition_across_strategies_and_kernels() {
        // Cosine + Manhattan through every partition strategy, with both the
        // blocked Prim kernel and the Borůvka blocked-step kernel, against
        // the scalar-Prim oracle.
        use crate::dense::{BoruvkaDense, PrimScalar};
        for kind in [
            crate::geometry::MetricKind::Cosine,
            crate::geometry::MetricKind::Manhattan,
        ] {
            let ds = int_dataset(212, 48, 4);
            let expect = PrimScalar::new(kind).mst(&ds);
            for strategy in PartitionStrategy::ALL {
                let cfg = DecompConfig { parts: 4, strategy, seed: 3, ..Default::default() };
                let a = decomposed_mst(&ds, &cfg, &PrimDense::new(kind));
                let b = decomposed_mst(&ds, &cfg, &BoruvkaDense::new_rust(kind));
                assert_eq!(
                    normalize_tree(&expect),
                    normalize_tree(&a.mst),
                    "{kind:?} {strategy:?} prim-blocked"
                );
                assert_eq!(
                    normalize_tree(&expect),
                    normalize_tree(&b.mst),
                    "{kind:?} {strategy:?} boruvka-blocked"
                );
            }
        }
    }

    #[test]
    fn weight_equals_exact_for_many_seeds() {
        for seed in 0..8 {
            let ds = uniform(40, 7, 1.0, Pcg64::seeded(300 + seed));
            let expect = total_weight(&exact_mst(&ds));
            let cfg = DecompConfig { parts: 4, seed, ..Default::default() };
            let got = total_weight(&decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid()).mst);
            assert!((expect - got).abs() < 1e-6 * (1.0 + expect), "seed={seed}");
        }
    }
}
