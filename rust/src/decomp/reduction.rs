//! The paper's bandwidth-reducing variant: instead of gathering all
//! `O(|V||P|)` pair-tree edges at the leader, reduce the trees pairwise with
//! the associative-enough operation `⊕(T1, T2) = MST(T1 ∪ T2)`, which keeps
//! every intermediate at ≤ `|V|-1` edges and the final gather at `O(|V|)`.
//!
//! The paper calls the distinction "purely pedantic" for correctness but it
//! changes the communication bound from `O(|V|√p)` to `O(|V|)`; experiment
//! E3 measures both.

use crate::graph::{Edge, UnionFind};
use crate::mst::kruskal;
use std::cmp::Ordering;

/// `⊕(T1, T2) = MST(T1 ∪ T2)` over `n` global vertices.
pub fn tree_merge(n: usize, t1: &[Edge], t2: &[Edge]) -> Vec<Edge> {
    let mut union = Vec::with_capacity(t1.len() + t2.len());
    union.extend_from_slice(t1);
    union.extend_from_slice(t2);
    kruskal(n, &union)
}

/// Streaming ⊕-accumulator: fold pair trees into a bounded running MSF as
/// they arrive, instead of buffering the full `O(|V|·|P|)` union for one
/// final Kruskal. ⊕ is associative and commutative on the canonical strict
/// order, so the arrival order (which is nondeterministic under the pooled
/// scheduler) never changes the result, and the leader's working set stays
/// ≤ `|V| - 1` edges at all times.
///
/// Folds are **incremental**: the running forest is kept presorted in the
/// strict `(w, u, v)` order, each arriving tree is sorted once (it is at
/// most `|V| - 1` edges), and the fold is a merge-join of the two sorted
/// streams through a reusable union-find — `O(|V|)` work per fold after the
/// arrival sort, with no per-push allocation and **no re-sort of the
/// running forest** (the old implementation re-ran a full Kruskal, i.e.
/// re-sorted up to `2(|V|-1)` edges, on every push). The merge of two
/// sorted streams visits edges in exactly the order the re-sorting Kruskal
/// did, so the admitted set — and therefore the result — is identical.
#[derive(Clone, Debug)]
pub struct StreamReducer {
    n: usize,
    /// running MSF, presorted ascending in the strict `(w, u, v)` order
    forest: Vec<Edge>,
    /// scratch: the arriving tree, canonicalized + sorted (reused)
    incoming: Vec<Edge>,
    /// scratch: the next forest being assembled (reused, swapped in)
    scratch: Vec<Edge>,
    /// reusable union-find, reset (not reallocated) per fold
    uf: UnionFind,
    /// trees folded in so far
    pub merges: usize,
    /// total edges received across all pushes
    pub edges_seen: u64,
    /// total edges scanned by the merge-join folds — bounded by
    /// `Σ (|forest| + |tree|) ≤ merges · 2(|V|-1)`, the witness that no
    /// fold re-sorted the running union
    pub fold_edges: u64,
}

impl StreamReducer {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            forest: Vec::new(),
            incoming: Vec::new(),
            scratch: Vec::new(),
            uf: UnionFind::new(n),
            merges: 0,
            edges_seen: 0,
            fold_edges: 0,
        }
    }

    /// Fold one arriving tree into the running MSF (merge-join, `O(|V|)`).
    pub fn push(&mut self, tree: &[Edge]) {
        self.edges_seen += tree.len() as u64;
        self.merges += 1;
        self.incoming.clear();
        self.incoming.extend(tree.iter().map(|e| Edge::new(e.u, e.v, e.w)));
        self.incoming.sort_unstable();
        self.fold_edges += (self.forest.len() + self.incoming.len()) as u64;
        self.uf.reset();
        self.scratch.clear();
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.forest.len() || b < self.incoming.len() {
            let take_forest = match (self.forest.get(a), self.incoming.get(b)) {
                (Some(x), Some(y)) => x.cmp_strict(y) != Ordering::Greater,
                (Some(_), None) => true,
                _ => false,
            };
            let e = if take_forest {
                a += 1;
                self.forest[a - 1]
            } else {
                b += 1;
                self.incoming[b - 1]
            };
            if self.uf.union(e.u, e.v) {
                self.scratch.push(e);
                if self.uf.components() == 1 {
                    break; // spanning: every further edge closes a cycle
                }
            }
        }
        std::mem::swap(&mut self.forest, &mut self.scratch);
        debug_assert!(self.n == 0 || self.forest.len() < self.n, "bounded running MSF");
        debug_assert!(
            self.forest.windows(2).all(|w| w[0].cmp_strict(&w[1]) != Ordering::Greater),
            "running forest stays presorted"
        );
    }

    /// Edges currently held (≤ `n - 1`).
    pub fn len(&self) -> usize {
        self.forest.len()
    }

    pub fn is_empty(&self) -> bool {
        self.forest.is_empty()
    }

    /// The final MSF (ascending strict order).
    pub fn finish(self) -> Vec<Edge> {
        self.forest
    }
}

/// Statistics from a reduction run.
#[derive(Clone, Debug, Default)]
pub struct ReductionStats {
    /// levels in the binary reduction tree
    pub levels: usize,
    /// total edges transmitted across all merge steps (each merge step
    /// "receives" its right operand)
    pub edges_transmitted: u64,
    /// max edges any single step transmitted (the O(|V|) claim)
    pub max_step_edges: usize,
    /// merges performed
    pub merges: usize,
}

/// Binary-tree reduction of per-pair MSTs. Returns the global MSF and the
/// communication statistics.
///
/// The final result's hop to the leader **is charged** into
/// `edges_transmitted` — the model where the last merge happens on some
/// worker and the result still has to travel. When the reduction itself
/// runs *at* the leader (the exec engine's gather path, where NetSim
/// already charged each worker tree's arrival), use
/// [`reduce_trees_with`]`(n, trees, false)` so that hop is not counted a
/// second time.
pub fn reduce_trees(n: usize, trees: &[Vec<Edge>]) -> (Vec<Edge>, ReductionStats) {
    reduce_trees_with(n, trees, true)
}

/// [`reduce_trees`] with the final leader hop made explicit:
/// `final_hop_to_leader = false` models a reduction running at the leader
/// (no trailing transfer), `true` a reduction finishing on a worker.
pub fn reduce_trees_with(
    n: usize,
    trees: &[Vec<Edge>],
    final_hop_to_leader: bool,
) -> (Vec<Edge>, ReductionStats) {
    let mut stats = ReductionStats::default();
    if trees.is_empty() {
        return (Vec::new(), stats);
    }
    let mut layer: Vec<Vec<Edge>> = trees.to_vec();
    while layer.len() > 1 {
        stats.levels += 1;
        let mut next = Vec::with_capacity(crate::util::div_ceil(layer.len(), 2));
        let mut it = layer.into_iter();
        while let Some(left) = it.next() {
            match it.next() {
                Some(right) => {
                    // the right operand is "sent" to the left's owner
                    stats.edges_transmitted += right.len() as u64;
                    stats.max_step_edges = stats.max_step_edges.max(right.len());
                    stats.merges += 1;
                    next.push(tree_merge(n, &left, &right));
                }
                None => next.push(left),
            }
        }
        layer = next;
    }
    let result = layer.pop().unwrap();
    if final_hop_to_leader {
        stats.edges_transmitted += result.len() as u64;
        stats.max_step_edges = stats.max_step_edges.max(result.len());
    }
    (result, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::decomp::{decomposed_mst, DecompConfig};
    use crate::dense::{DenseMst, PrimDense};
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    #[test]
    fn merge_is_mst_of_union() {
        let t1 = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 5.0)];
        let t2 = vec![Edge::new(0, 2, 2.0), Edge::new(2, 3, 1.0)];
        let m = tree_merge(4, &t1, &t2);
        assert_eq!(
            normalize_tree(&m),
            normalize_tree(&[Edge::new(0, 1, 1.0), Edge::new(0, 2, 2.0), Edge::new(2, 3, 1.0)])
        );
    }

    #[test]
    fn reduction_equals_gather() {
        let ds = uniform(64, 5, 1.0, Pcg64::seeded(400));
        let cfg = DecompConfig { parts: 6, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let (reduced, stats) = reduce_trees(ds.n, &out.pair_trees);
        assert_eq!(normalize_tree(&out.mst), normalize_tree(&reduced));
        assert!(stats.merges > 0);
        assert_eq!(stats.levels, 4, "15 trees -> 4 levels");
        // every step bounded by |V|-1
        assert!(stats.max_step_edges <= ds.n - 1, "O(|V|) per step");
    }

    #[test]
    fn intermediates_stay_forest_sized() {
        // Direct check of the O(|V|) claim: reduce many overlapping trees.
        let ds = uniform(40, 3, 1.0, Pcg64::seeded(401));
        let cfg = DecompConfig { parts: 8, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let (_, stats) = reduce_trees(ds.n, &out.pair_trees);
        assert!(stats.max_step_edges < ds.n);
        // gather would transmit out.union_edges; reduction transmits less per
        // step but similar total across the tree: the *per-link* bound is the
        // claim.
        assert!(out.union_edges as u64 >= stats.max_step_edges as u64);
    }

    #[test]
    fn empty_and_single() {
        let (r, s) = reduce_trees(5, &[]);
        assert!(r.is_empty());
        assert_eq!(s.merges, 0);
        let one = vec![vec![Edge::new(0, 1, 1.0)]];
        let (r, s) = reduce_trees(5, &one);
        assert_eq!(r.len(), 1);
        assert_eq!(s.levels, 0);
        assert_eq!(s.edges_transmitted, 1);
    }

    #[test]
    fn stream_reducer_equals_batch_kruskal_any_order() {
        let ds = uniform(56, 4, 1.0, Pcg64::seeded(402));
        let cfg = DecompConfig { parts: 7, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let union: Vec<Edge> = out.pair_trees.iter().flatten().copied().collect();
        let batch = crate::mst::kruskal(ds.n, &union);
        // forward and reversed arrival orders give the identical MSF
        for reversed in [false, true] {
            let mut r = StreamReducer::new(ds.n);
            let mut trees: Vec<&Vec<Edge>> = out.pair_trees.iter().collect();
            if reversed {
                trees.reverse();
            }
            for t in trees {
                r.push(t);
                assert!(r.len() < ds.n, "bounded at every step");
            }
            assert_eq!(r.merges, out.pair_trees.len());
            assert_eq!(r.edges_seen as usize, out.union_edges);
            assert_eq!(normalize_tree(&batch), normalize_tree(&r.finish()), "rev={reversed}");
        }
    }

    #[test]
    fn stream_reducer_equals_batch_under_random_permutations() {
        // beyond forward/reverse: commutativity under arbitrary arrival
        // orders, exactly the nondeterminism the pooled scheduler produces
        let ds = uniform(48, 4, 1.0, Pcg64::seeded(403));
        let cfg = DecompConfig { parts: 6, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let union: Vec<Edge> = out.pair_trees.iter().flatten().copied().collect();
        let batch = crate::mst::kruskal(ds.n, &union);
        let mut rng = Pcg64::seeded(77);
        for round in 0..12 {
            let mut order: Vec<usize> = (0..out.pair_trees.len()).collect();
            rng.shuffle(&mut order);
            let mut r = StreamReducer::new(ds.n);
            for &k in &order {
                r.push(&out.pair_trees[k]);
                assert!(r.len() < ds.n, "bounded at every step");
            }
            assert_eq!(r.merges, out.pair_trees.len());
            assert_eq!(
                normalize_tree(&batch),
                normalize_tree(&r.finish()),
                "round {round}: order {order:?}"
            );
        }
    }

    #[test]
    fn stream_reducer_folds_are_linear_not_resorted() {
        // fold_edges ≤ merges · 2(|V|-1): every fold is a merge-join over
        // the bounded forest + one tree, never a re-sort of the full union.
        let ds = uniform(64, 5, 1.0, Pcg64::seeded(404));
        let cfg = DecompConfig { parts: 8, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let mut r = StreamReducer::new(ds.n);
        for t in &out.pair_trees {
            r.push(t);
        }
        let folds = r.merges as u64;
        assert!(folds > 0);
        assert!(
            r.fold_edges <= folds * 2 * (ds.n as u64 - 1),
            "fold cost {} exceeds the O(|V|)-per-fold bound",
            r.fold_edges
        );
        // strictly cheaper than re-sorting the accumulated union each fold
        assert!(r.fold_edges < r.edges_seen * folds, "sanity: not quadratic in the union");
    }

    #[test]
    fn reduce_trees_final_hop_gating() {
        // At-the-leader reductions must not charge the final result's trip.
        let one = vec![vec![Edge::new(0, 1, 1.0)]];
        let (r, s) = reduce_trees_with(5, &one, false);
        assert_eq!(r.len(), 1);
        assert_eq!(s.edges_transmitted, 0, "no merge, no final hop: nothing travels");
        let (_, with_hop) = reduce_trees_with(5, &one, true);
        assert_eq!(with_hop.edges_transmitted, 1);
        // with merges, the two models differ by exactly the result size
        let trees = vec![
            vec![Edge::new(0, 1, 1.0)],
            vec![Edge::new(1, 2, 2.0)],
            vec![Edge::new(2, 3, 3.0)],
        ];
        let (result, at_leader) = reduce_trees_with(5, &trees, false);
        let (_, on_worker) = reduce_trees_with(5, &trees, true);
        assert_eq!(
            on_worker.edges_transmitted,
            at_leader.edges_transmitted + result.len() as u64
        );
    }

    #[test]
    fn stream_reducer_empty_and_single() {
        let mut r = StreamReducer::new(4);
        assert!(r.is_empty());
        r.push(&[Edge::new(0, 1, 1.0), Edge::new(0, 1, 2.0)]);
        assert_eq!(r.len(), 1, "parallel edges collapse immediately");
        assert_eq!(r.finish(), vec![Edge::new(0, 1, 1.0)]);
    }

    #[test]
    fn idempotent_merge() {
        let t = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.0)];
        assert_eq!(normalize_tree(&tree_merge(3, &t, &t)), normalize_tree(&t));
    }
}
