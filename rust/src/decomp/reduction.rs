//! The paper's bandwidth-reducing variant: instead of gathering all
//! `O(|V||P|)` pair-tree edges at the leader, reduce the trees pairwise with
//! the associative-enough operation `⊕(T1, T2) = MST(T1 ∪ T2)`, which keeps
//! every intermediate at ≤ `|V|-1` edges and the final gather at `O(|V|)`.
//!
//! The paper calls the distinction "purely pedantic" for correctness but it
//! changes the communication bound from `O(|V|√p)` to `O(|V|)`; experiment
//! E3 measures both.

use crate::graph::Edge;
use crate::mst::kruskal;

/// `⊕(T1, T2) = MST(T1 ∪ T2)` over `n` global vertices.
pub fn tree_merge(n: usize, t1: &[Edge], t2: &[Edge]) -> Vec<Edge> {
    let mut union = Vec::with_capacity(t1.len() + t2.len());
    union.extend_from_slice(t1);
    union.extend_from_slice(t2);
    kruskal(n, &union)
}

/// Streaming ⊕-accumulator: fold pair trees into a bounded running MSF as
/// they arrive, instead of buffering the full `O(|V|·|P|)` union for one
/// final Kruskal. ⊕ is associative and commutative on the canonical strict
/// order, so the arrival order (which is nondeterministic under the pooled
/// scheduler) never changes the result, and the leader's working set stays
/// ≤ `|V| - 1` edges at all times.
#[derive(Clone, Debug)]
pub struct StreamReducer {
    n: usize,
    forest: Vec<Edge>,
    /// trees folded in so far
    pub merges: usize,
    /// total edges received across all pushes
    pub edges_seen: u64,
}

impl StreamReducer {
    pub fn new(n: usize) -> Self {
        Self { n, forest: Vec::new(), merges: 0, edges_seen: 0 }
    }

    /// Fold one arriving tree into the running MSF.
    pub fn push(&mut self, tree: &[Edge]) {
        self.edges_seen += tree.len() as u64;
        self.merges += 1;
        self.forest = tree_merge(self.n, &self.forest, tree);
        debug_assert!(self.n == 0 || self.forest.len() < self.n, "bounded running MSF");
    }

    /// Edges currently held (≤ `n - 1`).
    pub fn len(&self) -> usize {
        self.forest.len()
    }

    pub fn is_empty(&self) -> bool {
        self.forest.is_empty()
    }

    /// The final MSF (ascending strict order).
    pub fn finish(self) -> Vec<Edge> {
        self.forest
    }
}

/// Statistics from a reduction run.
#[derive(Clone, Debug, Default)]
pub struct ReductionStats {
    /// levels in the binary reduction tree
    pub levels: usize,
    /// total edges transmitted across all merge steps (each merge step
    /// "receives" its right operand)
    pub edges_transmitted: u64,
    /// max edges any single step transmitted (the O(|V|) claim)
    pub max_step_edges: usize,
    /// merges performed
    pub merges: usize,
}

/// Binary-tree reduction of per-pair MSTs. Returns the global MSF and the
/// communication statistics.
pub fn reduce_trees(n: usize, trees: &[Vec<Edge>]) -> (Vec<Edge>, ReductionStats) {
    let mut stats = ReductionStats::default();
    if trees.is_empty() {
        return (Vec::new(), stats);
    }
    let mut layer: Vec<Vec<Edge>> = trees.to_vec();
    while layer.len() > 1 {
        stats.levels += 1;
        let mut next = Vec::with_capacity(crate::util::div_ceil(layer.len(), 2));
        let mut it = layer.into_iter();
        while let Some(left) = it.next() {
            match it.next() {
                Some(right) => {
                    // the right operand is "sent" to the left's owner
                    stats.edges_transmitted += right.len() as u64;
                    stats.max_step_edges = stats.max_step_edges.max(right.len());
                    stats.merges += 1;
                    next.push(tree_merge(n, &left, &right));
                }
                None => next.push(left),
            }
        }
        layer = next;
    }
    // final result travels to the leader once
    let result = layer.pop().unwrap();
    stats.edges_transmitted += result.len() as u64;
    stats.max_step_edges = stats.max_step_edges.max(result.len());
    (result, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::decomp::{decomposed_mst, DecompConfig};
    use crate::dense::{DenseMst, PrimDense};
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    #[test]
    fn merge_is_mst_of_union() {
        let t1 = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 5.0)];
        let t2 = vec![Edge::new(0, 2, 2.0), Edge::new(2, 3, 1.0)];
        let m = tree_merge(4, &t1, &t2);
        assert_eq!(
            normalize_tree(&m),
            normalize_tree(&[Edge::new(0, 1, 1.0), Edge::new(0, 2, 2.0), Edge::new(2, 3, 1.0)])
        );
    }

    #[test]
    fn reduction_equals_gather() {
        let ds = uniform(64, 5, 1.0, Pcg64::seeded(400));
        let cfg = DecompConfig { parts: 6, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let (reduced, stats) = reduce_trees(ds.n, &out.pair_trees);
        assert_eq!(normalize_tree(&out.mst), normalize_tree(&reduced));
        assert!(stats.merges > 0);
        assert_eq!(stats.levels, 4, "15 trees -> 4 levels");
        // every step bounded by |V|-1
        assert!(stats.max_step_edges <= ds.n - 1, "O(|V|) per step");
    }

    #[test]
    fn intermediates_stay_forest_sized() {
        // Direct check of the O(|V|) claim: reduce many overlapping trees.
        let ds = uniform(40, 3, 1.0, Pcg64::seeded(401));
        let cfg = DecompConfig { parts: 8, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let (_, stats) = reduce_trees(ds.n, &out.pair_trees);
        assert!(stats.max_step_edges < ds.n);
        // gather would transmit out.union_edges; reduction transmits less per
        // step but similar total across the tree: the *per-link* bound is the
        // claim.
        assert!(out.union_edges as u64 >= stats.max_step_edges as u64);
    }

    #[test]
    fn empty_and_single() {
        let (r, s) = reduce_trees(5, &[]);
        assert!(r.is_empty());
        assert_eq!(s.merges, 0);
        let one = vec![vec![Edge::new(0, 1, 1.0)]];
        let (r, s) = reduce_trees(5, &one);
        assert_eq!(r.len(), 1);
        assert_eq!(s.levels, 0);
        assert_eq!(s.edges_transmitted, 1);
    }

    #[test]
    fn stream_reducer_equals_batch_kruskal_any_order() {
        let ds = uniform(56, 4, 1.0, Pcg64::seeded(402));
        let cfg = DecompConfig { parts: 7, keep_pair_trees: true, ..Default::default() };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let union: Vec<Edge> = out.pair_trees.iter().flatten().copied().collect();
        let batch = crate::mst::kruskal(ds.n, &union);
        // forward and reversed arrival orders give the identical MSF
        for reversed in [false, true] {
            let mut r = StreamReducer::new(ds.n);
            let mut trees: Vec<&Vec<Edge>> = out.pair_trees.iter().collect();
            if reversed {
                trees.reverse();
            }
            for t in trees {
                r.push(t);
                assert!(r.len() < ds.n, "bounded at every step");
            }
            assert_eq!(r.merges, out.pair_trees.len());
            assert_eq!(r.edges_seen as usize, out.union_edges);
            assert_eq!(normalize_tree(&batch), normalize_tree(&r.finish()), "rev={reversed}");
        }
    }

    #[test]
    fn stream_reducer_empty_and_single() {
        let mut r = StreamReducer::new(4);
        assert!(r.is_empty());
        r.push(&[Edge::new(0, 1, 1.0), Edge::new(0, 1, 2.0)]);
        assert_eq!(r.len(), 1, "parallel edges collapse immediately");
        assert_eq!(r.finish(), vec![Edge::new(0, 1, 1.0)]);
    }

    #[test]
    fn idempotent_merge() {
        let t = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.0)];
        assert_eq!(normalize_tree(&tree_merge(3, &t, &t)), normalize_tree(&t));
    }
}
