//! The pair schedule: one job per unordered partition pair `(S_i, S_j)`.
//!
//! `|P|(|P|-1)/2` jobs — the paper's process count `p`. Jobs are independent
//! (zero communication between them), which is the whole point.

/// One d-MST job over `S_i ∪ S_j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PairJob {
    /// job id in schedule order
    pub id: u32,
    pub i: u32,
    pub j: u32,
}

/// `p = |P|(|P|-1)/2` — the number of pair jobs / processes.
pub fn pair_count(parts: usize) -> usize {
    parts * parts.saturating_sub(1) / 2
}

/// All unordered pairs in the paper's loop order (`j` outer from 2, `i`
/// inner), which interleaves subsets across early jobs.
#[derive(Clone, Debug)]
pub struct PairSchedule {
    pub parts: usize,
    pub jobs: Vec<PairJob>,
}

impl PairSchedule {
    pub fn new(parts: usize) -> Self {
        let mut jobs = Vec::with_capacity(pair_count(parts));
        let mut id = 0u32;
        for j in 1..parts as u32 {
            for i in 0..j {
                jobs.push(PairJob { id, i, j });
                id += 1;
            }
        }
        Self { parts, jobs }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// How many jobs touch each subset (= |P| - 1 for all subsets).
    pub fn touches_per_subset(&self) -> Vec<usize> {
        let mut t = vec![0usize; self.parts];
        for job in &self.jobs {
            t[job.i as usize] += 1;
            t[job.j as usize] += 1;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(pair_count(1), 0);
        assert_eq!(pair_count(2), 1);
        assert_eq!(pair_count(4), 6);
        assert_eq!(pair_count(16), 120);
    }

    #[test]
    fn schedule_enumerates_all_pairs_once() {
        let s = PairSchedule::new(5);
        assert_eq!(s.len(), 10);
        let mut seen = std::collections::HashSet::new();
        for job in &s.jobs {
            assert!(job.i < job.j, "canonical order");
            assert!(seen.insert((job.i, job.j)), "duplicate pair");
        }
        // ids are schedule positions
        for (pos, job) in s.jobs.iter().enumerate() {
            assert_eq!(job.id as usize, pos);
        }
    }

    #[test]
    fn paper_loop_order() {
        let s = PairSchedule::new(4);
        let pairs: Vec<(u32, u32)> = s.jobs.iter().map(|j| (j.i, j.j)).collect();
        assert_eq!(pairs, vec![(0, 1), (0, 2), (1, 2), (0, 3), (1, 3), (2, 3)]);
    }

    #[test]
    fn every_subset_touched_p_minus_1_times() {
        for parts in [2usize, 3, 7, 12] {
            let s = PairSchedule::new(parts);
            assert!(s.touches_per_subset().iter().all(|&t| t == parts - 1));
        }
    }

    #[test]
    fn single_part_empty_schedule() {
        let s = PairSchedule::new(1);
        assert!(s.is_empty());
    }
}
