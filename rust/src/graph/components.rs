//! Connected components of an edge list (via union-find).

use super::{Edge, UnionFind};

/// Dense component labels (`0..k`) for `n` vertices under `edges`.
pub fn component_labels(n: usize, edges: &[Edge]) -> Vec<u32> {
    let mut uf = UnionFind::new(n);
    for e in edges {
        uf.union(e.u, e.v);
    }
    uf.dense_labels()
}

/// Number of connected components of `n` vertices under `edges`.
pub fn num_components(n: usize, edges: &[Edge]) -> usize {
    let mut uf = UnionFind::new(n);
    for e in edges {
        uf.union(e.u, e.v);
    }
    uf.components()
}

/// True iff `edges` form a spanning tree of `n` vertices: exactly `n-1`
/// edges, one component, no duplicate pairs.
pub fn is_spanning_tree(n: usize, edges: &[Edge]) -> bool {
    if n == 0 {
        return edges.is_empty();
    }
    if edges.len() != n - 1 {
        return false;
    }
    let mut uf = UnionFind::new(n);
    for e in edges {
        if (e.u as usize) >= n || (e.v as usize) >= n || e.u == e.v {
            return false;
        }
        if !uf.union(e.u, e.v) {
            return false; // cycle
        }
    }
    uf.components() == 1
}

/// True iff `edges` form a spanning forest (acyclic; any component count).
pub fn is_forest(n: usize, edges: &[Edge]) -> bool {
    let mut uf = UnionFind::new(n);
    edges.iter().all(|e| {
        (e.u as usize) < n && (e.v as usize) < n && e.u != e.v && uf.union(e.u, e.v)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(u: u32, v: u32) -> Edge {
        Edge::new(u, v, 1.0)
    }

    #[test]
    fn labels_and_counts() {
        let edges = vec![e(0, 1), e(2, 3), e(3, 4)];
        assert_eq!(num_components(6, &edges), 3);
        let l = component_labels(6, &edges);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[2], l[4]);
        assert_ne!(l[0], l[2]);
        assert_ne!(l[5], l[0]);
    }

    #[test]
    fn spanning_tree_checks() {
        assert!(is_spanning_tree(4, &[e(0, 1), e(1, 2), e(2, 3)]));
        assert!(!is_spanning_tree(4, &[e(0, 1), e(1, 2)]), "too few edges");
        assert!(!is_spanning_tree(4, &[e(0, 1), e(1, 2), e(0, 2)]), "cycle");
        assert!(is_spanning_tree(1, &[]));
        assert!(is_spanning_tree(0, &[]));
    }

    #[test]
    fn forest_checks() {
        assert!(is_forest(5, &[e(0, 1), e(2, 3)]));
        assert!(!is_forest(5, &[e(0, 1), e(1, 2), e(0, 2)]));
        assert!(is_forest(5, &[]));
    }
}
