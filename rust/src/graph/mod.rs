//! Graph primitives: weighted edges, union-find, connected components.

pub mod edge;
pub mod dsu;
pub mod components;

pub use dsu::UnionFind;
pub use edge::{canonical_edges, dedup_edges, sort_edges, Edge};
pub use components::{component_labels, num_components};
