//! Union-find (disjoint-set union) with union by rank and path halving.
//!
//! Used by Kruskal, sparse/dense Borůvka, SLINK→dendrogram conversion, and
//! flat-cluster extraction. Amortized `O(α(n))` per op.

/// Disjoint-set union over `0..n`.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "UnionFind supports up to 2^32-1 elements");
        Self {
            parent: (0..n as u32).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components remaining.
    #[inline]
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving (iterative, no recursion).
    #[inline]
    pub fn find(&mut self, mut x: u32) -> u32 {
        debug_assert!((x as usize) < self.parent.len());
        loop {
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
    }

    /// Read-only find (no compression) — usable with `&self`.
    #[inline]
    pub fn find_const(&self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            x = p;
        }
    }

    /// Union by rank; returns `true` if a merge happened.
    #[inline]
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        self.components -= 1;
        true
    }

    #[inline]
    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    /// Root label for every element (compresses everything).
    pub fn labels(&mut self) -> Vec<u32> {
        (0..self.parent.len() as u32).map(|i| self.find(i)).collect()
    }

    /// Root labels renumbered densely to `0..k` in first-appearance order.
    pub fn dense_labels(&mut self) -> Vec<u32> {
        let roots = self.labels();
        let mut map = vec![u32::MAX; self.parent.len()];
        let mut next = 0u32;
        let mut out = Vec::with_capacity(roots.len());
        for r in roots {
            if map[r as usize] == u32::MAX {
                map[r as usize] = next;
                next += 1;
            }
            out.push(map[r as usize]);
        }
        out
    }

    /// Reset to n singletons without reallocating.
    pub fn reset(&mut self) {
        for (i, p) in self.parent.iter_mut().enumerate() {
            *p = i as u32;
        }
        self.rank.fill(0);
        self.components = self.parent.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singletons_initially() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        for i in 0..5 {
            assert_eq!(uf.find(i), i);
        }
    }

    #[test]
    fn union_merges_and_counts() {
        let mut uf = UnionFind::new(6);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0), "already merged");
        assert_eq!(uf.components(), 4);
        assert!(uf.same(0, 1));
        assert!(!uf.same(0, 2));
        assert!(uf.union(0, 2));
        assert!(uf.same(1, 3));
        assert_eq!(uf.components(), 3);
    }

    #[test]
    fn chain_compresses() {
        let mut uf = UnionFind::new(100);
        for i in 0..99 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.components(), 1);
        let r = uf.find(0);
        for i in 0..100 {
            assert_eq!(uf.find(i), r);
        }
    }

    #[test]
    fn dense_labels_are_dense() {
        let mut uf = UnionFind::new(7);
        uf.union(1, 2);
        uf.union(4, 5);
        uf.union(5, 6);
        let l = uf.dense_labels();
        assert_eq!(l[1], l[2]);
        assert_eq!(l[4], l[5]);
        assert_eq!(l[5], l[6]);
        assert_ne!(l[0], l[1]);
        let max = *l.iter().max().unwrap() as usize;
        assert_eq!(max + 1, uf.components());
        assert_eq!(l[0], 0, "first-appearance order starts at 0");
    }

    #[test]
    fn find_const_agrees_with_find() {
        let mut uf = UnionFind::new(32);
        for i in (0..31).step_by(2) {
            uf.union(i, i + 1);
        }
        for i in 0..32 {
            assert_eq!(uf.find_const(i), uf.clone().find(i));
        }
    }

    #[test]
    fn reset_restores_singletons() {
        let mut uf = UnionFind::new(8);
        uf.union(0, 7);
        uf.union(1, 2);
        uf.reset();
        assert_eq!(uf.components(), 8);
        assert!(!uf.same(0, 7));
    }
}
