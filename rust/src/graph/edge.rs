//! Weighted undirected edges with the crate-wide canonical strict order.

use crate::util::fkey::edge_cmp;
use std::cmp::Ordering;

/// An undirected weighted edge. Canonical form keeps `u < v`.
///
/// The strict total order `(w, u, v)` (weights via IEEE total_cmp) makes the
/// minimum spanning forest unique even under weight ties, which is the
/// uniqueness assumption the paper's Theorem 1 relies on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub u: u32,
    pub v: u32,
    pub w: f32,
}

impl Edge {
    /// Construct in canonical form (`u < v`). Panics on self-loops in debug.
    #[inline]
    pub fn new(u: u32, v: u32, w: f32) -> Self {
        debug_assert!(u != v, "self-loop edge ({u},{v})");
        debug_assert!(!w.is_nan(), "NaN edge weight");
        if u < v {
            Self { u, v, w }
        } else {
            Self { u: v, v: u, w }
        }
    }

    /// The endpoint other than `x` (debug-asserts `x` is an endpoint).
    #[inline]
    pub fn other(&self, x: u32) -> u32 {
        debug_assert!(x == self.u || x == self.v);
        if x == self.u {
            self.v
        } else {
            self.u
        }
    }

    /// Strict total order: `(w, u, v)` lexicographic.
    #[inline]
    pub fn cmp_strict(&self, other: &Self) -> Ordering {
        edge_cmp(self.w, self.u, self.v, other.w, other.u, other.v)
    }

    /// Serialized wire size in bytes (u32 + u32 + f32): used by the netsim
    /// byte accounting.
    pub const WIRE_BYTES: usize = 12;
}

impl Eq for Edge {}

impl PartialOrd for Edge {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp_strict(other))
    }
}

impl Ord for Edge {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.cmp_strict(other)
    }
}

/// Sort edges by the canonical strict order.
pub fn sort_edges(edges: &mut [Edge]) {
    edges.sort_unstable();
}

/// Canonicalize endpoint order on every edge (u < v), preserving weights.
pub fn canonical_edges(edges: &[Edge]) -> Vec<Edge> {
    edges.iter().map(|e| Edge::new(e.u, e.v, e.w)).collect()
}

/// Sort + remove duplicate `(u, v)` pairs, keeping the smallest weight for
/// each pair. Inputs need not be canonical. Used when unioning pairwise
/// d-MSTs before the final sparse MST — the same global edge appears in up to
/// `|P|-1` subproblem trees.
pub fn dedup_edges(edges: &[Edge]) -> Vec<Edge> {
    let mut es = canonical_edges(edges);
    // Order by (u, v, w) so equal pairs are adjacent, cheapest first.
    es.sort_unstable_by(|a, b| {
        a.u.cmp(&b.u).then(a.v.cmp(&b.v)).then(a.w.total_cmp(&b.w))
    });
    es.dedup_by(|next, prev| next.u == prev.u && next.v == prev.v);
    es
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_orientation() {
        let e = Edge::new(5, 2, 1.5);
        assert_eq!((e.u, e.v, e.w), (2, 5, 1.5));
        assert_eq!(e.other(2), 5);
        assert_eq!(e.other(5), 2);
    }

    #[test]
    fn strict_order_ties_broken_by_endpoints() {
        let a = Edge::new(0, 1, 1.0);
        let b = Edge::new(0, 2, 1.0);
        let c = Edge::new(1, 2, 0.5);
        let mut v = vec![b, a, c];
        sort_edges(&mut v);
        assert_eq!(v, vec![c, a, b]);
    }

    #[test]
    fn dedup_keeps_min_weight() {
        let es = vec![
            Edge { u: 3, v: 1, w: 2.0 }, // non-canonical on purpose
            Edge::new(1, 3, 1.0),
            Edge::new(1, 3, 3.0),
            Edge::new(0, 1, 0.5),
        ];
        let d = dedup_edges(&es);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0], Edge::new(0, 1, 0.5));
        assert_eq!(d[1], Edge::new(1, 3, 1.0));
    }

    #[test]
    fn dedup_empty() {
        assert!(dedup_edges(&[]).is_empty());
    }

    #[test]
    fn wire_bytes_matches_fields() {
        assert_eq!(Edge::WIRE_BYTES, 4 + 4 + 4);
    }
}
