//! Reporting: fixed-width console tables (the bench harness prints the
//! paper-style rows through this) and CSV output.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: add a row of displayable items.
    pub fn push_row<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for c in 0..cols {
                if c > 0 {
                    s.push_str("  ");
                }
                let w = widths[c];
                let cell = &cells[c];
                // right-align numerics, left-align text
                if cell.parse::<f64>().is_ok() || cell.ends_with('%') || cell.ends_with('x') {
                    let pad = w.saturating_sub(cell.chars().count());
                    s.push_str(&" ".repeat(pad));
                    s.push_str(cell);
                } else {
                    s.push_str(cell);
                    let pad = w.saturating_sub(cell.chars().count());
                    s.push_str(&" ".repeat(pad));
                }
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut s = String::new();
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, s)
    }
}

/// Format a ratio as `1.87x`.
pub fn ratio(x: f64) -> String {
    format!("{:.2}x", x)
}

/// Format a float with 3 significant decimals.
pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.push_row(&["alpha".to_string(), "1.5".to_string()]);
        t.push_row(&["b".to_string(), "100.25".to_string()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        // all rows same width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("c", &["a", "b"]);
        t.push_row(&["has,comma".to_string(), "has\"quote".to_string()]);
        let p = std::env::temp_dir().join("demst_report_test.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"has,comma\""));
        assert!(s.contains("\"has\"\"quote\""));
    }

    #[test]
    fn helpers() {
        assert_eq!(ratio(1.875), "1.88x");
        assert_eq!(f3(0.12349), "0.123");
    }
}
