//! Minimal CSV reader/writer for numeric matrices (embedding exports from
//! pandas / spreadsheets). Auto-detects and skips a single header row;
//! accepts comma / semicolon / tab separators; rejects ragged rows.

use super::Dataset;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Read a numeric CSV as a dataset. A first row that fails to parse as
/// numbers is treated as a header and skipped.
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Parse CSV text into a dataset.
pub fn parse_csv(text: &str) -> Result<Dataset> {
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut width: Option<usize> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let sep = detect_sep(line);
        let cells: Vec<&str> = line.split(sep).map(str::trim).collect();
        let parsed: std::result::Result<Vec<f32>, _> =
            cells.iter().map(|c| c.parse::<f32>()).collect();
        match parsed {
            Ok(vals) => {
                if let Some(w) = width {
                    if vals.len() != w {
                        bail!(
                            "line {}: ragged row ({} fields, expected {w})",
                            lineno + 1,
                            vals.len()
                        );
                    }
                } else {
                    width = Some(vals.len());
                }
                rows.push(vals);
            }
            Err(_) if rows.is_empty() && width.is_none() => {
                // header row — skip
                continue;
            }
            Err(e) => bail!("line {}: non-numeric cell ({e})", lineno + 1),
        }
    }
    let d = width.context("empty CSV")?;
    if d == 0 {
        bail!("zero-width CSV");
    }
    let n = rows.len();
    let mut data = Vec::with_capacity(n * d);
    for r in rows {
        data.extend_from_slice(&r);
    }
    Ok(Dataset::new(n, d, data))
}

fn detect_sep(line: &str) -> char {
    for sep in [',', ';', '\t'] {
        if line.contains(sep) {
            return sep;
        }
    }
    ',' // single column
}

/// Write a dataset as plain comma-separated values (no header).
pub fn write_csv(path: &Path, ds: &Dataset) -> Result<()> {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(ds.n * ds.d * 8);
    for i in 0..ds.n {
        for (j, v) in ds.row(i).iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(s, "{v}");
        }
        s.push('\n');
    }
    std::fs::write(path, s).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_csv() {
        let ds = parse_csv("1.0,2.0\n3.5,-4\n").unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert_eq!(ds.row(1), &[3.5, -4.0]);
    }

    #[test]
    fn skips_header_and_comments() {
        let ds = parse_csv("x,y,z\n# comment\n1,2,3\n4,5,6\n").unwrap();
        assert_eq!((ds.n, ds.d), (2, 3));
    }

    #[test]
    fn handles_semicolon_and_tab() {
        assert_eq!(parse_csv("1;2;3\n").unwrap().d, 3);
        assert_eq!(parse_csv("1\t2\n").unwrap().d, 2);
        assert_eq!(parse_csv("7\n8\n").unwrap(), Dataset::new(2, 1, vec![7.0, 8.0]));
    }

    #[test]
    fn rejects_ragged_and_garbage() {
        assert!(parse_csv("1,2\n3\n").is_err());
        assert!(parse_csv("1,2\n3,abc\n").is_err());
        assert!(parse_csv("").is_err());
        assert!(parse_csv("only,header,row\n").is_err(), "header but no data");
    }

    #[test]
    fn roundtrip() {
        let ds = Dataset::new(3, 2, vec![1.5, -2.0, 0.0, 4.25, 1e6, -1e-3]);
        let p = std::env::temp_dir().join("demst_csv_roundtrip.csv");
        write_csv(&p, &ds).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(ds, back);
    }
}
