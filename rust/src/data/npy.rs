//! Minimal NumPy `.npy` (format version 1.0) reader/writer for f32 matrices.
//!
//! Lets users bring real embedding matrices exported from Python
//! (`np.save("emb.npy", X.astype(np.float32))`) into the CLI, and lets the
//! examples persist datasets. Only little-endian f32, C-order, 1-D or 2-D.

use super::Dataset;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Write a dataset as a 2-D f32 `.npy` file.
pub fn write_npy(path: &Path, ds: &Dataset) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let header_body = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({}, {}), }}",
        ds.n, ds.d
    );
    // Pad with spaces so magic(6)+ver(2)+len(2)+header is a multiple of 64,
    // ending in \n, per the format spec.
    let base = 6 + 2 + 2;
    let unpadded = base + header_body.len() + 1;
    let padded = (unpadded + 63) / 64 * 64;
    let pad = padded - base - header_body.len() - 1;
    let header = format!("{}{}\n", header_body, " ".repeat(pad));
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(ds.n * ds.d * 4);
    for &v in ds.as_slice() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read a 1-D or 2-D little-endian f32 `.npy` file (1-D becomes `(n, 1)`).
pub fn read_npy(path: &Path) -> Result<Dataset> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a .npy file (bad magic)", path.display());
    }
    let mut ver = [0u8; 2];
    f.read_exact(&mut ver)?;
    let header_len = match ver[0] {
        1 => {
            let mut l = [0u8; 2];
            f.read_exact(&mut l)?;
            u16::from_le_bytes(l) as usize
        }
        2 | 3 => {
            let mut l = [0u8; 4];
            f.read_exact(&mut l)?;
            u32::from_le_bytes(l) as usize
        }
        v => bail!("unsupported .npy version {v}"),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header not utf-8")?;
    let descr = dict_str_value(&header, "descr").ok_or_else(|| anyhow!("no descr in header"))?;
    if descr != "<f4" {
        bail!("unsupported dtype {descr:?} (only little-endian f32 '<f4')");
    }
    if header.contains("'fortran_order': True") {
        bail!("fortran_order arrays unsupported (save with C order)");
    }
    let shape = parse_shape(&header)?;
    let (n, d) = match shape.len() {
        1 => (shape[0], 1),
        2 => (shape[0], shape[1]),
        k => bail!("only 1-D/2-D arrays supported, got {k}-D"),
    };
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() < n * d * 4 {
        bail!("truncated .npy: need {} bytes, have {}", n * d * 4, raw.len());
    }
    let data: Vec<f32> = raw[..n * d * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Dataset::new(n, d, data))
}

/// Extract `'key': 'value'` from the header dict (string values only).
fn dict_str_value<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = header[at..].trim_start();
    let quote = rest.chars().next()?;
    if quote != '\'' && quote != '"' {
        return None;
    }
    let inner = &rest[1..];
    let end = inner.find(quote)?;
    Some(&inner[..end])
}

/// Parse `'shape': (a, b)` from the header dict.
fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let at = header.find("'shape':").ok_or_else(|| anyhow!("no shape in header"))? + 8;
    let rest = header[at..].trim_start();
    if !rest.starts_with('(') {
        bail!("malformed shape");
    }
    let end = rest.find(')').ok_or_else(|| anyhow!("unterminated shape tuple"))?;
    let inner = &rest[1..end];
    let mut dims = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        dims.push(p.parse::<usize>().with_context(|| format!("bad dim {p:?}"))?);
    }
    if dims.is_empty() {
        bail!("scalar .npy unsupported");
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("demst_npy_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::seeded(4);
        let ds = Dataset::new(17, 5, (0..17 * 5).map(|_| rng.next_f32()).collect());
        let p = tmp("roundtrip.npy");
        write_npy(&p, &ds).unwrap();
        let back = read_npy(&p).unwrap();
        assert_eq!(ds, back);
    }

    #[test]
    fn header_is_64_aligned() {
        let ds = Dataset::zeros(3, 3);
        let p = tmp("aligned.npy");
        write_npy(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
        assert_eq!(bytes[10 + header_len - 1], b'\n');
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(read_npy(&p).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        // hand-craft an f64 header
        let p = tmp("f64.npy");
        let body = "{'descr': '<f8', 'fortran_order': False, 'shape': (1, 1), }";
        let header = format!("{}\n", body);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&0f64.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = read_npy(&p).unwrap_err().to_string();
        assert!(err.contains("unsupported dtype"), "{err}");
    }

    #[test]
    fn reads_1d_as_column() {
        let p = tmp("onedim.npy");
        let body = "{'descr': '<f4', 'fortran_order': False, 'shape': (3,), }";
        let header = format!("{}\n", body);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for v in [1.0f32, 2.0, 3.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let ds = read_npy(&p).unwrap();
        assert_eq!((ds.n, ds.d), (3, 1));
        assert_eq!(ds.as_slice(), &[1.0, 2.0, 3.0]);
    }
}
