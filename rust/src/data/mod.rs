//! Datasets: row-major f32 point sets, synthetic generators, and `.npy` IO.

pub mod dataset;
pub mod generators;
pub mod npy;
pub mod csv;

pub use dataset::Dataset;
