//! Synthetic dataset generators.
//!
//! The paper's motivating workload is "high dimensional embeddings produced by
//! neural networks". Real embeddings aren't shippable here, so
//! `embedding_like` synthesizes the relevant structure: points drawn from a
//! Gaussian mixture in a low-dimensional latent space, embedded into D
//! dimensions through a random orthogonal-ish rotation, plus small ambient
//! noise — i.e. cluster structure on a low intrinsic-dimension manifold inside
//! a high-dimensional space, which is what makes single-linkage on embeddings
//! meaningful and what defeats low-dimensional (k-d tree / WSPD) EMST methods.

use super::Dataset;
use crate::util::prng::Pcg64;

/// Parameters for isotropic Gaussian blobs.
#[derive(Clone, Debug)]
pub struct BlobSpec {
    pub n: usize,
    pub d: usize,
    /// number of clusters
    pub k: usize,
    /// per-cluster standard deviation
    pub std: f32,
    /// scale of the box cluster centers are drawn from
    pub spread: f32,
}

/// Isotropic Gaussian blobs around `k` uniform-random centers.
/// Returns the dataset; ground-truth labels via [`gaussian_blobs_labeled`].
pub fn gaussian_blobs(spec: &BlobSpec, rng: Pcg64) -> Dataset {
    gaussian_blobs_labeled(spec, rng).0
}

/// Blobs + ground-truth cluster labels (for cluster-recovery checks).
pub fn gaussian_blobs_labeled(spec: &BlobSpec, mut rng: Pcg64) -> (Dataset, Vec<u32>) {
    assert!(spec.k >= 1 && spec.n >= spec.k);
    let centers: Vec<f32> =
        (0..spec.k * spec.d).map(|_| (rng.next_f32() - 0.5) * 2.0 * spec.spread).collect();
    let mut data = Vec::with_capacity(spec.n * spec.d);
    let mut labels = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let c = i % spec.k; // balanced assignment
        labels.push(c as u32);
        for j in 0..spec.d {
            data.push(centers[c * spec.d + j] + spec.std * rng.next_gaussian() as f32);
        }
    }
    (Dataset::new(spec.n, spec.d, data), labels)
}

/// Uniform points in `[-scale, scale)^d` — the unstructured worst case.
pub fn uniform(n: usize, d: usize, scale: f32, mut rng: Pcg64) -> Dataset {
    let data = (0..n * d).map(|_| (rng.next_f32() - 0.5) * 2.0 * scale).collect();
    Dataset::new(n, d, data)
}

/// Parameters for the neural-embedding-like generator.
#[derive(Clone, Debug)]
pub struct EmbeddingSpec {
    pub n: usize,
    /// ambient (embedding) dimension, e.g. 256 or 768
    pub d: usize,
    /// latent (intrinsic) dimension, e.g. 8
    pub latent: usize,
    /// number of semantic clusters
    pub k: usize,
    /// latent per-cluster std
    pub cluster_std: f32,
    /// ambient isotropic noise std
    pub noise: f32,
}

impl Default for EmbeddingSpec {
    fn default() -> Self {
        Self { n: 1024, d: 256, latent: 8, k: 16, cluster_std: 0.3, noise: 0.02 }
    }
}

/// Synthetic "neural embedding" point cloud: Gaussian mixture in a
/// `latent`-dim space, pushed through a random rotation-like map into `d`
/// dims (rows of a random Gaussian matrix, orthonormalized by modified
/// Gram–Schmidt), plus ambient noise.
pub fn embedding_like(spec: &EmbeddingSpec, mut rng: Pcg64) -> (Dataset, Vec<u32>) {
    assert!(spec.latent <= spec.d, "latent {} > ambient {}", spec.latent, spec.d);
    assert!(spec.k >= 1 && spec.n >= spec.k);
    // Random semi-orthogonal map latent -> d (columns orthonormal).
    let basis = random_semi_orthogonal(spec.d, spec.latent, &mut rng);
    // Latent cluster centers on a sphere of radius ~4*cluster_std*sqrt(latent)
    // so clusters are well separated but not trivially so.
    let radius = 4.0 * spec.cluster_std * (spec.latent as f32).sqrt();
    let mut centers = vec![0.0f32; spec.k * spec.latent];
    for c in 0..spec.k {
        let mut norm = 0.0f32;
        for j in 0..spec.latent {
            let g = rng.next_gaussian() as f32;
            centers[c * spec.latent + j] = g;
            norm += g * g;
        }
        let norm = norm.sqrt().max(1e-6);
        for j in 0..spec.latent {
            centers[c * spec.latent + j] *= radius / norm;
        }
    }
    let mut data = vec![0.0f32; spec.n * spec.d];
    let mut labels = Vec::with_capacity(spec.n);
    let mut latent_pt = vec![0.0f32; spec.latent];
    for i in 0..spec.n {
        let c = i % spec.k;
        labels.push(c as u32);
        for j in 0..spec.latent {
            latent_pt[j] =
                centers[c * spec.latent + j] + spec.cluster_std * rng.next_gaussian() as f32;
        }
        let row = &mut data[i * spec.d..(i + 1) * spec.d];
        // row = basis * latent_pt  (basis is d x latent, column-major by construction)
        for (j, r) in row.iter_mut().enumerate() {
            let mut s = 0.0f32;
            for (l, &lp) in latent_pt.iter().enumerate() {
                s += basis[l * spec.d + j] * lp;
            }
            *r = s + spec.noise * rng.next_gaussian() as f32;
        }
    }
    (Dataset::new(spec.n, spec.d, data), labels)
}

/// `cols` orthonormal vectors in R^`rows` (stored row-per-vector: shape
/// `(cols, rows)` row-major), via Gaussian init + modified Gram–Schmidt.
fn random_semi_orthogonal(rows: usize, cols: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut m: Vec<f32> = (0..cols * rows).map(|_| rng.next_gaussian() as f32).collect();
    for c in 0..cols {
        // subtract projections onto previous vectors
        for p in 0..c {
            let (head, tail) = m.split_at_mut(c * rows);
            let prev = &head[p * rows..(p + 1) * rows];
            let cur = &mut tail[..rows];
            let dot: f32 = prev.iter().zip(cur.iter()).map(|(a, b)| a * b).sum();
            for (cu, pr) in cur.iter_mut().zip(prev) {
                *cu -= dot * pr;
            }
        }
        let cur = &mut m[c * rows..(c + 1) * rows];
        let norm: f32 = cur.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for x in cur.iter_mut() {
            *x /= norm;
        }
    }
    m
}

/// Two concentric d-dimensional shells ("moons-in-D"): a non-convex shape
/// single linkage separates but k-means-style methods cannot. Used in the
/// dendrogram example.
pub fn concentric_shells(n: usize, d: usize, r_inner: f32, r_outer: f32, noise: f32, mut rng: Pcg64) -> (Dataset, Vec<u32>) {
    assert!(d >= 2 && n >= 2);
    let mut data = vec![0.0f32; n * d];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let shell = (i % 2) as u32;
        labels.push(shell);
        let r = if shell == 0 { r_inner } else { r_outer };
        // random direction on the sphere
        let row = &mut data[i * d..(i + 1) * d];
        let mut norm = 0.0f32;
        for x in row.iter_mut() {
            let g = rng.next_gaussian() as f32;
            *x = g;
            norm += g * g;
        }
        let norm = norm.sqrt().max(1e-9);
        for x in row.iter_mut() {
            *x = *x / norm * r + noise * rng.next_gaussian() as f32;
        }
    }
    (Dataset::new(n, d, data), labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::metric::sq_euclid;

    #[test]
    fn blobs_shape_and_labels() {
        let spec = BlobSpec { n: 100, d: 8, k: 5, std: 0.1, spread: 10.0 };
        let (ds, labels) = gaussian_blobs_labeled(&spec, Pcg64::seeded(1));
        assert_eq!(ds.n, 100);
        assert_eq!(ds.d, 8);
        assert_eq!(labels.len(), 100);
        assert_eq!(*labels.iter().max().unwrap(), 4);
        // balanced: each cluster has 20
        for c in 0..5u32 {
            assert_eq!(labels.iter().filter(|&&l| l == c).count(), 20);
        }
    }

    #[test]
    fn blobs_are_deterministic() {
        let spec = BlobSpec { n: 32, d: 4, k: 2, std: 0.5, spread: 3.0 };
        let a = gaussian_blobs(&spec, Pcg64::seeded(7));
        let b = gaussian_blobs(&spec, Pcg64::seeded(7));
        assert_eq!(a, b);
    }

    #[test]
    fn blobs_intra_closer_than_inter() {
        // With tight std and wide spread, same-cluster pairs should be far
        // closer than cross-cluster pairs on average.
        let spec = BlobSpec { n: 60, d: 16, k: 3, std: 0.05, spread: 20.0 };
        let (ds, labels) = gaussian_blobs_labeled(&spec, Pcg64::seeded(3));
        let (mut intra, mut inter, mut ni, mut nx) = (0.0f64, 0.0f64, 0u64, 0u64);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                let dist = sq_euclid(ds.row(i), ds.row(j)) as f64;
                if labels[i] == labels[j] {
                    intra += dist;
                    ni += 1;
                } else {
                    inter += dist;
                    nx += 1;
                }
            }
        }
        assert!(intra / ni as f64 * 10.0 < inter / nx as f64);
    }

    #[test]
    fn semi_orthogonal_is_orthonormal() {
        let mut rng = Pcg64::seeded(5);
        let (rows, cols) = (32, 6);
        let m = random_semi_orthogonal(rows, cols, &mut rng);
        for a in 0..cols {
            for b in a..cols {
                let dot: f32 = (0..rows).map(|r| m[a * rows + r] * m[b * rows + r]).sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn embedding_preserves_latent_distances() {
        // With zero ambient noise, pairwise distances in ambient space must
        // equal latent distances (semi-orthogonal map is an isometry on the
        // latent subspace).
        let spec = EmbeddingSpec { n: 40, d: 64, latent: 4, k: 4, cluster_std: 0.5, noise: 0.0 };
        let (ds, _) = embedding_like(&spec, Pcg64::seeded(9));
        // All points lie in a 4-dim subspace: distances must behave; sanity
        // check that the data is not degenerate and is deterministic.
        let (ds2, _) = embedding_like(&spec, Pcg64::seeded(9));
        assert_eq!(ds, ds2);
        let d01 = sq_euclid(ds.row(0), ds.row(1));
        assert!(d01 > 0.0);
    }

    #[test]
    fn shells_radii() {
        let (ds, labels) = concentric_shells(64, 8, 1.0, 5.0, 0.0, Pcg64::seeded(2));
        for i in 0..ds.n {
            let r: f32 = ds.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            let expect = if labels[i] == 0 { 1.0 } else { 5.0 };
            assert!((r - expect).abs() < 1e-3, "i={i} r={r} expect={expect}");
        }
    }
}
