//! Row-major f32 point sets: the vector-per-vertex representation the
//! paper's graph `G = (V, E)` is built over.

/// `n` points in `d` dimensions, row-major contiguous.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    data: Vec<f32>,
}

impl Dataset {
    pub fn new(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "data length {} != n*d = {}", data.len(), n * d);
        Self { n, d, data }
    }

    pub fn zeros(n: usize, d: usize) -> Self {
        Self { n, d, data: vec![0.0; n * d] }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.n);
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.n);
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Gather rows by index into a new dataset. Used to materialize the
    /// partition subsets `S_i` (and `S_i ∪ S_j` unions) that are shipped to
    /// workers — this models the scatter of vectors in the distributed
    /// setting, so its size is what the netsim charges for.
    pub fn gather(&self, idx: &[u32]) -> Dataset {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            data.extend_from_slice(self.row(i as usize));
        }
        Dataset::new(idx.len(), self.d, data)
    }

    /// Bytes occupied by the raw vector payload (netsim accounting).
    pub fn payload_bytes(&self) -> u64 {
        (self.n * self.d * std::mem::size_of::<f32>()) as u64
    }

    /// Per-coordinate mean (for centering / reporting).
    pub fn mean(&self) -> Vec<f32> {
        let mut m = vec![0.0f64; self.d];
        for i in 0..self.n {
            for (j, &x) in self.row(i).iter().enumerate() {
                m[j] += x as f64;
            }
        }
        m.iter().map(|&s| (s / self.n.max(1) as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_index_correctly() {
        let ds = Dataset::new(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ds.row(0), &[0.0, 1.0]);
        assert_eq!(ds.row(2), &[4.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn gather_selects_rows() {
        let ds = Dataset::new(4, 2, (0..8).map(|i| i as f32).collect());
        let g = ds.gather(&[3, 1]);
        assert_eq!(g.n, 2);
        assert_eq!(g.row(0), &[6.0, 7.0]);
        assert_eq!(g.row(1), &[2.0, 3.0]);
    }

    #[test]
    fn payload_bytes_counts_f32() {
        let ds = Dataset::zeros(10, 7);
        assert_eq!(ds.payload_bytes(), 10 * 7 * 4);
    }

    #[test]
    fn mean_is_columnwise() {
        let ds = Dataset::new(2, 2, vec![0.0, 4.0, 2.0, 8.0]);
        assert_eq!(ds.mean(), vec![1.0, 6.0]);
    }
}
