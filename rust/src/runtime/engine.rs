//! The PJRT engine: one CPU client + a cache of compiled executables keyed
//! by artifact. One engine per worker thread (PJRT handles are raw pointers,
//! deliberately thread-local — see `crate::coordinator`).

use super::manifest::{Artifact, Manifest};
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// PJRT client + compiled-executable cache over an artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<(String, usize, usize), Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True iff an artifact directory looks usable (manifest present).
    pub fn artifacts_available(artifacts_dir: &Path) -> bool {
        artifacts_dir.join("manifest.txt").is_file()
    }

    /// Smallest bucket fitting `(n, d)` for `kernel`, or an error listing
    /// what's available.
    pub fn bucket_for(&self, kernel: &str, n: usize, d: usize) -> Result<Artifact> {
        self.manifest.find_bucket(kernel, n, d).cloned().ok_or_else(|| {
            let have: Vec<String> = self
                .manifest
                .artifacts
                .iter()
                .filter(|a| a.kernel == kernel)
                .map(|a| format!("({},{})", a.n, a.d))
                .collect();
            anyhow!(
                "no artifact bucket fits kernel={kernel} n={n} d={d}; available: [{}] — \
                 regenerate with `make artifacts` after extending python/compile/shapes.py",
                have.join(", ")
            )
        })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&self, a: &Artifact) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (a.kernel.clone(), a.n, a.d);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(Rc::clone(exe));
        }
        let path = self.manifest.path_of(a);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute a compiled artifact with literal inputs; returns the
    /// (possibly tuple) output literal.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing artifact: {e:?}"))?;
        result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result literal: {e:?}"))
            .context("device-to-host transfer")
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}
