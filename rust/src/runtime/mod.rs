//! Runtime layer: the pluggable [`ComputeBackend`] plus (behind the
//! `backend-xla` feature) the PJRT engine that loads the AOT-compiled HLO
//! artifacts produced by `python/compile/aot.py` and executes them from the
//! Rust hot path.
//!
//! Always compiled:
//! - [`backend`] — backend selection, kernel resolution with graceful
//!   fallback, artifact-directory probing.
//! - [`manifest`] — the artifact manifest format (pure text parsing; no
//!   PJRT dependency), so `demst info` and preflight checks work in every
//!   build.
//!
//! Only with `--features backend-xla`:
//! - [`engine`] / [`cheapest_edge`] / [`pairwise`] — the PJRT CPU client,
//!   executable cache, and the kernel executors.
//!
//! Interchange format is **HLO text** (`HloModuleProto::from_text_file`),
//! not serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Artifacts are compiled for fixed `(N, D)` shape buckets; inputs are
//! padded (rows: `comp = -1` masked inside the kernel; feature dims: zeros,
//! distance-preserving) up to the smallest fitting bucket, and compiled
//! executables are cached per bucket for the life of the engine.

pub mod backend;
pub mod manifest;

#[cfg(feature = "backend-xla")]
pub mod engine;

#[cfg(feature = "backend-xla")]
pub mod cheapest_edge;

#[cfg(feature = "backend-xla")]
pub mod pairwise;

pub use backend::{
    artifacts_available, backend_xla_compiled, build_dense_kernel, exec_kernel_label,
    kernel_fallback_note, resolved_kernel_name, xla_panel_dir, BackendKind, ComputeBackend,
    RustBackend,
};
pub use manifest::{Artifact, Manifest};

#[cfg(feature = "backend-xla")]
pub use backend::XlaBackend;
#[cfg(feature = "backend-xla")]
pub use cheapest_edge::XlaStep;
#[cfg(feature = "backend-xla")]
pub use engine::Engine;
#[cfg(feature = "backend-xla")]
pub use pairwise::XlaPairwise;
