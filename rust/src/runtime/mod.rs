//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange format is **HLO text** (`HloModuleProto::from_text_file`),
//! not serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Artifacts are compiled for fixed `(N, D)` shape buckets; inputs are
//! padded (rows: `comp = -1` masked inside the kernel; feature dims: zeros,
//! distance-preserving) up to the smallest fitting bucket, and compiled
//! executables are cached per bucket for the life of the engine.

pub mod manifest;
pub mod engine;
pub mod cheapest_edge;
pub mod pairwise;

pub use cheapest_edge::XlaStep;
pub use engine::Engine;
pub use manifest::{Artifact, Manifest};
pub use pairwise::XlaPairwise;
