//! The XLA-backed cheapest-edge step: pads inputs into the artifact's shape
//! bucket, executes the AOT-compiled Pallas kernel, and unpads the result.

use super::engine::Engine;
use crate::dense::step::CheapestEdgeStep;
use anyhow::{anyhow, Result};

pub const KERNEL_NAME: &str = "cheapest_edge";

/// [`CheapestEdgeStep`] provider backed by the AOT Pallas/XLA kernel.
pub struct XlaStep {
    engine: Engine,
}

impl XlaStep {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn step_impl(
        &self,
        points: &[f32],
        n: usize,
        d: usize,
        comps: &[i32],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let bucket = self.engine.bucket_for(KERNEL_NAME, n, d)?;
        let (bn, bd) = (bucket.n, bucket.d);
        // Pad rows with zeros (masked out via comp = -1) and feature dims
        // with zeros (adds 0 to every squared distance).
        let mut pts = vec![0.0f32; bn * bd];
        for i in 0..n {
            pts[i * bd..i * bd + d].copy_from_slice(&points[i * d..(i + 1) * d]);
        }
        let mut cs = vec![-1i32; bn];
        cs[..n].copy_from_slice(comps);

        let exe = self.engine.executable(&bucket)?;
        let x = xla::Literal::vec1(&pts)
            .reshape(&[bn as i64, bd as i64])
            .map_err(|e| anyhow!("reshaping points literal: {e:?}"))?;
        let c = xla::Literal::vec1(&cs);
        let out = self.engine.run(&exe, &[x, c])?;
        let (dist_l, idx_l) =
            out.to_tuple2().map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;
        let mut dist = dist_l.to_vec::<f32>().map_err(|e| anyhow!("dist to_vec: {e:?}"))?;
        let mut idx = idx_l.to_vec::<i32>().map_err(|e| anyhow!("idx to_vec: {e:?}"))?;
        dist.truncate(n);
        idx.truncate(n);
        // Sanity: padded rows can never be selected as neighbors.
        debug_assert!(idx.iter().all(|&j| j < n as i32));
        Ok((dist, idx))
    }
}

impl CheapestEdgeStep for XlaStep {
    fn step(&self, points: &[f32], n: usize, d: usize, comps: &[i32]) -> (Vec<f32>, Vec<i32>) {
        self.step_impl(points, n, d, comps)
            .expect("XLA cheapest-edge execution failed (rebuild artifacts with `make artifacts`)")
    }

    fn name(&self) -> &'static str {
        "pallas-xla"
    }

    /// The kernel computes the full padded `N²` matrix — charge the bucket,
    /// not the logical size (honest hardware work for E2/E7).
    fn evals_per_call(&self, valid_n: u64) -> u64 {
        match self.engine.manifest().find_bucket(KERNEL_NAME, valid_n as usize, 1) {
            Some(a) => (a.n * a.n) as u64,
            None => valid_n * valid_n,
        }
    }
}
