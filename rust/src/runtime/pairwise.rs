//! The XLA-backed pairwise-distance block: full `(N, N)` squared-Euclidean
//! matrix for a padded point set. Used by the E7 kernel bench and as a
//! cross-check of the Rust blocked routines against the Pallas kernel.

use super::engine::Engine;
use anyhow::{anyhow, Result};

pub const KERNEL_NAME: &str = "pairwise";

/// Executor for the AOT pairwise-distance kernel.
pub struct XlaPairwise {
    engine: Engine,
}

impl XlaPairwise {
    pub fn new(engine: Engine) -> Self {
        Self { engine }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Full `(n, n)` squared-Euclidean distance matrix (row-major), computed
    /// by the AOT kernel in the smallest fitting `(N, D)` bucket.
    ///
    /// Padding note: padded rows are zero vectors, so their distances are
    /// meaningless but sliced away before returning.
    pub fn matrix(&self, points: &[f32], n: usize, d: usize) -> Result<Vec<f32>> {
        assert_eq!(points.len(), n * d);
        let bucket = self.engine.bucket_for(KERNEL_NAME, n, d)?;
        let (bn, bd) = (bucket.n, bucket.d);
        let mut pts = vec![0.0f32; bn * bd];
        for i in 0..n {
            pts[i * bd..i * bd + d].copy_from_slice(&points[i * d..(i + 1) * d]);
        }
        let exe = self.engine.executable(&bucket)?;
        let x = xla::Literal::vec1(&pts)
            .reshape(&[bn as i64, bd as i64])
            .map_err(|e| anyhow!("reshaping points literal: {e:?}"))?;
        let out = self.engine.run(&exe, &[x])?;
        let full = out
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("matrix to_vec: {e:?}"))?;
        // slice the (n, n) top-left block out of the (bn, bn) padded matrix
        let mut m = Vec::with_capacity(n * n);
        for i in 0..n {
            m.extend_from_slice(&full[i * bn..i * bn + n]);
        }
        Ok(m)
    }

    /// `(m, n)` squared-Euclidean **bipartite** block between two packed
    /// panels (`d` real values per row at `stride_a`/`stride_b`): stacks
    /// the `m + n` rows into one point set, runs the AOT self-matrix
    /// kernel, and slices out the off-diagonal block. The bipartite hook
    /// behind the pair kernel's panel path in `backend-xla` builds.
    #[allow(clippy::too_many_arguments)]
    pub fn bipartite_block(
        &self,
        a: &[f32],
        m: usize,
        stride_a: usize,
        b: &[f32],
        n: usize,
        stride_b: usize,
        d: usize,
    ) -> Result<Vec<f32>> {
        debug_assert!(a.len() >= m * stride_a && stride_a >= d);
        debug_assert!(b.len() >= n * stride_b && stride_b >= d);
        let mut pts = vec![0.0f32; (m + n) * d];
        for i in 0..m {
            pts[i * d..(i + 1) * d].copy_from_slice(&a[i * stride_a..i * stride_a + d]);
        }
        for j in 0..n {
            pts[(m + j) * d..(m + j + 1) * d]
                .copy_from_slice(&b[j * stride_b..j * stride_b + d]);
        }
        let full = self.matrix(&pts, m + n, d)?;
        let w = m + n;
        let mut blk = Vec::with_capacity(m * n);
        for i in 0..m {
            blk.extend_from_slice(&full[i * w + m..i * w + m + n]);
        }
        Ok(blk)
    }
}
