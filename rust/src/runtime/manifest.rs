//! The artifact manifest: which HLO files exist for which kernel and shape
//! bucket. Written by `aot.py` as a line-based text file (one artifact per
//! line: `kernel N D filename`), deliberately trivial to parse in both
//! languages.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    pub kernel: String,
    /// row-capacity of the bucket
    pub n: usize,
    /// feature-dim capacity of the bucket
    pub d: usize,
    pub file: String,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text. Lines: `kernel N D filename`; `#` comments.
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 4 {
                bail!("manifest line {}: expected `kernel N D file`, got {raw:?}", lineno + 1);
            }
            artifacts.push(Artifact {
                kernel: fields[0].to_string(),
                n: fields[1].parse().with_context(|| format!("line {}: bad N", lineno + 1))?,
                d: fields[2].parse().with_context(|| format!("line {}: bad D", lineno + 1))?,
                file: fields[3].to_string(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest in {} lists no artifacts", dir.display());
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// Smallest bucket that fits `(n, d)` for `kernel`: minimize `N`, then
    /// `D`, subject to `N >= n && D >= d`.
    pub fn find_bucket(&self, kernel: &str, n: usize, d: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.kernel == kernel && a.n >= n && a.d >= d)
            .min_by_key(|a| (a.n, a.d))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// All distinct kernels in the manifest.
    pub fn kernels(&self) -> Vec<&str> {
        let mut ks: Vec<&str> = self.artifacts.iter().map(|a| a.kernel.as_str()).collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# kernel N D file
cheapest_edge 64 8 ce_n64_d8.hlo.txt
cheapest_edge 64 32 ce_n64_d32.hlo.txt
cheapest_edge 256 8 ce_n256_d8.hlo.txt
cheapest_edge 256 32 ce_n256_d32.hlo.txt
pairwise 64 8 pw_n64_d8.hlo.txt
";

    fn sample() -> Manifest {
        Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap()
    }

    #[test]
    fn parse_and_kernels() {
        let m = sample();
        assert_eq!(m.artifacts.len(), 5);
        assert_eq!(m.kernels(), vec!["cheapest_edge", "pairwise"]);
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        let m = sample();
        let a = m.find_bucket("cheapest_edge", 50, 8).unwrap();
        assert_eq!((a.n, a.d), (64, 8));
        let a = m.find_bucket("cheapest_edge", 64, 9).unwrap();
        assert_eq!((a.n, a.d), (64, 32));
        let a = m.find_bucket("cheapest_edge", 65, 4).unwrap();
        assert_eq!((a.n, a.d), (256, 8));
        assert!(m.find_bucket("cheapest_edge", 257, 8).is_none());
        assert!(m.find_bucket("nonexistent", 1, 1).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/x"), "cheapest_edge 64 8").is_err());
        assert!(Manifest::parse(Path::new("/x"), "k sixty 8 f").is_err());
        assert!(Manifest::parse(Path::new("/x"), "# only comments\n").is_err());
    }

    #[test]
    fn path_join() {
        let m = sample();
        assert_eq!(
            m.path_of(&m.artifacts[0]),
            PathBuf::from("/tmp/a/ce_n64_d8.hlo.txt")
        );
    }
}
