//! The pluggable compute backend: where d-MST kernels get their distance
//! engines from.
//!
//! A [`ComputeBackend`] is a factory for the hot-path primitives — the
//! Borůvka cheapest-edge step provider and full pairwise blocks — so the
//! coordinator, CLI, and benches select *what computes distances* separately
//! from *which MST algorithm runs*. Two backends exist:
//!
//! - [`RustBackend`] — the metric-generic blocked kernels
//!   ([`crate::geometry::DistanceBlock`]); always available, any metric.
//! - `XlaBackend` — the AOT-compiled Pallas kernels through PJRT; only
//!   compiled with `--features backend-xla`, squared-Euclidean only, and
//!   only usable when an artifact directory is present.
//!
//! Kernel resolution ([`build_dense_kernel`]) is where graceful degradation
//! lives: a config requesting `boruvka-xla` in a build without the feature
//! falls back to the blocked Rust provider and reports why (the
//! `kernel_fallback` field in [`crate::coordinator::RunMetrics`]); in a
//! build *with* the feature, a missing/unusable artifact directory stays a
//! hard error — the operator explicitly asked for that engine.

use crate::config::{KernelChoice, RunConfig};
use crate::dense::step::CheapestEdgeStep;
use crate::dense::{BoruvkaDense, DenseMst, PrimDense, RustStep};
use crate::geometry::blocked::distance_block;
use crate::geometry::MetricKind;
use anyhow::Result;
use std::path::Path;
#[cfg(feature = "backend-xla")]
use std::sync::Arc;

/// Which backend family an implementation belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust blocked kernels (always available).
    Rust,
    /// PJRT-executed AOT artifacts (`backend-xla` feature).
    Xla,
}

/// A factory for distance-compute primitives.
pub trait ComputeBackend {
    /// Short name for reporting ("rust-blocked", "pjrt-xla").
    fn name(&self) -> &'static str;

    fn kind(&self) -> BackendKind;

    /// Build a cheapest-edge step provider for `metric`. Errors when the
    /// backend cannot serve the metric or its runtime is unavailable.
    fn cheapest_edge_step(&self, metric: MetricKind) -> Result<Box<dyn CheapestEdgeStep>>;

    /// Full `(n, n)` distance matrix under `metric` (benches/cross-checks).
    fn pairwise_matrix(
        &self,
        points: &[f32],
        n: usize,
        d: usize,
        metric: MetricKind,
    ) -> Result<Vec<f32>>;
}

/// The always-available pure-Rust blocked backend.
pub struct RustBackend;

impl ComputeBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust-blocked"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Rust
    }

    fn cheapest_edge_step(&self, metric: MetricKind) -> Result<Box<dyn CheapestEdgeStep>> {
        // Euclid compares in squared form; the kernels sqrt at emission.
        Ok(Box::new(RustStep::new(metric.compare_form())))
    }

    fn pairwise_matrix(
        &self,
        points: &[f32],
        n: usize,
        d: usize,
        metric: MetricKind,
    ) -> Result<Vec<f32>> {
        let blk = distance_block(metric);
        let aux = blk.prepare(points, n, d);
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut out = vec![0.0f32; n * n];
        blk.block(points, d, &aux, &ids, &ids, &mut out);
        if blk.compare_form_is_squared() {
            for v in &mut out {
                *v = v.sqrt();
            }
        }
        Ok(out)
    }
}

/// The PJRT backend over an AOT artifact directory.
#[cfg(feature = "backend-xla")]
pub struct XlaBackend {
    pub artifacts_dir: std::path::PathBuf,
}

#[cfg(feature = "backend-xla")]
impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "pjrt-xla"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }

    fn cheapest_edge_step(&self, metric: MetricKind) -> Result<Box<dyn CheapestEdgeStep>> {
        anyhow::ensure!(
            matches!(metric, MetricKind::SqEuclid | MetricKind::Euclid),
            "the XLA kernel computes (squared) Euclidean distances only; got {metric:?}"
        );
        let engine = super::engine::Engine::load(&self.artifacts_dir)?;
        Ok(Box::new(super::cheapest_edge::XlaStep::new(engine)))
    }

    fn pairwise_matrix(
        &self,
        points: &[f32],
        n: usize,
        d: usize,
        metric: MetricKind,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            matches!(metric, MetricKind::SqEuclid | MetricKind::Euclid),
            "the XLA pairwise kernel computes (squared) Euclidean distances only; got {metric:?}"
        );
        let engine = super::engine::Engine::load(&self.artifacts_dir)?;
        let mut m = super::pairwise::XlaPairwise::new(engine).matrix(points, n, d)?;
        if metric == MetricKind::Euclid {
            for v in &mut m {
                *v = v.sqrt();
            }
        }
        Ok(m)
    }
}

/// Whether this build compiled the PJRT/XLA path.
pub const fn backend_xla_compiled() -> bool {
    cfg!(feature = "backend-xla")
}

/// True iff an artifact directory looks usable (manifest present). Works in
/// every build; executing artifacts additionally needs `backend-xla`.
pub fn artifacts_available(artifacts_dir: &Path) -> bool {
    artifacts_dir.join("manifest.txt").is_file()
}

/// The fallback note for a config, if its kernel request cannot be honored
/// by this build **or** by the selected pair kernel. Pure function of
/// (config, compiled features) so the leader can report it without asking
/// workers.
pub fn kernel_fallback_note(cfg: &RunConfig) -> Option<String> {
    if cfg.pair_kernel == crate::config::PairKernelChoice::BipartiteMerge {
        // The bipartite-merge pair kernel runs the blocked Rust local
        // kernels; an explicit XLA request routes its *panel blocks*
        // through the AOT pairwise artifact when this build and filesystem
        // can honor that, and must be reported when they cannot.
        if cfg.kernel == KernelChoice::BoruvkaXla {
            if !backend_xla_compiled() {
                return Some(
                    "pair_kernel bipartite-merge: routing panel blocks through the \
                     boruvka-xla pairwise artifact needs --features backend-xla; panels \
                     run the SIMD/scalar Rust kernels"
                        .to_string(),
                );
            }
            if !matches!(cfg.metric, MetricKind::SqEuclid | MetricKind::Euclid) {
                return Some(format!(
                    "pair_kernel bipartite-merge: the boruvka-xla pairwise artifact \
                     computes (squared) Euclidean only; {} panels run the SIMD/scalar \
                     Rust kernels",
                    cfg.metric.name()
                ));
            }
            if !artifacts_available(&cfg.artifacts_dir) {
                return Some(format!(
                    "pair_kernel bipartite-merge: no artifacts at {}; boruvka-xla panel \
                     routing disabled, panels run the SIMD/scalar Rust kernels",
                    cfg.artifacts_dir.display()
                ));
            }
            return None; // panel blocks route through the XLA artifact
        }
        return None;
    }
    if cfg.kernel == KernelChoice::BoruvkaXla && !backend_xla_compiled() {
        Some(
            "backend-xla not compiled into this build; boruvka-xla fell back to \
             boruvka-rust (rebuild with --features backend-xla to execute artifacts)"
                .to_string(),
        )
    } else {
        None
    }
}

/// The artifact directory the bipartite pair kernel's panel blocks should
/// route through — `Some` only when the config explicitly requests the XLA
/// kernel under `pair_kernel bipartite-merge` AND this build compiled the
/// feature AND the metric is (squared) Euclidean AND the artifact manifest
/// is present. `None` means the SIMD/scalar panel path runs (with the
/// reason, if any, in [`kernel_fallback_note`]).
pub fn xla_panel_dir(cfg: &RunConfig) -> Option<std::path::PathBuf> {
    (cfg.pair_kernel == crate::config::PairKernelChoice::BipartiteMerge
        && cfg.kernel == KernelChoice::BoruvkaXla
        && backend_xla_compiled()
        && matches!(cfg.metric, MetricKind::SqEuclid | MetricKind::Euclid)
        && artifacts_available(&cfg.artifacts_dir))
    .then(|| cfg.artifacts_dir.clone())
}

/// The kernel name workers actually run for this config in this build.
pub fn resolved_kernel_name(cfg: &RunConfig) -> &'static str {
    if cfg.kernel == KernelChoice::BoruvkaXla && !backend_xla_compiled() {
        KernelChoice::BoruvkaRust.name()
    } else {
        cfg.kernel.name()
    }
}

/// The kernel label the exec engine reports in `RunMetrics::kernel`,
/// covering both pair-kernel families: the dense path resolves through the
/// backend (with fallback), the bipartite-merge path always runs the
/// blocked-Prim local/bipartite kernels of the Rust backend.
pub fn exec_kernel_label(cfg: &RunConfig) -> String {
    match cfg.pair_kernel {
        crate::config::PairKernelChoice::Dense => resolved_kernel_name(cfg).to_string(),
        crate::config::PairKernelChoice::BipartiteMerge => {
            format!("bipartite-merge[prim-blocked/{}]", cfg.metric.name())
        }
    }
}

/// Build the d-MST kernel a worker rank runs for this config.
///
/// Called *inside* the worker thread so PJRT handles (not `Send`) stay
/// thread-local, mirroring per-rank process memory. Returns the kernel plus
/// the fallback note (if the requested kernel was unavailable in this
/// build).
pub fn build_dense_kernel(cfg: &RunConfig) -> Result<(Box<dyn DenseMst>, Option<String>)> {
    let fallback = kernel_fallback_note(cfg);
    let kernel: Box<dyn DenseMst> = match cfg.kernel {
        KernelChoice::PrimDense => Box::new(PrimDense::new(cfg.metric)),
        KernelChoice::BoruvkaRust => Box::new(BoruvkaDense::new_rust(cfg.metric)),
        KernelChoice::BoruvkaXla => {
            #[cfg(feature = "backend-xla")]
            {
                let backend = XlaBackend { artifacts_dir: cfg.artifacts_dir.clone() };
                let step = backend.cheapest_edge_step(cfg.metric)?;
                Box::new(BoruvkaDense::new(Arc::from(step), cfg.metric))
            }
            #[cfg(not(feature = "backend-xla"))]
            {
                Box::new(BoruvkaDense::new_rust(cfg.metric))
            }
        }
    };
    Ok((kernel, fallback))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::util::prng::Pcg64;

    #[test]
    fn rust_backend_serves_every_metric() {
        let backend = RustBackend;
        assert_eq!(backend.kind(), BackendKind::Rust);
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let step = backend.cheapest_edge_step(kind).unwrap();
            // Euclid compares in squared form via the SqEuclid provider.
            let expect =
                if kind == MetricKind::Euclid { MetricKind::SqEuclid } else { kind };
            assert_eq!(step.metric(), expect, "{kind:?}");
        }
    }

    #[test]
    fn rust_backend_pairwise_matches_blocked_self() {
        let ds = uniform(20, 6, 1.0, Pcg64::seeded(9));
        let m = RustBackend
            .pairwise_matrix(ds.as_slice(), ds.n, ds.d, MetricKind::SqEuclid)
            .unwrap();
        let want = crate::geometry::blocked::pairwise_self(ds.as_slice(), ds.n, ds.d);
        assert_eq!(m, want);
        let e = RustBackend
            .pairwise_matrix(ds.as_slice(), ds.n, ds.d, MetricKind::Euclid)
            .unwrap();
        for (a, b) in e.iter().zip(&want) {
            assert_eq!(*a, b.sqrt());
        }
    }

    #[test]
    fn fallback_note_only_for_unavailable_xla() {
        let mut cfg = RunConfig::default();
        assert!(kernel_fallback_note(&cfg).is_none());
        assert_eq!(resolved_kernel_name(&cfg), "boruvka-rust");
        cfg.kernel = KernelChoice::BoruvkaXla;
        if backend_xla_compiled() {
            assert!(kernel_fallback_note(&cfg).is_none());
            assert_eq!(resolved_kernel_name(&cfg), "boruvka-xla");
        } else {
            let note = kernel_fallback_note(&cfg).expect("fallback note");
            assert!(note.contains("backend-xla"), "{note}");
            assert_eq!(resolved_kernel_name(&cfg), "boruvka-rust");
        }
    }

    #[test]
    fn exec_kernel_label_covers_both_pair_kernels() {
        let mut cfg = RunConfig::default();
        assert_eq!(exec_kernel_label(&cfg), "boruvka-rust");
        cfg.pair_kernel = crate::config::PairKernelChoice::BipartiteMerge;
        let label = exec_kernel_label(&cfg);
        assert!(label.starts_with("bipartite-merge"), "{label}");
        assert!(label.contains("sqeuclid"), "{label}");
    }

    #[test]
    fn bipartite_merge_notes_ignored_xla_kernel_request() {
        let mut cfg = RunConfig::default();
        cfg.pair_kernel = crate::config::PairKernelChoice::BipartiteMerge;
        assert!(kernel_fallback_note(&cfg).is_none(), "rust kernels: nothing to report");
        cfg.kernel = KernelChoice::BoruvkaXla;
        match kernel_fallback_note(&cfg) {
            Some(note) => {
                assert!(note.contains("bipartite-merge"), "{note}");
                assert!(note.contains("boruvka-xla"), "{note}");
                assert!(xla_panel_dir(&cfg).is_none(), "note and routing are exclusive");
            }
            None => {
                // only possible when the build + filesystem can actually
                // route panel blocks through the artifact
                assert!(backend_xla_compiled() && artifacts_available(&cfg.artifacts_dir));
                assert_eq!(xla_panel_dir(&cfg), Some(cfg.artifacts_dir.clone()));
            }
        }
        // a non-Euclidean metric can never route through the artifact
        cfg.metric = MetricKind::Manhattan;
        assert!(xla_panel_dir(&cfg).is_none());
        if backend_xla_compiled() {
            let note = kernel_fallback_note(&cfg).expect("metric mismatch must be flagged");
            assert!(note.contains("Euclidean"), "{note}");
        }
    }

    #[test]
    fn build_kernel_resolves_all_choices() {
        let ds = uniform(24, 4, 1.0, Pcg64::seeded(10));
        let mut cfg = RunConfig::default();
        for choice in [KernelChoice::PrimDense, KernelChoice::BoruvkaRust] {
            cfg.kernel = choice;
            let (kernel, fallback) = build_dense_kernel(&cfg).unwrap();
            assert!(fallback.is_none());
            let tree = kernel.mst(&ds);
            assert_eq!(tree.len(), ds.n - 1);
        }
        // boruvka-xla without the feature: silently-but-reportedly rust
        #[cfg(not(feature = "backend-xla"))]
        {
            cfg.kernel = KernelChoice::BoruvkaXla;
            let (kernel, fallback) = build_dense_kernel(&cfg).unwrap();
            assert!(fallback.is_some());
            let tree = kernel.mst(&ds);
            assert_eq!(tree.len(), ds.n - 1);
        }
    }

    #[test]
    fn artifacts_available_checks_manifest() {
        assert!(!artifacts_available(Path::new("/definitely/not/here")));
        let dir = std::env::temp_dir().join("demst_backend_tests");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!artifacts_available(&dir));
        std::fs::write(dir.join("manifest.txt"), "cheapest_edge 64 8 f.hlo.txt\n").unwrap();
        assert!(artifacts_available(&dir));
        std::fs::remove_file(dir.join("manifest.txt")).ok();
    }
}
