//! `demst` — launcher CLI for the distributed EMST / single-linkage system.
//!
//! Subcommands:
//!   run         distributed EMST + optional dendrogram on a dataset
//!   worker      remote worker process for a `run --transport tcp` leader
//!   dendrogram  decomposed MST → single-linkage dendrogram → CSV outputs
//!   gen         generate a synthetic dataset to .npy
//!   info        inspect an artifact directory
//!   selftest    quick end-to-end correctness check (all kernels available)
//!
//! Examples:
//!   demst run --data embedding --n 2048 --d 128 --parts 6 --workers 4 --verify
//!   demst run --config examples/configs/embedding.toml --kernel xla
//!   demst run --pair-kernel bipartite --stream-reduce --n 4096 --parts 8
//!   demst run --transport tcp --listen 127.0.0.1:7000 --workers 2 --n 4096
//!   demst worker --connect 127.0.0.1:7000
//!   demst dendrogram --data blobs --n 1000 --d 32 --out-merges merges.csv
//!   demst gen --kind blobs --n 1000 --d 64 --out /tmp/blobs.npy
//!   demst info --artifacts artifacts

use anyhow::{bail, Context, Result};
use demst::cli::{parse_args, Args, OptSpec};
use demst::config::run_config::build_dataset;
use demst::config::{KernelChoice, PairKernelChoice, RunConfig};
use demst::coordinator::{run_distributed, RunMetrics};
use demst::decomp::PartitionStrategy;
use demst::geometry::MetricKind;
use demst::report::Table;
use demst::slink::mst_to_dendrogram;
use demst::util::human_bytes;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match real_main(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn real_main(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "worker" => cmd_worker(rest),
        "dendrogram" => cmd_dendrogram(rest),
        "gen" => cmd_gen(rest),
        "info" => cmd_info(rest),
        "selftest" => cmd_selftest(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `demst help`)"),
    }
}

fn print_help() {
    println!(
        "demst — distributed Euclidean-MST / single-linkage dendrograms via distance decomposition

USAGE: demst <run|worker|dendrogram|gen|info|selftest|help> [options]

run         distributed EMST (+ dendrogram) on a generated or .npy dataset
worker      remote worker process: connect to a `run --transport tcp` leader
dendrogram  decomposed MST -> dendrogram; write merge heights and cluster labels as CSV
gen         write a synthetic dataset to .npy
info        list AOT artifacts and check they compile
selftest    quick correctness check across kernels
"
    );
}

fn run_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", takes_value: true, help: "TOML config file (defaults applied first)" },
        OptSpec { name: "data", takes_value: true, help: "blobs|uniform|embedding|shells|npy" },
        OptSpec { name: "path", takes_value: true, help: ".npy file when --data npy" },
        OptSpec { name: "n", takes_value: true, help: "points" },
        OptSpec { name: "d", takes_value: true, help: "dimensions" },
        OptSpec { name: "clusters", takes_value: true, help: "generator clusters" },
        OptSpec { name: "parts", takes_value: true, help: "|P| partition subsets" },
        OptSpec { name: "workers", takes_value: true, help: "worker threads (0 = auto)" },
        OptSpec { name: "strategy", takes_value: true, help: "block|round-robin|random|kmeans-lite" },
        OptSpec { name: "metric", takes_value: true, help: "sqeuclid|euclid|cosine|manhattan" },
        OptSpec { name: "kernel", takes_value: true, help: "prim-dense|boruvka-rust|boruvka-xla" },
        OptSpec { name: "pair-kernel", takes_value: true, help: "dense|bipartite-merge pair-job kernel" },
        OptSpec { name: "no-affinity", takes_value: false, help: "disable subset-affinity routing; ship S_i ∪ S_j for every job (dense byte model)" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "transport", takes_value: true, help: "sim (default) | tcp multi-process transport" },
        OptSpec { name: "listen", takes_value: true, help: "leader bind address for --transport tcp (port 0 = auto)" },
        OptSpec { name: "spawn-workers", takes_value: false, help: "tcp: spawn the `demst worker` processes locally instead of awaiting external connects" },
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir (for --kernel boruvka-xla)" },
        OptSpec { name: "reduce-tree", takes_value: false, help: "use the O(|V|) tree-reduction gather" },
        OptSpec { name: "stream-reduce", takes_value: false, help: "fold trees into a bounded running MSF at the leader" },
        OptSpec { name: "simulate-net", takes_value: false, help: "sleep for modeled latency/bandwidth" },
        OptSpec { name: "verify", takes_value: false, help: "check result against SLINK oracle (O(n^2))" },
        OptSpec { name: "k", takes_value: true, help: "also cut dendrogram into k flat clusters" },
        OptSpec { name: "min-cluster-size", takes_value: true, help: "HDBSCAN-style stability extraction with this min size" },
        OptSpec { name: "out-mst", takes_value: true, help: "write MST edges as CSV" },
        OptSpec { name: "out-labels", takes_value: true, help: "write flat cluster labels as CSV (needs --k)" },
    ]
}

fn build_run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("data") {
        cfg.data.kind = v.to_string();
    }
    if let Some(v) = args.get("path") {
        cfg.data.path = Some(v.into());
    }
    if let Some(v) = args.get_parse::<usize>("n")? {
        cfg.data.n = v;
    }
    if let Some(v) = args.get_parse::<usize>("d")? {
        cfg.data.d = v;
    }
    if let Some(v) = args.get_parse::<usize>("clusters")? {
        cfg.data.clusters = v;
    }
    if let Some(v) = args.get_parse::<usize>("parts")? {
        cfg.parts = v;
    }
    if let Some(v) = args.get_parse::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get("strategy") {
        cfg.strategy =
            PartitionStrategy::parse(v).with_context(|| format!("unknown strategy {v:?}"))?;
    }
    if let Some(v) = args.get("metric") {
        cfg.metric = MetricKind::parse(v).with_context(|| format!("unknown metric {v:?}"))?;
    }
    if let Some(v) = args.get("kernel") {
        cfg.kernel = KernelChoice::parse(v).with_context(|| format!("unknown kernel {v:?}"))?;
    }
    if let Some(v) = args.get("pair-kernel") {
        cfg.pair_kernel =
            PairKernelChoice::parse(v).with_context(|| format!("unknown pair kernel {v:?}"))?;
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = demst::config::TransportChoice::parse(v)
            .with_context(|| format!("unknown transport {v:?} (sim|tcp)"))?;
    }
    if let Some(v) = args.get("listen") {
        cfg.listen = Some(v.to_string());
    }
    if args.has_flag("spawn-workers") {
        cfg.spawn_workers = true;
    }
    if args.has_flag("no-affinity") {
        cfg.affinity = false;
    }
    if args.has_flag("reduce-tree") {
        cfg.reduce_tree = true;
    }
    if args.has_flag("stream-reduce") {
        cfg.stream_reduce = true;
    }
    if args.has_flag("simulate-net") {
        cfg.net.simulate_delays = true;
    }
    if args.has_flag("verify") {
        cfg.verify = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let specs = run_specs();
    let args = parse_args(argv, &specs)?;
    let cfg = build_run_config(&args)?;

    // npy datasets override n/d from the file
    let (ds, _truth) = build_dataset(&cfg)?;
    println!(
        "dataset: kind={} n={} d={} | parts={} strategy={} kernel={} workers={} transport={}",
        cfg.data.kind,
        ds.n,
        ds.d,
        cfg.parts,
        cfg.strategy.name(),
        cfg.kernel.name(),
        demst::coordinator::leader::resolve_workers(&cfg),
        cfg.transport.name(),
    );

    let out = run_distributed(&ds, &cfg)?;
    if let Some(note) = &out.metrics.kernel_fallback {
        println!("kernel fallback: {note}");
    }
    println!("mst: {} edges, total weight {:.6}", out.mst.len(), demst::mst::total_weight(&out.mst));
    println!("metrics: {}", out.metrics.summary());
    print_phases_and_workers(&out.metrics);

    if cfg.verify {
        verify_against_slink(&ds, cfg.metric, &out.mst)?;
    }

    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    let heights = dendro.heights();
    if !heights.is_empty() {
        println!(
            "dendrogram: {} merges, height range [{:.4}, {:.4}]",
            dendro.merges.len(),
            heights.first().unwrap(),
            heights.last().unwrap()
        );
    }

    if let Some(k) = args.get_parse::<usize>("k")? {
        let labels = dendro.cut_to_k(k);
        let sizes = cluster_sizes(&labels);
        println!("flat clustering k={k}: sizes {sizes:?}");
        if let Some(path) = args.get("out-labels") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, l) in labels.iter().enumerate() {
                t.push_row(&[i.to_string(), l.to_string()]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("labels written to {path}");
        }
    }

    if let Some(mcs) = args.get_parse::<usize>("min-cluster-size")? {
        let stable = demst::slink::extract_stable_clusters(&dendro, mcs);
        let k = stable.stabilities.len();
        let noise = stable.labels.iter().filter(|&&l| l == demst::slink::NOISE).count();
        let mut sizes = vec![0usize; k];
        for &l in &stable.labels {
            if l != demst::slink::NOISE {
                sizes[l as usize] += 1;
            }
        }
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        println!(
            "stable clusters (min size {mcs}): {k} clusters, sizes {sizes:?}, {noise} noise points"
        );
    }

    if let Some(path) = args.get("out-mst") {
        write_mst_csv(path, &out.mst)?;
    }
    Ok(())
}

/// Check the computed MSF's total weight against the independent `O(n²)`
/// SLINK oracle. 1e-4 relative: the blocked kernels compute Gram-form
/// distances, which differ from the scalar SLINK oracle by float rounding.
fn verify_against_slink(
    ds: &demst::data::Dataset,
    metric: MetricKind,
    mst: &[demst::graph::Edge],
) -> Result<()> {
    let metric = demst::geometry::metric::PlainMetric(metric);
    let oracle = demst::slink::slink_mst(ds, &metric);
    let (a, b) = (demst::mst::total_weight(&oracle), demst::mst::total_weight(mst));
    if (a - b).abs() > 1e-4 * (1.0 + a.abs()) {
        bail!("VERIFY FAILED: slink oracle weight {a} != distributed weight {b}");
    }
    println!("verify: OK (slink oracle weight matches: {a:.6})");
    Ok(())
}

fn write_mst_csv(path: &str, mst: &[demst::graph::Edge]) -> Result<()> {
    let mut t = Table::new("", &["u", "v", "weight"]);
    for e in mst {
        t.push_row(&[e.u.to_string(), e.v.to_string(), format!("{}", e.w)]);
    }
    t.write_csv(std::path::Path::new(path))?;
    println!("mst written to {path}");
    Ok(())
}

/// Per-phase timings, locality wins (affinity scatter savings, panel-cache
/// hit rate, streaming-fold cost), and per-worker busy utilization, so
/// scheduler skew is visible straight from the CLI.
fn print_phases_and_workers(m: &RunMetrics) {
    println!("phases: {}", m.phase_summary());
    let locality = m.locality_summary();
    if !locality.is_empty() {
        println!("locality: {locality}");
    }
    if m.worker_busy.is_empty() {
        return;
    }
    let wall = m.wall.as_secs_f64().max(1e-9);
    let per_worker = m
        .worker_busy
        .iter()
        .enumerate()
        .map(|(w, b)| format!("w{w} {:.0}% ({:.1?})", 100.0 * b.as_secs_f64() / wall, b))
        .collect::<Vec<_>>()
        .join("  ");
    println!(
        "workers: {per_worker}  | busy efficiency {:.2}, imbalance {:.2}",
        m.busy_efficiency(),
        m.imbalance()
    );
}

/// `demst worker --connect <addr>`: one remote worker rank. Connects (with
/// retries — workers routinely start before the leader finishes binding),
/// handshakes, serves job frames until the leader's Shutdown, then prints a
/// one-line report and exits 0.
fn cmd_worker(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "connect", takes_value: true, help: "leader address (host:port) — required" },
        OptSpec { name: "retry-ms", takes_value: true, help: "keep retrying the connect for this long (default 10000)" },
    ];
    let args = parse_args(argv, &specs)?;
    let addr = args
        .get("connect")
        .context("demst worker requires --connect <addr> (the leader's --listen address)")?;
    let retry = std::time::Duration::from_millis(args.get_or("retry-ms", 10_000u64)?);
    let report = demst::net::worker::run(addr, retry)?;
    println!(
        "worker {}: {} pair jobs + {} local-MST jobs, {} dist evals, rx {}, tx {}",
        report.worker_id,
        report.jobs,
        report.local_jobs,
        report.dist_evals,
        human_bytes(report.bytes_rx),
        human_bytes(report.bytes_tx),
    );
    Ok(())
}

fn cmd_dendrogram(argv: &[String]) -> Result<()> {
    let mut specs = run_specs();
    specs.push(OptSpec {
        name: "out-merges",
        takes_value: true,
        help: "write dendrogram merges (a, b, height, size) as CSV (required)",
    });
    specs.push(OptSpec {
        name: "out-stable",
        takes_value: true,
        help: "write HDBSCAN-style stable-cluster labels as CSV (needs --min-cluster-size)",
    });
    let args = parse_args(argv, &specs)?;
    let cfg = build_run_config(&args)?;
    let merges_path = args.get("out-merges").context("--out-merges is required")?;

    let (ds, _) = build_dataset(&cfg)?;
    let out = run_distributed(&ds, &cfg)?;
    if cfg.verify {
        verify_against_slink(&ds, cfg.metric, &out.mst)?;
    }
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    println!(
        "dendrogram: n={} merges={} (kernel={}, pair_kernel={})",
        ds.n,
        dendro.merges.len(),
        out.metrics.kernel,
        out.metrics.pair_kernel
    );

    let mut t = Table::new("", &["cluster_a", "cluster_b", "height", "size"]);
    for m in &dendro.merges {
        let height = format!("{}", m.height);
        t.push_row(&[m.a.to_string(), m.b.to_string(), height, m.size.to_string()]);
    }
    t.write_csv(std::path::Path::new(merges_path))?;
    println!("merges written to {merges_path}");

    if let Some(k) = args.get_parse::<usize>("k")? {
        let labels = dendro.cut_to_k(k);
        println!("flat clustering k={k}: sizes {:?}", cluster_sizes(&labels));
        if let Some(path) = args.get("out-labels") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, l) in labels.iter().enumerate() {
                t.push_row(&[i.to_string(), l.to_string()]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("labels written to {path}");
        }
    }

    if let Some(mcs) = args.get_parse::<usize>("min-cluster-size")? {
        let stable = demst::slink::extract_stable_clusters(&dendro, mcs);
        let k = stable.stabilities.len();
        let noise = stable.labels.iter().filter(|&&l| l == demst::slink::NOISE).count();
        println!("stable clusters (min size {mcs}): {k} clusters, {noise} noise points");
        if let Some(path) = args.get("out-stable") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, &l) in stable.labels.iter().enumerate() {
                let label = if l == demst::slink::NOISE { "-1".into() } else { l.to_string() };
                t.push_row(&[i.to_string(), label]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("stable labels written to {path}");
        }
    }
    if let Some(path) = args.get("out-mst") {
        write_mst_csv(path, &out.mst)?;
    }
    Ok(())
}

fn cluster_sizes(labels: &[u32]) -> Vec<usize> {
    let k = labels.iter().copied().max().map_or(0, |m| m as usize + 1);
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    sizes
}

fn cmd_gen(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "kind", takes_value: true, help: "blobs|uniform|embedding|shells" },
        OptSpec { name: "n", takes_value: true, help: "points" },
        OptSpec { name: "d", takes_value: true, help: "dimensions" },
        OptSpec { name: "clusters", takes_value: true, help: "generator clusters" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "out", takes_value: true, help: "output .npy path (required)" },
    ];
    let args = parse_args(argv, &specs)?;
    let mut cfg = RunConfig::default();
    cfg.data.kind = args.get("kind").unwrap_or("blobs").to_string();
    cfg.data.n = args.get_or("n", 1024usize)?;
    cfg.data.d = args.get_or("d", 64usize)?;
    cfg.data.clusters = args.get_or("clusters", 8usize)?;
    cfg.seed = args.get_or("seed", 42u64)?;
    cfg.parts = 1;
    let out = args.get("out").context("--out is required")?;
    let (ds, _) = build_dataset(&cfg)?;
    demst::data::npy::write_npy(std::path::Path::new(out), &ds)?;
    println!("wrote {} ({} x {}, {})", out, ds.n, ds.d, human_bytes(ds.payload_bytes()));
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir" },
        OptSpec { name: "compile", takes_value: false, help: "also compile every artifact (needs backend-xla)" },
    ];
    let args = parse_args(argv, &specs)?;
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    // Manifest parsing needs no PJRT, so `info` works in every build; only
    // the --compile probe requires the backend-xla feature.
    let manifest = demst::runtime::Manifest::load(&dir)?;
    if args.has_flag("compile") && !demst::runtime::backend_xla_compiled() {
        bail!("--compile requires a build with --features backend-xla");
    }
    let mut t = Table::new(format!("artifacts in {}", dir.display()), &["kernel", "N", "D", "file", "status"]);
    #[cfg(feature = "backend-xla")]
    let engine = if args.has_flag("compile") { Some(demst::runtime::Engine::load(&dir)?) } else { None };
    for a in manifest.artifacts.clone() {
        #[cfg(feature = "backend-xla")]
        let status = if let Some(engine) = &engine {
            match engine.executable(&a) {
                Ok(_) => "compiles".to_string(),
                Err(e) => format!("ERROR: {e}"),
            }
        } else if manifest.path_of(&a).is_file() {
            "present".to_string()
        } else {
            "MISSING".to_string()
        };
        #[cfg(not(feature = "backend-xla"))]
        let status = if manifest.path_of(&a).is_file() {
            "present".to_string()
        } else {
            "MISSING".to_string()
        };
        t.push_row(&[a.kernel.clone(), a.n.to_string(), a.d.to_string(), a.file.clone(), status]);
    }
    t.print();
    if !demst::runtime::backend_xla_compiled() {
        println!("(metadata only: this build has no PJRT runtime — rebuild with --features backend-xla to execute artifacts)");
    }
    Ok(())
}

fn cmd_selftest(argv: &[String]) -> Result<()> {
    let specs = vec![OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir" }];
    let args = parse_args(argv, &specs)?;
    let artifacts = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    let mut cfg = RunConfig::default();
    cfg.data.kind = "blobs".into();
    cfg.data.n = 200;
    cfg.data.d = 16;
    cfg.data.clusters = 5;
    cfg.parts = 4;
    cfg.artifacts_dir = artifacts.clone();
    let (ds, _) = build_dataset(&cfg)?;
    let metric = demst::geometry::metric::PlainMetric(cfg.metric);
    let oracle = demst::mst::total_weight(&demst::slink::slink_mst(&ds, &metric));

    let mut kernels = vec![KernelChoice::PrimDense, KernelChoice::BoruvkaRust];
    if !demst::runtime::backend_xla_compiled() {
        println!("(backend-xla not compiled — skipping boruvka-xla; rebuild with --features backend-xla)");
    } else if demst::runtime::artifacts_available(&artifacts) {
        kernels.push(KernelChoice::BoruvkaXla);
    } else {
        println!("(artifacts missing at {} — skipping boruvka-xla; run `make artifacts`)", artifacts.display());
    }
    let mut t = Table::new("selftest", &["kernel", "weight", "status"]);
    for kernel in kernels {
        cfg.kernel = kernel.clone();
        let out = run_distributed(&ds, &cfg)?;
        let w = demst::mst::total_weight(&out.mst);
        // 1e-4 relative: blocked Gram-form kernels vs the scalar SLINK oracle.
        let ok = (w - oracle).abs() < 1e-4 * (1.0 + oracle.abs());
        t.push_row(&[
            kernel.name().to_string(),
            format!("{w:.6}"),
            if ok { "OK".into() } else { format!("MISMATCH vs oracle {oracle:.6}") },
        ]);
        if !ok {
            t.print();
            bail!("selftest failed for kernel {}", kernel.name());
        }
    }
    t.print();
    println!("selftest passed (oracle weight {oracle:.6})");
    Ok(())
}
