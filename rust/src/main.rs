//! `demst` — launcher CLI for the distributed EMST / single-linkage system.
//!
//! Subcommands:
//!   run         distributed EMST + optional dendrogram on a dataset
//!   worker      remote worker process for a `run --transport tcp` leader
//!   partition   split a dataset into checksummed shard files + manifest
//!   dendrogram  decomposed MST → single-linkage dendrogram → CSV outputs
//!   gen         generate a synthetic dataset to .npy
//!   info        inspect an artifact directory
//!   selftest    quick end-to-end correctness check (all kernels available)
//!
//! Examples:
//!   demst run --data embedding --n 2048 --d 128 --parts 6 --workers 4 --verify
//!   demst run --config examples/configs/embedding.toml --kernel xla
//!   demst run --pair-kernel bipartite --stream-reduce --n 4096 --parts 8
//!   demst run --transport tcp --listen 127.0.0.1:7000 --workers 2 --n 4096
//!   demst worker --connect 127.0.0.1:7000
//!   demst partition --data embedding --n 65536 --d 128 --parts 8 --out-dir shards/
//!   demst run --shard shards/embedding.manifest.toml --transport tcp \
//!       --listen 0.0.0.0:7000 --workers 3
//!   demst worker --connect leader:7000 --shard shards/embedding.manifest.toml \
//!       --shard-ids 0-3,6
//!   demst dendrogram --data blobs --n 1000 --d 32 --out-merges merges.csv
//!   demst gen --kind blobs --n 1000 --d 64 --out /tmp/blobs.npy
//!   demst info --artifacts artifacts

use anyhow::{bail, Context, Result};
use demst::cli::{parse_args, Args, OptSpec};
use demst::config::run_config::build_dataset;
use demst::config::{KernelChoice, PairKernelChoice, RunConfig};
use demst::coordinator::{run_distributed, RunMetrics};
use demst::decomp::PartitionStrategy;
use demst::geometry::MetricKind;
use demst::report::Table;
use demst::slink::mst_to_dendrogram;
use demst::util::human_bytes;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match real_main(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn real_main(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "worker" => cmd_worker(rest),
        "partition" => cmd_partition(rest),
        "dendrogram" => cmd_dendrogram(rest),
        "gen" => cmd_gen(rest),
        "info" => cmd_info(rest),
        "report" => cmd_report(rest),
        "selftest" => cmd_selftest(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `demst help`)"),
    }
}

fn print_help() {
    println!(
        "demst — distributed Euclidean-MST / single-linkage dendrograms via distance decomposition

USAGE: demst <run|worker|dendrogram|gen|info|report|selftest|help> [options]

run         distributed EMST (+ dendrogram) on a generated, .npy, or sharded dataset
worker      remote worker process: connect to a `run --transport tcp` leader
partition   split a dataset into per-subset shard files + a TOML manifest
dendrogram  decomposed MST -> dendrogram; write merge heights and cluster labels as CSV
gen         write a synthetic dataset to .npy
info        list AOT artifacts and check they compile
report      compare run reports: `report diff <baseline.json> <candidate.json>` exits
            non-zero when a tracked metric regresses beyond its threshold
selftest    quick correctness check across kernels
"
    );
}

fn run_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", takes_value: true, help: "TOML config file (defaults applied first)" },
        OptSpec { name: "data", takes_value: true, help: "blobs|uniform|embedding|shells|npy" },
        OptSpec { name: "path", takes_value: true, help: ".npy file when --data npy" },
        OptSpec { name: "n", takes_value: true, help: "points" },
        OptSpec { name: "d", takes_value: true, help: "dimensions" },
        OptSpec { name: "clusters", takes_value: true, help: "generator clusters" },
        OptSpec { name: "parts", takes_value: true, help: "|P| partition subsets" },
        OptSpec { name: "workers", takes_value: true, help: "worker threads (0 = auto)" },
        OptSpec { name: "strategy", takes_value: true, help: "block|round-robin|random|kmeans-lite" },
        OptSpec { name: "metric", takes_value: true, help: "sqeuclid|euclid|cosine|manhattan" },
        OptSpec { name: "kernel", takes_value: true, help: "prim-dense|boruvka-rust|boruvka-xla" },
        OptSpec { name: "pair-kernel", takes_value: true, help: "dense|bipartite-merge pair-job kernel" },
        OptSpec { name: "no-affinity", takes_value: false, help: "disable subset-affinity routing; ship S_i ∪ S_j for every job (dense byte model)" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "transport", takes_value: true, help: "sim (default) | tcp multi-process transport" },
        OptSpec { name: "listen", takes_value: true, help: "leader bind address for --transport tcp (port 0 = auto)" },
        OptSpec { name: "spawn-workers", takes_value: false, help: "tcp: spawn the `demst worker` processes locally instead of awaiting external connects" },
        OptSpec { name: "shard", takes_value: true, help: "sharded run: plan from this `demst partition` manifest; workers hold the vectors" },
        OptSpec { name: "window", takes_value: true, help: "tcp: pair jobs in flight per worker link (default 2; 1 = strict rendezvous)" },
        OptSpec { name: "liveness-timeout", takes_value: true, help: "tcp: per-link read deadline in seconds (default 30; 0 disables heartbeats + stall detection; must exceed the slowest single pair job)" },
        OptSpec { name: "no-panel-simd", takes_value: false, help: "force the canonical scalar panel kernels (same bits, no SIMD dispatch)" },
        OptSpec { name: "panel-threads", takes_value: true, help: "threads per bipartite panel block, 1..=256 (default 0 = all cores)" },
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir (for --kernel boruvka-xla)" },
        OptSpec { name: "reduce-tree", takes_value: false, help: "use the O(|V|) tree-reduction gather" },
        OptSpec { name: "reduce-topology", takes_value: true, help: "leader|tree|ring — where the ⊕-reduction folds (tree/ring imply --reduce-tree; workers fold among themselves and only the root's forest reaches the leader)" },
        OptSpec { name: "peer-route", takes_value: false, help: "route cached-tree fetches worker↔worker instead of shipping them inline from the leader (default on sharded runs)" },
        OptSpec { name: "no-peer-route", takes_value: false, help: "force inline tree shipping even on sharded runs" },
        OptSpec { name: "stream-reduce", takes_value: false, help: "fold trees into a bounded running MSF at the leader" },
        OptSpec { name: "simulate-net", takes_value: false, help: "sleep for modeled latency/bandwidth" },
        OptSpec { name: "verify", takes_value: false, help: "check result against SLINK oracle (O(n^2))" },
        OptSpec { name: "k", takes_value: true, help: "also cut dendrogram into k flat clusters" },
        OptSpec { name: "min-cluster-size", takes_value: true, help: "HDBSCAN-style stability extraction with this min size" },
        OptSpec { name: "out-mst", takes_value: true, help: "write MST edges as CSV" },
        OptSpec { name: "out-labels", takes_value: true, help: "write flat cluster labels as CSV (needs --k)" },
        OptSpec { name: "trace-out", takes_value: true, help: "record spans fleet-wide and write a Chrome-trace/Perfetto JSON timeline here" },
        OptSpec { name: "report-out", takes_value: true, help: "write the versioned machine-readable run report (full metrics JSON) here" },
        OptSpec { name: "metrics-listen", takes_value: true, help: "serve live fleet-merged Prometheus text exposition on this address (e.g. 127.0.0.1:9399; port 0 = auto), scrapeable mid-run at /metrics" },
        OptSpec { name: "metrics-push-ms", takes_value: true, help: "cadence of the workers' periodic metrics pushes in ms (default 1000; 0 = final WorkerDone snapshot only)" },
        OptSpec { name: "quiet", takes_value: false, help: "suppress the live progress ticker" },
    ]
}

fn build_run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("data") {
        cfg.data.kind = v.to_string();
    }
    if let Some(v) = args.get("path") {
        cfg.data.path = Some(v.into());
    }
    if let Some(v) = args.get_parse::<usize>("n")? {
        cfg.data.n = v;
    }
    if let Some(v) = args.get_parse::<usize>("d")? {
        cfg.data.d = v;
    }
    if let Some(v) = args.get_parse::<usize>("clusters")? {
        cfg.data.clusters = v;
    }
    if let Some(v) = args.get_parse::<usize>("parts")? {
        cfg.parts = v;
    }
    if let Some(v) = args.get_parse::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get("strategy") {
        cfg.strategy =
            PartitionStrategy::parse(v).with_context(|| format!("unknown strategy {v:?}"))?;
    }
    if let Some(v) = args.get("metric") {
        cfg.metric = MetricKind::parse(v).with_context(|| format!("unknown metric {v:?}"))?;
    }
    if let Some(v) = args.get("kernel") {
        cfg.kernel = KernelChoice::parse(v).with_context(|| format!("unknown kernel {v:?}"))?;
    }
    if let Some(v) = args.get("pair-kernel") {
        cfg.pair_kernel =
            PairKernelChoice::parse(v).with_context(|| format!("unknown pair kernel {v:?}"))?;
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = demst::config::TransportChoice::parse(v)
            .with_context(|| format!("unknown transport {v:?} (sim|tcp)"))?;
    }
    if let Some(v) = args.get("listen") {
        cfg.listen = Some(v.to_string());
    }
    if args.has_flag("spawn-workers") {
        cfg.spawn_workers = true;
    }
    if let Some(v) = args.get("shard") {
        cfg.shard_manifest = Some(v.into());
    }
    if let Some(v) = args.get_parse::<usize>("window")? {
        cfg.pipeline_window = v;
    }
    if let Some(v) = args.get_parse::<f64>("liveness-timeout")? {
        if !v.is_finite() || v < 0.0 {
            bail!("--liveness-timeout must be a non-negative number of seconds");
        }
        cfg.net.liveness_timeout_ms = (v * 1000.0).round() as u64;
    }
    if args.has_flag("no-panel-simd") {
        cfg.panel_simd = false;
    }
    if let Some(v) = args.get_parse::<usize>("panel-threads")? {
        cfg.panel_threads = v;
    }
    if args.has_flag("no-affinity") {
        cfg.affinity = false;
    }
    if args.has_flag("reduce-tree") {
        cfg.reduce_tree = true;
    }
    if let Some(v) = args.get("reduce-topology") {
        cfg.reduce_topology = demst::config::ReduceTopology::parse(v)
            .with_context(|| format!("unknown reduce topology {v:?} (leader|tree|ring)"))?;
        if cfg.reduce_topology != demst::config::ReduceTopology::Leader {
            // tree/ring fold worker-locally by definition
            cfg.reduce_tree = true;
        }
    }
    if args.has_flag("peer-route") {
        cfg.peer_route = Some(true);
    }
    if args.has_flag("no-peer-route") {
        if args.has_flag("peer-route") {
            bail!("--peer-route and --no-peer-route are mutually exclusive");
        }
        cfg.peer_route = Some(false);
    }
    if args.has_flag("stream-reduce") {
        cfg.stream_reduce = true;
    }
    if args.has_flag("simulate-net") {
        cfg.net.simulate_delays = true;
    }
    if args.has_flag("verify") {
        cfg.verify = true;
    }
    if let Some(v) = args.get("trace-out") {
        cfg.obs.trace_out = Some(v.into());
        cfg.obs.trace = true; // an exporter without spans is useless
    }
    if let Some(v) = args.get("report-out") {
        cfg.obs.report_out = Some(v.into());
    }
    if let Some(v) = args.get("metrics-listen") {
        cfg.obs.metrics_listen = Some(v.to_string());
    }
    if let Some(v) = args.get_parse::<u64>("metrics-push-ms")? {
        cfg.obs.metrics_push_ms = v;
    }
    if args.has_flag("quiet") {
        cfg.obs.progress = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let specs = run_specs();
    let args = parse_args(argv, &specs)?;
    let cfg = build_run_config(&args)?;

    let (out, ds, n) = if let Some(manifest_path) = &cfg.shard_manifest {
        // Sharded: the leader plans from the manifest and never holds the
        // vectors, so there is no dataset (and no O(n²) oracle) here.
        if cfg.verify {
            bail!("--verify needs leader-resident vectors; a sharded leader has none (run the oracle on a host holding the full dataset)");
        }
        let manifest = demst::shard::Manifest::load(manifest_path)?;
        println!(
            "dataset: shard manifest {} (n={} d={} metric={} parts={}) | kernel={} workers={} transport=tcp window={}",
            manifest_path.display(),
            manifest.n,
            manifest.d,
            manifest.metric.name(),
            manifest.parts(),
            cfg.kernel.name(),
            cfg.workers,
            cfg.pipeline_window,
        );
        let n = manifest.n;
        (demst::coordinator::run_sharded(&cfg)?, None, n)
    } else {
        // npy datasets override n/d from the file
        let (ds, _truth) = build_dataset(&cfg)?;
        println!(
            "dataset: kind={} n={} d={} | parts={} strategy={} kernel={} workers={} transport={}",
            cfg.data.kind,
            ds.n,
            ds.d,
            cfg.parts,
            cfg.strategy.name(),
            cfg.kernel.name(),
            demst::coordinator::leader::resolve_workers(&cfg),
            cfg.transport.name(),
        );
        let n = ds.n;
        (run_distributed(&ds, &cfg)?, Some(ds), n)
    };
    if let Some(note) = &out.metrics.kernel_fallback {
        println!("kernel fallback: {note}");
    }
    let kernel_line = out.metrics.kernel_summary();
    if !kernel_line.is_empty() {
        println!("kernel: {kernel_line}");
    }
    println!("mst: {} edges, total weight {:.6}", out.mst.len(), demst::mst::total_weight(&out.mst));
    println!("metrics: {}", out.metrics.summary());
    print_latency_line(&out.metrics);
    print_phases_and_workers(&out.metrics);
    if let Some(path) = &cfg.obs.trace_out {
        demst::obs::trace::write_chrome_trace(path, &out.metrics)
            .with_context(|| format!("writing trace to {}", path.display()))?;
        println!("trace written to {} ({} spans)", path.display(), out.metrics.spans.len());
    }
    if let Some(path) = &cfg.obs.report_out {
        demst::obs::report::write_run_report(path, &cfg, &out.metrics)
            .with_context(|| format!("writing run report to {}", path.display()))?;
        println!("report written to {}", path.display());
    }

    if cfg.verify {
        let ds = ds.as_ref().expect("verify rejected on sharded runs above");
        verify_against_slink(ds, cfg.metric, &out.mst)?;
    }

    let dendro = mst_to_dendrogram(n, &out.mst);
    let heights = dendro.heights();
    if !heights.is_empty() {
        println!(
            "dendrogram: {} merges, height range [{:.4}, {:.4}]",
            dendro.merges.len(),
            heights.first().unwrap(),
            heights.last().unwrap()
        );
    }

    if let Some(k) = args.get_parse::<usize>("k")? {
        let labels = dendro.cut_to_k(k);
        let sizes = cluster_sizes(&labels);
        println!("flat clustering k={k}: sizes {sizes:?}");
        if let Some(path) = args.get("out-labels") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, l) in labels.iter().enumerate() {
                t.push_row(&[i.to_string(), l.to_string()]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("labels written to {path}");
        }
    }

    if let Some(mcs) = args.get_parse::<usize>("min-cluster-size")? {
        let stable = demst::slink::extract_stable_clusters(&dendro, mcs);
        let k = stable.stabilities.len();
        let noise = stable.labels.iter().filter(|&&l| l == demst::slink::NOISE).count();
        let mut sizes = vec![0usize; k];
        for &l in &stable.labels {
            if l != demst::slink::NOISE {
                sizes[l as usize] += 1;
            }
        }
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        println!(
            "stable clusters (min size {mcs}): {k} clusters, sizes {sizes:?}, {noise} noise points"
        );
    }

    if let Some(path) = args.get("out-mst") {
        write_mst_csv(path, &out.mst)?;
    }
    Ok(())
}

fn report_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "max-wall-regress", takes_value: true, help: "allowed wall-clock regression in percent (default 25)" },
        OptSpec { name: "max-dist-evals-regress", takes_value: true, help: "allowed distance-evaluation regression in percent (default 1)" },
        OptSpec { name: "max-bytes-regress", takes_value: true, help: "allowed scatter+gather+control byte regression in percent (default 1)" },
        OptSpec { name: "max-p99-job-regress", takes_value: true, help: "allowed p99 pair-job latency regression in percent (default 50)" },
    ]
}

/// `demst report diff <baseline.json> <candidate.json>`: the cross-run
/// regression gate. Prints the full comparison table, then fails (exit 1)
/// if any tracked quantity regressed beyond its allowance — so CI can
/// pin a committed baseline report against every candidate run.
fn cmd_report(argv: &[String]) -> Result<()> {
    use demst::obs::report::{diff_reports, DiffThresholds};
    let args = parse_args(argv, &report_specs())?;
    let [action, base_path, cand_path] = args.positional.as_slice() else {
        bail!(
            "usage: demst report diff <baseline.json> <candidate.json>\n{}",
            demst::cli::usage(&report_specs())
        );
    };
    if action != "diff" {
        bail!("unknown report action {action:?} (only `diff` exists)");
    }
    let read = |path: &str| -> Result<demst::obs::json::Value> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading run report {path:?}"))?;
        demst::obs::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing run report {path:?}: {e}"))
    };
    let baseline = read(base_path)?;
    let candidate = read(cand_path)?;

    let mut th = DiffThresholds::default();
    if let Some(v) = args.get_parse::<f64>("max-wall-regress")? {
        th.wall_pct = v;
    }
    if let Some(v) = args.get_parse::<f64>("max-dist-evals-regress")? {
        th.dist_evals_pct = v;
    }
    if let Some(v) = args.get_parse::<f64>("max-bytes-regress")? {
        th.bytes_pct = v;
    }
    if let Some(v) = args.get_parse::<f64>("max-p99-job-regress")? {
        th.p99_job_pct = v;
    }

    let rows = diff_reports(&baseline, &candidate, &th).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "{:<20} {:>14} {:>14} {:>10} {:>8}  verdict",
        "metric", "baseline", "candidate", "delta", "limit"
    );
    for r in &rows {
        println!(
            "{:<20} {:>14.6} {:>14.6} {:>+9.2}% {:>7.0}%  {}",
            r.name,
            r.baseline,
            r.candidate,
            r.delta_pct(),
            r.limit_pct,
            if r.regressed() { "REGRESSED" } else { "ok" }
        );
    }
    let bad: Vec<&str> = rows.iter().filter(|r| r.regressed()).map(|r| r.name).collect();
    if !bad.is_empty() {
        bail!("regression beyond threshold in: {}", bad.join(", "));
    }
    println!("report diff: ok ({} metrics within thresholds)", rows.len());
    Ok(())
}

/// The run summary's `latency:` line, sourced from the fleet-merged
/// pair-job latency histogram: p50/p95/p99 (bucket-bound estimates,
/// ≤ 12.5% relative error) plus the slowest job's (i, j) identity. Omitted
/// when no pair job was recorded (e.g. a run whose remote workers never
/// shipped metrics).
fn print_latency_line(metrics: &RunMetrics) {
    let Some(fleet) = &metrics.fleet_metrics else { return };
    let h = fleet.hist(demst::obs::metrics::Hist::JobLatency);
    if h.count == 0 {
        return;
    }
    let q = |q: f64| fmt_ns(h.quantile(q).unwrap_or(0));
    let slowest = match fleet.slowest {
        Some(s) => format!(" | slowest job ({}, {}) {}", s.i, s.j, fmt_ns(s.ns)),
        None => String::new(),
    };
    println!(
        "latency: pair-job p50 {} p95 {} p99 {} over {} jobs{slowest}",
        q(0.50),
        q(0.95),
        q(0.99),
        h.count,
    );
}

/// Human nanoseconds: picks ns/µs/ms/s to keep 3 significant-ish digits.
fn fmt_ns(ns: u64) -> String {
    match ns {
        0..=999 => format!("{ns}ns"),
        1_000..=999_999 => format!("{:.1}µs", ns as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.1}ms", ns as f64 / 1e6),
        _ => format!("{:.2}s", ns as f64 / 1e9),
    }
}

/// Check the computed MSF's total weight against the independent `O(n²)`
/// SLINK oracle. 1e-4 relative: the blocked kernels compute Gram-form
/// distances, which differ from the scalar SLINK oracle by float rounding.
fn verify_against_slink(
    ds: &demst::data::Dataset,
    metric: MetricKind,
    mst: &[demst::graph::Edge],
) -> Result<()> {
    let metric = demst::geometry::metric::PlainMetric(metric);
    let oracle = demst::slink::slink_mst(ds, &metric);
    let (a, b) = (demst::mst::total_weight(&oracle), demst::mst::total_weight(mst));
    if (a - b).abs() > 1e-4 * (1.0 + a.abs()) {
        bail!("VERIFY FAILED: slink oracle weight {a} != distributed weight {b}");
    }
    println!("verify: OK (slink oracle weight matches: {a:.6})");
    Ok(())
}

fn write_mst_csv(path: &str, mst: &[demst::graph::Edge]) -> Result<()> {
    let mut t = Table::new("", &["u", "v", "weight"]);
    for e in mst {
        t.push_row(&[e.u.to_string(), e.v.to_string(), format!("{}", e.w)]);
    }
    t.write_csv(std::path::Path::new(path))?;
    println!("mst written to {path}");
    Ok(())
}

/// Per-phase timings, locality wins (affinity scatter savings, panel-cache
/// hit rate, streaming-fold cost), and per-worker busy utilization, so
/// scheduler skew is visible straight from the CLI.
fn print_phases_and_workers(m: &RunMetrics) {
    println!("phases: {}", m.phase_summary());
    let locality = m.locality_summary();
    if !locality.is_empty() {
        println!("locality: {locality}");
    }
    let sharding = m.sharding_summary();
    if !sharding.is_empty() {
        println!("sharding: {sharding}");
    }
    if m.worker_failures > 0 {
        let stall_note = if m.stalls_detected > 0 {
            format!(" ({} by liveness stall)", m.stalls_detected)
        } else {
            String::new()
        };
        println!(
            "elastic: {} worker link(s) failed{stall_note}, {} job(s) reassigned to the surviving fleet",
            m.worker_failures, m.jobs_reassigned
        );
    }
    if m.workers_admitted > 0 {
        println!(
            "elastic: {} worker(s) admitted mid-run via Join/AdmitAck and rebalanced onto",
            m.workers_admitted
        );
    }
    if m.worker_busy.is_empty() {
        return;
    }
    let wall = m.wall.as_secs_f64().max(1e-9);
    let per_worker = m
        .worker_busy
        .iter()
        .enumerate()
        .map(|(w, b)| format!("w{w} {:.0}% ({:.1?})", 100.0 * b.as_secs_f64() / wall, b))
        .collect::<Vec<_>>()
        .join("  ");
    println!(
        "workers: {per_worker}  | busy efficiency {:.2}, imbalance {:.2}",
        m.busy_efficiency(),
        m.imbalance()
    );
}

/// `demst worker --connect <addr>`: one remote worker rank. Optionally
/// loads shard files first (`--shard` + `--shard-ids`), connects (with
/// bounded-backoff retries — workers routinely start before the leader
/// finishes binding), handshakes, serves job frames until the leader's
/// Shutdown, then prints a one-line report and exits 0.
fn cmd_worker(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "connect", takes_value: true, help: "leader address (host:port) — required" },
        OptSpec { name: "connect-timeout", takes_value: true, help: "keep retrying the connect for this many ms (default 10000)" },
        OptSpec { name: "connect-backoff-ms", takes_value: true, help: "initial retry backoff in ms, doubling up to 2 s (default 100)" },
        OptSpec { name: "retry-ms", takes_value: true, help: "deprecated alias of --connect-timeout" },
        OptSpec { name: "peer-connect-timeout", takes_value: true, help: "per-attempt timeout for worker↔worker peer dials in ms (default 5000)" },
        OptSpec { name: "shard", takes_value: true, help: "load subsets from this shard manifest before connecting" },
        OptSpec { name: "shard-ids", takes_value: true, help: "which shards to load, e.g. 0,2-4 (default: all in the manifest)" },
    ];
    let args = parse_args(argv, &specs)?;
    let addr = args
        .get("connect")
        .context("demst worker requires --connect <addr> (the leader's --listen address)")?;
    let timeout_ms = match args.get_parse::<u64>("connect-timeout")? {
        Some(v) => v,
        None => args.get_or("retry-ms", 10_000u64)?,
    };
    let shards = match args.get("shard") {
        Some(manifest) => {
            let ids = match args.get("shard-ids") {
                Some(spec) => demst::shard::decode_id_ranges(spec)
                    .with_context(|| format!("parsing --shard-ids {spec:?}"))?,
                None => Vec::new(), // empty = all shards in the manifest
            };
            Some((std::path::PathBuf::from(manifest), ids))
        }
        None => {
            if args.get("shard-ids").is_some() {
                bail!("--shard-ids requires --shard <manifest>");
            }
            None
        }
    };
    let peer_ms = args.get_or("peer-connect-timeout", 5_000u64)?;
    if peer_ms == 0 {
        bail!("--peer-connect-timeout must be positive (a zero dial window fails every peer fetch)");
    }
    let opts = demst::net::worker::WorkerOptions {
        connect_timeout: std::time::Duration::from_millis(timeout_ms),
        connect_backoff: std::time::Duration::from_millis(args.get_or("connect-backoff-ms", 100u64)?),
        peer_connect_timeout: std::time::Duration::from_millis(peer_ms),
        shards,
    };
    let report = demst::net::worker::run_with(addr, &opts)?;
    let shard_note = if report.shards_loaded > 0 {
        format!(
            ", {} shards held locally ({})",
            report.shards_loaded,
            human_bytes(report.shard_local_bytes)
        )
    } else {
        String::new()
    };
    let peer_note = if report.peer_tx_bytes > 0 || report.peer_ships > 0 {
        format!(
            ", peer tx {} ({} ships)",
            human_bytes(report.peer_tx_bytes),
            report.peer_ships
        )
    } else {
        String::new()
    };
    println!(
        "worker {}: {} pair jobs + {} local-MST jobs, {} dist evals, rx {}, tx {}{}{}",
        report.worker_id,
        report.jobs,
        report.local_jobs,
        report.dist_evals,
        human_bytes(report.bytes_rx),
        human_bytes(report.bytes_tx),
        shard_note,
        peer_note,
    );
    Ok(())
}

/// `demst partition`: split a dataset into per-subset shard files plus a
/// manifest, ready to place on worker hosts for a sharded run. Also prints
/// a pair-covering `--shard-ids` assignment for the requested fleet size.
fn cmd_partition(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "data", takes_value: true, help: "blobs|uniform|embedding|shells|npy" },
        OptSpec { name: "path", takes_value: true, help: ".npy file when --data npy" },
        OptSpec { name: "n", takes_value: true, help: "points" },
        OptSpec { name: "d", takes_value: true, help: "dimensions" },
        OptSpec { name: "clusters", takes_value: true, help: "generator clusters" },
        OptSpec { name: "parts", takes_value: true, help: "|P| partition subsets (= shards)" },
        OptSpec { name: "strategy", takes_value: true, help: "block|round-robin|random|kmeans-lite" },
        OptSpec { name: "metric", takes_value: true, help: "sqeuclid|euclid|cosine|manhattan" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "out-dir", takes_value: true, help: "directory for shard files + manifest (required)" },
        OptSpec { name: "name", takes_value: true, help: "shard set name (default: the data kind)" },
        OptSpec { name: "plan-workers", takes_value: true, help: "also print a pair-covering --shard-ids assignment for this many workers" },
    ];
    let args = parse_args(argv, &specs)?;
    let mut cfg = RunConfig::default();
    if let Some(v) = args.get("data") {
        cfg.data.kind = v.to_string();
    }
    if let Some(v) = args.get("path") {
        cfg.data.path = Some(v.into());
    }
    cfg.data.n = args.get_or("n", cfg.data.n)?;
    cfg.data.d = args.get_or("d", cfg.data.d)?;
    cfg.data.clusters = args.get_or("clusters", cfg.data.clusters)?;
    cfg.parts = args.get_or("parts", 8usize)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(v) = args.get("strategy") {
        cfg.strategy =
            PartitionStrategy::parse(v).with_context(|| format!("unknown strategy {v:?}"))?;
    }
    if let Some(v) = args.get("metric") {
        cfg.metric = MetricKind::parse(v).with_context(|| format!("unknown metric {v:?}"))?;
    }
    let out_dir = std::path::PathBuf::from(args.get("out-dir").context("--out-dir is required")?);
    let name = args.get("name").unwrap_or(cfg.data.kind.as_str()).to_string();
    if cfg.data.kind == "npy" && cfg.data.path.is_none() {
        bail!("--data npy requires --path <file.npy>");
    }

    let (ds, _) = build_dataset(&cfg)?;
    if cfg.parts > ds.n {
        bail!("--parts {} exceeds the dataset's n = {}", cfg.parts, ds.n);
    }
    let (manifest, manifest_path) = demst::shard::write_dataset_shards(
        &out_dir, &name, &ds, cfg.parts, cfg.strategy, cfg.seed, cfg.metric,
    )?;
    println!(
        "partitioned n={} d={} metric={} into {} shards ({} vectors total) under {}",
        manifest.n,
        manifest.d,
        manifest.metric.name(),
        manifest.parts(),
        human_bytes(ds.payload_bytes()),
        out_dir.display(),
    );
    println!("manifest: {} (fingerprint {:#018x})", manifest_path.display(), manifest.fingerprint());
    for e in &manifest.shards {
        println!(
            "  shard {}: {} rows, {}, digest {:#018x}",
            e.part,
            e.ids.len(),
            e.file,
            e.digest
        );
    }
    if let Some(w) = args.get_parse::<usize>("plan-workers")? {
        if w == 0 {
            bail!("--plan-workers must be >= 1");
        }
        println!("\npair-covering assignment for {w} workers (every subset pair co-resident):");
        for (i, ids) in demst::shard::suggest_assignment(cfg.parts, w).iter().enumerate() {
            println!(
                "  worker {i}: demst worker --connect <leader> --shard {} --shard-ids {}",
                manifest_path.display(),
                demst::shard::encode_id_ranges(ids)
            );
        }
    }
    println!(
        "\nrun the leader with: demst run --shard {} --transport tcp --listen <addr> --workers <N>",
        manifest_path.display()
    );
    Ok(())
}

fn cmd_dendrogram(argv: &[String]) -> Result<()> {
    let mut specs = run_specs();
    specs.push(OptSpec {
        name: "out-merges",
        takes_value: true,
        help: "write dendrogram merges (a, b, height, size) as CSV (required)",
    });
    specs.push(OptSpec {
        name: "out-stable",
        takes_value: true,
        help: "write HDBSCAN-style stable-cluster labels as CSV (needs --min-cluster-size)",
    });
    let args = parse_args(argv, &specs)?;
    let cfg = build_run_config(&args)?;
    if cfg.shard_manifest.is_some() {
        bail!("demst dendrogram runs leader-resident; for sharded data use `demst run --shard ... --out-mst <csv>` and post-process the MST");
    }
    let merges_path = args.get("out-merges").context("--out-merges is required")?;

    let (ds, _) = build_dataset(&cfg)?;
    let out = run_distributed(&ds, &cfg)?;
    if cfg.verify {
        verify_against_slink(&ds, cfg.metric, &out.mst)?;
    }
    let dendro = mst_to_dendrogram(ds.n, &out.mst);
    println!(
        "dendrogram: n={} merges={} (kernel={}, pair_kernel={})",
        ds.n,
        dendro.merges.len(),
        out.metrics.kernel,
        out.metrics.pair_kernel
    );

    let mut t = Table::new("", &["cluster_a", "cluster_b", "height", "size"]);
    for m in &dendro.merges {
        let height = format!("{}", m.height);
        t.push_row(&[m.a.to_string(), m.b.to_string(), height, m.size.to_string()]);
    }
    t.write_csv(std::path::Path::new(merges_path))?;
    println!("merges written to {merges_path}");

    if let Some(k) = args.get_parse::<usize>("k")? {
        let labels = dendro.cut_to_k(k);
        println!("flat clustering k={k}: sizes {:?}", cluster_sizes(&labels));
        if let Some(path) = args.get("out-labels") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, l) in labels.iter().enumerate() {
                t.push_row(&[i.to_string(), l.to_string()]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("labels written to {path}");
        }
    }

    if let Some(mcs) = args.get_parse::<usize>("min-cluster-size")? {
        let stable = demst::slink::extract_stable_clusters(&dendro, mcs);
        let k = stable.stabilities.len();
        let noise = stable.labels.iter().filter(|&&l| l == demst::slink::NOISE).count();
        println!("stable clusters (min size {mcs}): {k} clusters, {noise} noise points");
        if let Some(path) = args.get("out-stable") {
            let mut t = Table::new("", &["index", "label"]);
            for (i, &l) in stable.labels.iter().enumerate() {
                let label = if l == demst::slink::NOISE { "-1".into() } else { l.to_string() };
                t.push_row(&[i.to_string(), label]);
            }
            t.write_csv(std::path::Path::new(path))?;
            println!("stable labels written to {path}");
        }
    }
    if let Some(path) = args.get("out-mst") {
        write_mst_csv(path, &out.mst)?;
    }
    Ok(())
}

fn cluster_sizes(labels: &[u32]) -> Vec<usize> {
    let k = labels.iter().copied().max().map_or(0, |m| m as usize + 1);
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    sizes
}

fn cmd_gen(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "kind", takes_value: true, help: "blobs|uniform|embedding|shells" },
        OptSpec { name: "n", takes_value: true, help: "points" },
        OptSpec { name: "d", takes_value: true, help: "dimensions" },
        OptSpec { name: "clusters", takes_value: true, help: "generator clusters" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "out", takes_value: true, help: "output .npy path (required)" },
    ];
    let args = parse_args(argv, &specs)?;
    let mut cfg = RunConfig::default();
    cfg.data.kind = args.get("kind").unwrap_or("blobs").to_string();
    cfg.data.n = args.get_or("n", 1024usize)?;
    cfg.data.d = args.get_or("d", 64usize)?;
    cfg.data.clusters = args.get_or("clusters", 8usize)?;
    cfg.seed = args.get_or("seed", 42u64)?;
    cfg.parts = 1;
    let out = args.get("out").context("--out is required")?;
    let (ds, _) = build_dataset(&cfg)?;
    demst::data::npy::write_npy(std::path::Path::new(out), &ds)?;
    println!("wrote {} ({} x {}, {})", out, ds.n, ds.d, human_bytes(ds.payload_bytes()));
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir" },
        OptSpec { name: "compile", takes_value: false, help: "also compile every artifact (needs backend-xla)" },
    ];
    let args = parse_args(argv, &specs)?;
    let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    // Manifest parsing needs no PJRT, so `info` works in every build; only
    // the --compile probe requires the backend-xla feature.
    let manifest = demst::runtime::Manifest::load(&dir)?;
    if args.has_flag("compile") && !demst::runtime::backend_xla_compiled() {
        bail!("--compile requires a build with --features backend-xla");
    }
    let mut t = Table::new(format!("artifacts in {}", dir.display()), &["kernel", "N", "D", "file", "status"]);
    #[cfg(feature = "backend-xla")]
    let engine = if args.has_flag("compile") { Some(demst::runtime::Engine::load(&dir)?) } else { None };
    for a in manifest.artifacts.clone() {
        #[cfg(feature = "backend-xla")]
        let status = if let Some(engine) = &engine {
            match engine.executable(&a) {
                Ok(_) => "compiles".to_string(),
                Err(e) => format!("ERROR: {e}"),
            }
        } else if manifest.path_of(&a).is_file() {
            "present".to_string()
        } else {
            "MISSING".to_string()
        };
        #[cfg(not(feature = "backend-xla"))]
        let status = if manifest.path_of(&a).is_file() {
            "present".to_string()
        } else {
            "MISSING".to_string()
        };
        t.push_row(&[a.kernel.clone(), a.n.to_string(), a.d.to_string(), a.file.clone(), status]);
    }
    t.print();
    if !demst::runtime::backend_xla_compiled() {
        println!("(metadata only: this build has no PJRT runtime — rebuild with --features backend-xla to execute artifacts)");
    }
    Ok(())
}

fn cmd_selftest(argv: &[String]) -> Result<()> {
    let specs = vec![OptSpec { name: "artifacts", takes_value: true, help: "artifacts dir" }];
    let args = parse_args(argv, &specs)?;
    let artifacts = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    let mut cfg = RunConfig::default();
    cfg.data.kind = "blobs".into();
    cfg.data.n = 200;
    cfg.data.d = 16;
    cfg.data.clusters = 5;
    cfg.parts = 4;
    cfg.artifacts_dir = artifacts.clone();
    let (ds, _) = build_dataset(&cfg)?;
    let metric = demst::geometry::metric::PlainMetric(cfg.metric);
    let oracle = demst::mst::total_weight(&demst::slink::slink_mst(&ds, &metric));

    let mut kernels = vec![KernelChoice::PrimDense, KernelChoice::BoruvkaRust];
    if !demst::runtime::backend_xla_compiled() {
        println!("(backend-xla not compiled — skipping boruvka-xla; rebuild with --features backend-xla)");
    } else if demst::runtime::artifacts_available(&artifacts) {
        kernels.push(KernelChoice::BoruvkaXla);
    } else {
        println!("(artifacts missing at {} — skipping boruvka-xla; run `make artifacts`)", artifacts.display());
    }
    let mut t = Table::new("selftest", &["kernel", "weight", "status"]);
    for kernel in kernels {
        cfg.kernel = kernel.clone();
        let out = run_distributed(&ds, &cfg)?;
        let w = demst::mst::total_weight(&out.mst);
        // 1e-4 relative: blocked Gram-form kernels vs the scalar SLINK oracle.
        let ok = (w - oracle).abs() < 1e-4 * (1.0 + oracle.abs());
        t.push_row(&[
            kernel.name().to_string(),
            format!("{w:.6}"),
            if ok { "OK".into() } else { format!("MISMATCH vs oracle {oracle:.6}") },
        ]);
        if !ok {
            t.print();
            bail!("selftest failed for kernel {}", kernel.name());
        }
    }
    t.print();
    println!("selftest passed (oracle weight {oracle:.6})");
    Ok(())
}
