//! A small hand-rolled CLI argument parser (no clap in the offline vendor
//! set). Supports `--flag`, `--key value`, `--key=value`, positional args,
//! and generates usage text.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

/// Parse `argv` (without the program/subcommand) against specs.
pub fn parse_args(argv: &[String], specs: &[OptSpec]) -> Result<Args> {
    let mut out = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(body) = arg.strip_prefix("--") {
            let (name, inline_value) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| anyhow!("unknown option --{name}\n{}", usage(specs)))?;
            if spec.takes_value {
                let value = match inline_value {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| anyhow!("--{name} requires a value"))?
                        .clone(),
                };
                out.opts.insert(name.to_string(), value);
            } else {
                if inline_value.is_some() {
                    bail!("--{name} does not take a value");
                }
                out.flags.push(name.to_string());
            }
        } else {
            out.positional.push(arg.clone());
        }
    }
    Ok(out)
}

/// Render usage text for a spec list.
pub fn usage(specs: &[OptSpec]) -> String {
    let mut s = String::from("options:\n");
    for spec in specs {
        let arg = if spec.takes_value {
            format!("--{} <value>", spec.name)
        } else {
            format!("--{}", spec.name)
        };
        s.push_str(&format!("  {:<28} {}\n", arg, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", takes_value: true, help: "points" },
            OptSpec { name: "verbose", takes_value: false, help: "chatty" },
            OptSpec { name: "kind", takes_value: true, help: "dataset kind" },
        ]
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_flag_positional() {
        let a = parse_args(&sv(&["--n", "100", "--verbose", "pos1", "--kind=blobs"]), &specs())
            .unwrap();
        assert_eq!(a.get("n"), Some("100"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("kind"), Some("blobs"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_or("n", 0usize).unwrap(), 100);
        assert_eq!(a.get_or("missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        let e = parse_args(&sv(&["--bogus"]), &specs()).unwrap_err();
        assert!(e.to_string().contains("unknown option"));
        assert!(e.to_string().contains("--n <value>"), "usage included");
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse_args(&sv(&["--n"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse_args(&sv(&["--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn bad_parse_reported() {
        let a = parse_args(&sv(&["--n", "abc"]), &specs()).unwrap();
        assert!(a.get_or("n", 0usize).is_err());
    }
}
