//! Configuration: a hand-rolled TOML-subset parser (no serde offline) plus
//! the typed run configuration the CLI and launcher consume.

pub mod toml_lite;
pub mod run_config;

pub use run_config::{
    DataConfig, KernelChoice, NetConfig, ObsConfig, PairKernelChoice, ReduceTopology, RunConfig,
    TransportChoice,
};
pub use toml_lite::{parse_toml, TomlValue};
