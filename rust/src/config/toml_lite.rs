//! A TOML-subset parser sufficient for run configuration files.
//!
//! Supported: `[section]` headers (one level), `key = value` with string
//! (`"..."`), integer, float, boolean, and flat arrays of those; `#`
//! comments; blank lines. Unsupported (rejected with errors): nested tables,
//! inline tables, multi-line strings, dotted keys, datetimes.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed TOML-lite value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// `section.key -> value` map; keys before any section land under `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-lite document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                bail!("line {}: unsupported section name {name:?}", lineno + 1);
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() || key.contains('.') || key.contains(' ') {
            bail!("line {}: unsupported key {key:?}", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            bail!("trailing garbage after string");
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            items.push(parse_value(p)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Split on commas not inside strings (arrays of scalars only, no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse_toml(
            r#"
# run configuration
name = "demo"

[data]
n = 1024
d = 256
std = 0.5          # cluster std
kinds = ["blobs", "uniform"]

[net]
enabled = true
latency_us = 50
bandwidth = 1.5e9
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("demo".into()));
        assert_eq!(doc["data"]["n"], TomlValue::Int(1024));
        assert_eq!(doc["data"]["std"], TomlValue::Float(0.5));
        assert_eq!(
            doc["data"]["kinds"],
            TomlValue::Array(vec![TomlValue::Str("blobs".into()), TomlValue::Str("uniform".into())])
        );
        assert_eq!(doc["net"]["enabled"], TomlValue::Bool(true));
        assert_eq!(doc["net"]["bandwidth"].as_float(), Some(1.5e9));
    }

    #[test]
    fn comments_and_underscores() {
        let doc = parse_toml("x = 1_000_000 # one million\ny = \"a # not comment\"").unwrap();
        assert_eq!(doc[""]["x"], TomlValue::Int(1_000_000));
        assert_eq!(doc[""]["y"], TomlValue::Str("a # not comment".into()));
    }

    #[test]
    fn rejects_nested_sections() {
        assert!(parse_toml("[a.b]\nx = 1").is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_toml("just a line").is_err());
        assert!(parse_toml("x = ").is_err());
        assert!(parse_toml("x = \"unterminated").is_err());
        assert!(parse_toml("[unclosed").is_err());
    }

    #[test]
    fn int_float_distinction() {
        let doc = parse_toml("a = 3\nb = 3.0").unwrap();
        assert_eq!(doc[""]["a"].as_int(), Some(3));
        assert_eq!(doc[""]["a"].as_float(), Some(3.0)); // int coerces to float
        assert_eq!(doc[""]["b"].as_int(), None);
        assert_eq!(doc[""]["b"].as_float(), Some(3.0));
    }

    #[test]
    fn empty_array() {
        let doc = parse_toml("a = []").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Array(vec![]));
    }
}
