//! Typed run configuration assembled from a TOML-lite document + CLI
//! overrides. This is the "real config system" a launcher consumes.

use super::toml_lite::{parse_toml, TomlDoc, TomlValue};
use crate::decomp::PartitionStrategy;
use crate::geometry::MetricKind;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which d-MST kernel workers run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// pure-Rust dense Prim
    PrimDense,
    /// dense Borůvka with the pure-Rust blocked step
    BoruvkaRust,
    /// dense Borůvka with the AOT-compiled Pallas/XLA step
    BoruvkaXla,
}

impl KernelChoice {
    pub fn name(&self) -> &'static str {
        match self {
            KernelChoice::PrimDense => "prim-dense",
            KernelChoice::BoruvkaRust => "boruvka-rust",
            KernelChoice::BoruvkaXla => "boruvka-xla",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "prim-dense" | "prim" => Some(Self::PrimDense),
            "boruvka-rust" | "rust" => Some(Self::BoruvkaRust),
            "boruvka-xla" | "xla" => Some(Self::BoruvkaXla),
            _ => None,
        }
    }
}

/// How each pair job `d-MST(S_i ∪ S_j)` is solved by the exec engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairKernelChoice {
    /// full dense d-MST over the gathered union (the paper-literal path and
    /// the exactness oracle); re-solves each subset's internal structure in
    /// every pair it appears in
    Dense,
    /// cycle-property kernel: cached per-partition local MSTs + filtered
    /// Prim over `MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i × S_j)`; exactly
    /// `n(n-1)/2` distance evaluations per run
    BipartiteMerge,
}

impl PairKernelChoice {
    pub fn name(&self) -> &'static str {
        match self {
            PairKernelChoice::Dense => "dense",
            PairKernelChoice::BipartiteMerge => "bipartite-merge",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "dense" | "pair-dense" => Some(Self::Dense),
            "bipartite-merge" | "bipartite" | "merge" => Some(Self::BipartiteMerge),
            _ => None,
        }
    }
}

/// Which transport moves leader↔worker bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportChoice {
    /// in-process simulated fabric: worker threads share memory, the byte
    /// model charges what the wire encoding *would* occupy
    Sim,
    /// real multi-process transport: one blocking TCP socket per
    /// leader↔worker link, counters fed by actual encoded frame sizes
    Tcp,
}

impl TransportChoice {
    pub fn name(&self) -> &'static str {
        match self {
            TransportChoice::Sim => "sim",
            TransportChoice::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sim" | "simulated" | "netsim" => Some(Self::Sim),
            "tcp" => Some(Self::Tcp),
            _ => None,
        }
    }
}

/// Where the ⊕-reduction of per-worker partial MSFs happens.
///
/// Requires `reduce_tree` (worker-local folding) — under gather mode every
/// pair tree already travels to the leader, so there is nothing for the
/// fleet to fold among itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceTopology {
    /// every worker's partial MSF travels to the leader, which folds them
    /// all (the v3 behaviour and the default)
    Leader,
    /// workers fold pairwise along a deterministic binomial-tree schedule;
    /// only the root worker's ≤ |V|−1-edge forest reaches the leader
    Tree,
    /// each worker folds into its next-higher-id alive neighbour in a
    /// chain; only the highest-id worker's forest reaches the leader
    Ring,
}

impl ReduceTopology {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceTopology::Leader => "leader",
            ReduceTopology::Tree => "tree",
            ReduceTopology::Ring => "ring",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "leader" => Some(Self::Leader),
            "tree" | "binomial" => Some(Self::Tree),
            "ring" | "chain" => Some(Self::Ring),
            _ => None,
        }
    }
}

/// Simulated network model parameters plus real-transport liveness knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// charge latency/bandwidth sleep time (off = count bytes only)
    pub simulate_delays: bool,
    /// one-way message latency, microseconds
    pub latency_us: u64,
    /// link bandwidth, bytes/second
    pub bandwidth: f64,
    /// tcp only: per-link read deadline, milliseconds. A link silent for
    /// this long is declared stalled and demoted through the return lane;
    /// the leader pulses header-only heartbeats every third of it so idle
    /// links stay provably alive. Must exceed the worst-case single pair
    /// job, since a computing worker sends nothing until its reply.
    /// 0 disables liveness (no deadline, no heartbeats).
    pub liveness_timeout_ms: u64,
    /// tcp only: per-attempt timeout for worker↔worker peer dials,
    /// milliseconds (tree fetch + fold links)
    pub peer_connect_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // 25 GbE-ish defaults when delay simulation is on
        Self {
            simulate_delays: false,
            latency_us: 20,
            bandwidth: 3.0e9,
            liveness_timeout_ms: 30_000,
            peer_connect_timeout_ms: 5_000,
        }
    }
}

/// Observability knobs: span tracing, exporters, metrics, live progress.
/// CLI equivalents: `--trace-out`, `--report-out`, `--metrics-listen`,
/// `--quiet`; `DEMST_LOG` controls the stderr log level separately (an env
/// concern, not config).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// record spans fleet-wide (workers ship theirs back on `WorkerDone`).
    /// Forced on when `trace_out` is set; off by default so the job hot
    /// path stays allocation-free.
    pub trace: bool,
    /// write the reassembled timeline as Chrome-trace/Perfetto JSON here
    pub trace_out: Option<PathBuf>,
    /// write the versioned machine-readable run report here
    pub report_out: Option<PathBuf>,
    /// record fleet metrics (counters/gauges/histograms): workers ship
    /// snapshot blocks on `WorkerDone` and periodic `MetricsPush` frames.
    /// Off by default so default byte models stay exact; implied by
    /// `metrics_listen` and `report_out`.
    pub metrics: bool,
    /// serve Prometheus text exposition on this address (e.g.
    /// `127.0.0.1:9399`) for the run's duration; implies `metrics`
    pub metrics_listen: Option<String>,
    /// minimum milliseconds between two `MetricsPush` frames per worker
    pub metrics_push_ms: u64,
    /// leader-side live progress ticker (auto-disabled when stderr is not
    /// a tty; `--quiet` forces it off)
    pub progress: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace: false,
            trace_out: None,
            report_out: None,
            metrics: false,
            metrics_listen: None,
            metrics_push_ms: 1_000,
            progress: true,
        }
    }
}

impl ObsConfig {
    /// Metrics are armed when asked for directly or implied by a consumer
    /// (the exposition endpoint, the run report's histograms section).
    pub fn metrics_armed(&self) -> bool {
        self.metrics || self.metrics_listen.is_some() || self.report_out.is_some()
    }
}

/// Dataset source configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    /// "blobs" | "uniform" | "embedding" | "shells" | "npy"
    pub kind: String,
    pub n: usize,
    pub d: usize,
    /// generator-specific knobs
    pub clusters: usize,
    pub std: f32,
    pub spread: f32,
    pub latent: usize,
    pub noise: f32,
    /// for kind = "npy"
    pub path: Option<PathBuf>,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            kind: "embedding".into(),
            n: 1024,
            d: 128,
            clusters: 16,
            std: 0.3,
            spread: 8.0,
            latent: 8,
            noise: 0.02,
            path: None,
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub data: DataConfig,
    /// |P| — partition count
    pub parts: usize,
    pub strategy: PartitionStrategy,
    pub metric: MetricKind,
    pub kernel: KernelChoice,
    /// worker threads (simulated ranks); 0 = one per pair job, capped at cores
    pub workers: usize,
    pub seed: u64,
    /// gather (paper default) vs tree-reduction variant
    pub reduce_tree: bool,
    /// where worker partial MSFs ⊕-fold: at the leader (default), or among
    /// the workers along a binomial-tree or ring schedule so only the final
    /// forest reaches the leader (requires `reduce_tree`)
    pub reduce_topology: ReduceTopology,
    /// peer-routed tree scatter: the building anchor of a subset forwards
    /// its cached local MST directly to the worker that needs it, and the
    /// leader ships a header-only routing flag instead of the payload.
    /// `None` = on exactly for sharded runs (where the leader link should
    /// carry no data bytes at all); see [`RunConfig::effective_peer_route`].
    pub peer_route: Option<bool>,
    /// pair-job kernel: dense oracle vs cached-local-MST bipartite merge
    pub pair_kernel: PairKernelChoice,
    /// subset-affinity scheduling (default on): jobs route to the anchor
    /// worker of their larger subset (per-worker decks, idle stealing), and
    /// the scatter model charges only subsets/trees the executing worker
    /// does not already hold. `false` restores the shared LPT queue and the
    /// dense ship-`S_i ∪ S_j`-every-job byte model, byte-for-byte.
    pub affinity: bool,
    /// streaming ⊕-reduction at the leader: fold each arriving tree into a
    /// bounded (≤ |V|-1 edge) running MSF instead of buffering the full
    /// `O(|V|·|P|)` union for one final Kruskal
    pub stream_reduce: bool,
    /// `sim` (default) or `tcp` — which transport carries leader↔worker
    /// traffic; `tcp` runs the identical engine against remote
    /// `demst worker` processes
    pub transport: TransportChoice,
    /// leader bind address for `transport = tcp` (e.g. "127.0.0.1:7000";
    /// port 0 picks a free port)
    pub listen: Option<String>,
    /// with `transport = tcp`: the leader spawns the `demst worker`
    /// processes itself (on this host, against the bound address) instead
    /// of waiting for externally started workers to connect
    pub spawn_workers: bool,
    /// sharded run: plan from this shard manifest (`demst partition`
    /// output) instead of a leader-resident dataset — workers hold the
    /// vectors (`demst worker --shard`), the leader never ingests them.
    /// Forces `transport = tcp`; overrides `parts`/`metric`/`data.{n,d}`
    /// from the manifest.
    pub shard_manifest: Option<PathBuf>,
    /// max pair jobs in flight per worker link before the leader awaits a
    /// reply (tcp only; 1 = strict rendezvous). Overlaps scatter with
    /// remote compute; replies stay FIFO per link, so the window cannot
    /// change which bytes travel — only when.
    pub pipeline_window: usize,
    /// SIMD dispatch for the bipartite panel kernels (default on). `false`
    /// forces the canonical scalar path — bit-identical output, used by the
    /// exactness tests and the CI scalar leg (`DEMST_SIMD=off` is the env
    /// equivalent and wins over this flag).
    pub panel_simd: bool,
    /// intra-job threads for one bipartite panel: 0 = all available cores
    /// at the worker (the default), else 1..=256. Bands are deterministic,
    /// so any count is bit-identical — this is purely a speed/oversubscribe
    /// knob.
    pub panel_threads: usize,
    pub net: NetConfig,
    /// observability: span tracing, trace/report exporters, live progress
    pub obs: ObsConfig,
    /// artifacts dir for the XLA kernel
    pub artifacts_dir: PathBuf,
    /// verify the result against an independent oracle after the run
    pub verify: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            data: DataConfig::default(),
            parts: 4,
            strategy: PartitionStrategy::RandomShuffle,
            metric: MetricKind::SqEuclid,
            kernel: KernelChoice::BoruvkaRust,
            workers: 0,
            seed: 42,
            reduce_tree: false,
            reduce_topology: ReduceTopology::Leader,
            peer_route: None,
            pair_kernel: PairKernelChoice::Dense,
            affinity: true,
            stream_reduce: false,
            transport: TransportChoice::Sim,
            listen: None,
            spawn_workers: false,
            shard_manifest: None,
            pipeline_window: 2,
            panel_simd: true,
            panel_threads: 0,
            net: NetConfig::default(),
            obs: ObsConfig::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            verify: false,
        }
    }
}

impl RunConfig {
    /// Load from a TOML-lite file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-lite text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        apply_doc(&mut cfg, &doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// The SIMD panel-kernel settings this config resolves to: runtime ISA
    /// detection unless `panel_simd = false` (or `DEMST_SIMD=off` in the
    /// environment), thread count from `panel_threads` (0 = all cores).
    pub fn panel_settings(&self) -> crate::geometry::PanelSettings {
        crate::geometry::PanelSettings::from_config(self.panel_simd, self.panel_threads)
    }

    /// Whether this run routes cached-tree scatter over peer links. The
    /// explicit `peer_route` setting wins; otherwise it defaults to **on
    /// for sharded runs** (their whole point is a data-free leader link)
    /// and off elsewhere.
    pub fn effective_peer_route(&self) -> bool {
        self.peer_route.unwrap_or(self.shard_manifest.is_some())
    }

    /// Check invariants; call after all overrides are applied.
    pub fn validate(&self) -> Result<()> {
        if self.parts == 0 {
            bail!("parts must be >= 1");
        }
        if self.data.n == 0 || self.data.d == 0 {
            bail!("data.n and data.d must be positive");
        }
        if self.parts > self.data.n && self.shard_manifest.is_none() {
            // sharded runs take parts and n from the manifest (a validated
            // partition of 0..n), not from these CLI/config defaults
            bail!("parts ({}) cannot exceed n ({})", self.parts, self.data.n);
        }
        if self.data.kind == "npy" && self.data.path.is_none() {
            bail!("data.kind = \"npy\" requires data.path");
        }
        if self.kernel == KernelChoice::BoruvkaXla
            && !matches!(self.metric, MetricKind::SqEuclid | MetricKind::Euclid)
        {
            bail!("the XLA kernel computes (squared) Euclidean distances only");
        }
        if self.net.bandwidth <= 0.0 {
            bail!("net.bandwidth must be positive");
        }
        if self.net.liveness_timeout_ms > u64::from(u32::MAX) {
            bail!(
                "net.liveness_timeout_ms must fit the u32 wire field (max {} ms)",
                u32::MAX
            );
        }
        if self.obs.metrics_push_ms > u64::from(u32::MAX) {
            bail!(
                "obs.metrics_push_ms must fit the u32 wire field (max {} ms)",
                u32::MAX
            );
        }
        if self.net.peer_connect_timeout_ms == 0 {
            bail!("net.peer_connect_timeout_ms must be positive");
        }
        if self.transport == TransportChoice::Tcp {
            // Catch distributed-run misconfigurations up front with one-line
            // errors instead of panics, hangs, or silently auto-sized fleets.
            if self.listen.is_none() {
                bail!("transport tcp requires --listen <addr> on the leader (workers connect with `demst worker --connect <addr>`)");
            }
            if self.workers == 0 {
                bail!("transport tcp requires an explicit worker count (--workers N): a remote fleet cannot be auto-sized from local cores");
            }
            if self.workers > u8::MAX as usize {
                bail!("transport tcp supports at most {} workers (wire v5 limit)", u8::MAX);
            }
            // Shape-dependent checks run against the shape that will
            // actually execute: the CLI/config one here, or the manifest's
            // (which overrides parts/d) inside `serve_sharded`.
            if self.shard_manifest.is_none() {
                self.validate_tcp_shape()?;
            }
        } else if self.spawn_workers {
            bail!("--spawn-workers only applies to --transport tcp");
        }
        if self.pipeline_window == 0 || self.pipeline_window > 64 {
            bail!("pipeline window must be in 1..=64 (got {})", self.pipeline_window);
        }
        if self.reduce_topology != ReduceTopology::Leader && !self.reduce_tree {
            bail!(
                "--reduce-topology {} requires --reduce-tree: under gather mode every pair tree already travels to the leader, so there are no worker partials to fold among the fleet",
                self.reduce_topology.name()
            );
        }
        if self.panel_threads > 256 {
            bail!(
                "panel_threads must be in 1..=256, or 0 for all available cores (got {})",
                self.panel_threads
            );
        }
        if self.shard_manifest.is_some() {
            // Sharded runs only make sense across process boundaries, and
            // the engine's capability scheduling rides on affinity decks.
            if self.transport != TransportChoice::Tcp {
                bail!("--shard requires --transport tcp (a sharded dataset lives on the worker hosts)");
            }
            if !self.affinity {
                bail!("--shard requires affinity scheduling (drop --no-affinity): sharded jobs must run where their subsets are resident");
            }
            if self.spawn_workers {
                bail!("--shard cannot be combined with --spawn-workers: start each worker with its own --shard-ids on the host holding those shard files");
            }
        }
        Ok(())
    }

    /// The `parts`/`d`-dependent tcp checks. `validate` runs them for
    /// leader-resident runs; sharded leaders call this again after
    /// overriding `parts`/`data.d` from the manifest (the CLI defaults
    /// they start from say nothing about the manifest's real shape).
    pub fn validate_tcp_shape(&self) -> Result<()> {
        if self.parts < 2 {
            bail!("transport tcp requires parts >= 2 (a single-subset run has nothing to distribute)");
        }
        // The engine caps workers at the pair-job count; accepting more
        // connections than it will drive would strand real worker
        // processes in their handshake timeout.
        let jobs = crate::decomp::pair_count(self.parts);
        if self.workers > jobs {
            bail!(
                "transport tcp with parts = {} has only {jobs} pair jobs; --workers {} would leave {} worker processes unused (reduce --workers or raise --parts)",
                self.parts,
                self.workers,
                self.workers - jobs
            );
        }
        // v3 wire limits (see net::wire): u16 subset indices / dimension,
        // u8 worker ids in per-job Result routing.
        if self.parts > u16::MAX as usize {
            bail!("transport tcp supports at most {} parts (wire v5 limit)", u16::MAX);
        }
        if self.data.d > u16::MAX as usize {
            bail!("transport tcp supports at most d = {} (wire v5 limit)", u16::MAX);
        }
        Ok(())
    }
}

fn apply_doc(cfg: &mut RunConfig, doc: &TomlDoc) -> Result<()> {
    for (section, kv) in doc {
        for (key, value) in kv {
            apply_kv(cfg, section, key, value)
                .with_context(|| format!("config key [{section}] {key}"))?;
        }
    }
    Ok(())
}

fn get_usize(v: &TomlValue) -> Result<usize> {
    let i = v.as_int().ok_or_else(|| anyhow!("expected integer"))?;
    usize::try_from(i).map_err(|_| anyhow!("expected non-negative integer"))
}

fn apply_kv(cfg: &mut RunConfig, section: &str, key: &str, v: &TomlValue) -> Result<()> {
    let need_str = || v.as_str().ok_or_else(|| anyhow!("expected string"));
    let need_f32 = || v.as_float().map(|f| f as f32).ok_or_else(|| anyhow!("expected number"));
    match (section, key) {
        ("", "name") => cfg.name = need_str()?.to_string(),
        ("", "parts") => cfg.parts = get_usize(v)?,
        ("", "workers") => cfg.workers = get_usize(v)?,
        ("", "seed") => cfg.seed = get_usize(v)? as u64,
        ("", "reduce_tree") => {
            cfg.reduce_tree = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("", "stream_reduce") => {
            cfg.stream_reduce = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("", "reduce_topology") => {
            cfg.reduce_topology = ReduceTopology::parse(need_str()?)
                .ok_or_else(|| anyhow!("unknown reduce topology (leader|tree|ring)"))?
        }
        ("", "peer_route") => {
            cfg.peer_route = Some(v.as_bool().ok_or_else(|| anyhow!("expected bool"))?)
        }
        ("", "pair_kernel") => {
            cfg.pair_kernel = PairKernelChoice::parse(need_str()?)
                .ok_or_else(|| anyhow!("unknown pair kernel"))?
        }
        ("", "affinity") => {
            cfg.affinity = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("", "transport") => {
            cfg.transport = TransportChoice::parse(need_str()?)
                .ok_or_else(|| anyhow!("unknown transport (sim|tcp)"))?
        }
        ("", "listen") => cfg.listen = Some(need_str()?.to_string()),
        ("", "spawn_workers") => {
            cfg.spawn_workers = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("", "shard_manifest") => cfg.shard_manifest = Some(PathBuf::from(need_str()?)),
        ("", "pipeline_window") => cfg.pipeline_window = get_usize(v)?,
        ("", "panel_simd") => {
            cfg.panel_simd = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("", "panel_threads") => cfg.panel_threads = get_usize(v)?,
        ("", "verify") => cfg.verify = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?,
        ("", "strategy") => {
            cfg.strategy = PartitionStrategy::parse(need_str()?)
                .ok_or_else(|| anyhow!("unknown strategy"))?
        }
        ("", "metric") => {
            cfg.metric =
                MetricKind::parse(need_str()?).ok_or_else(|| anyhow!("unknown metric"))?
        }
        ("", "kernel") => {
            cfg.kernel =
                KernelChoice::parse(need_str()?).ok_or_else(|| anyhow!("unknown kernel"))?
        }
        ("", "artifacts_dir") => cfg.artifacts_dir = PathBuf::from(need_str()?),
        ("data", "kind") => cfg.data.kind = need_str()?.to_string(),
        ("data", "n") => cfg.data.n = get_usize(v)?,
        ("data", "d") => cfg.data.d = get_usize(v)?,
        ("data", "clusters") => cfg.data.clusters = get_usize(v)?,
        ("data", "latent") => cfg.data.latent = get_usize(v)?,
        ("data", "std") => cfg.data.std = need_f32()?,
        ("data", "spread") => cfg.data.spread = need_f32()?,
        ("data", "noise") => cfg.data.noise = need_f32()?,
        ("data", "path") => cfg.data.path = Some(PathBuf::from(need_str()?)),
        ("net", "simulate_delays") => {
            cfg.net.simulate_delays = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("net", "latency_us") => cfg.net.latency_us = get_usize(v)? as u64,
        ("net", "bandwidth") => {
            cfg.net.bandwidth = v.as_float().ok_or_else(|| anyhow!("expected number"))?
        }
        ("net", "liveness_timeout_ms") => {
            cfg.net.liveness_timeout_ms = get_usize(v)? as u64
        }
        ("net", "peer_connect_timeout_ms") => {
            cfg.net.peer_connect_timeout_ms = get_usize(v)? as u64
        }
        ("obs", "trace") => cfg.obs.trace = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?,
        ("obs", "trace_out") => {
            cfg.obs.trace_out = Some(PathBuf::from(need_str()?));
            cfg.obs.trace = true; // an exporter without spans is useless
        }
        ("obs", "report_out") => cfg.obs.report_out = Some(PathBuf::from(need_str()?)),
        ("obs", "metrics") => {
            cfg.obs.metrics = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        ("obs", "metrics_listen") => cfg.obs.metrics_listen = Some(need_str()?.to_string()),
        ("obs", "metrics_push_ms") => cfg.obs.metrics_push_ms = get_usize(v)? as u64,
        ("obs", "progress") => {
            cfg.obs.progress = v.as_bool().ok_or_else(|| anyhow!("expected bool"))?
        }
        _ => bail!("unknown config key"),
    }
    Ok(())
}

/// Build the dataset described by the config.
pub fn build_dataset(cfg: &RunConfig) -> Result<(crate::data::Dataset, Option<Vec<u32>>)> {
    use crate::data::generators as g;
    use crate::util::prng::Pcg64;
    let rng = Pcg64::seeded(cfg.seed);
    let dc = &cfg.data;
    Ok(match dc.kind.as_str() {
        "blobs" => {
            let (ds, labels) = g::gaussian_blobs_labeled(
                &g::BlobSpec { n: dc.n, d: dc.d, k: dc.clusters, std: dc.std, spread: dc.spread },
                rng,
            );
            (ds, Some(labels))
        }
        "uniform" => (g::uniform(dc.n, dc.d, dc.spread, rng), None),
        "embedding" => {
            let (ds, labels) = g::embedding_like(
                &g::EmbeddingSpec {
                    n: dc.n,
                    d: dc.d,
                    latent: dc.latent,
                    k: dc.clusters,
                    cluster_std: dc.std,
                    noise: dc.noise,
                },
                rng,
            );
            (ds, Some(labels))
        }
        "shells" => {
            let (ds, labels) =
                g::concentric_shells(dc.n, dc.d, dc.spread * 0.2, dc.spread, dc.noise, rng);
            (ds, Some(labels))
        }
        "npy" => {
            let path = dc.path.as_ref().expect("validated");
            (crate::data::npy::read_npy(path)?, None)
        }
        "csv" => {
            let path = dc
                .path
                .as_ref()
                .ok_or_else(|| anyhow!("data.kind = \"csv\" requires data.path"))?;
            (crate::data::csv::read_csv(path)?, None)
        }
        other => bail!("unknown data.kind {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn full_toml_roundtrip() {
        let cfg = RunConfig::from_toml(
            r#"
name = "exp1"
parts = 6
workers = 4
seed = 7
strategy = "block"
metric = "euclid"
kernel = "prim-dense"
reduce_tree = true
verify = true

[data]
kind = "blobs"
n = 500
d = 32
clusters = 5
std = 0.25
spread = 4.0

[net]
simulate_delays = true
latency_us = 100
bandwidth = 1e9
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "exp1");
        assert_eq!(cfg.parts, 6);
        assert_eq!(cfg.strategy, PartitionStrategy::Block);
        assert_eq!(cfg.metric, MetricKind::Euclid);
        assert_eq!(cfg.kernel, KernelChoice::PrimDense);
        assert!(cfg.reduce_tree && cfg.verify);
        assert_eq!(cfg.data.n, 500);
        assert_eq!(cfg.net.latency_us, 100);
        assert_eq!(cfg.net.bandwidth, 1e9);
    }

    #[test]
    fn affinity_key_defaults_on_and_parses() {
        assert!(RunConfig::default().affinity, "affinity routing is the default");
        let cfg = RunConfig::from_toml("affinity = false").unwrap();
        assert!(!cfg.affinity);
        assert!(RunConfig::from_toml("affinity = 3").is_err());
    }

    #[test]
    fn pair_kernel_and_stream_reduce_keys() {
        let cfg = RunConfig::from_toml("pair_kernel = \"bipartite-merge\"\nstream_reduce = true")
            .unwrap();
        assert_eq!(cfg.pair_kernel, PairKernelChoice::BipartiteMerge);
        assert!(cfg.stream_reduce);
        assert_eq!(RunConfig::default().pair_kernel, PairKernelChoice::Dense);
        assert!(!RunConfig::default().stream_reduce);
        for (s, want) in [
            ("dense", PairKernelChoice::Dense),
            ("bipartite", PairKernelChoice::BipartiteMerge),
            (" Merge ", PairKernelChoice::BipartiteMerge),
        ] {
            assert_eq!(PairKernelChoice::parse(s), Some(want), "{s:?}");
        }
        assert_eq!(PairKernelChoice::parse("bogus"), None);
        assert!(RunConfig::from_toml("pair_kernel = \"bogus\"").is_err());
    }

    #[test]
    fn transport_keys_parse_and_validate_early() {
        assert_eq!(RunConfig::default().transport, TransportChoice::Sim);
        // a complete tcp leader config parses
        let cfg = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nspawn_workers = true",
        )
        .unwrap();
        assert_eq!(cfg.transport, TransportChoice::Tcp);
        assert_eq!(cfg.listen.as_deref(), Some("127.0.0.1:0"));
        assert!(cfg.spawn_workers);
        // each missing/invalid piece fails with a clear one-line error
        let e = RunConfig::from_toml("transport = \"tcp\"\nworkers = 2").unwrap_err();
        assert!(e.to_string().contains("--listen"), "{e:#}");
        let e = RunConfig::from_toml("transport = \"tcp\"\nlisten = \"127.0.0.1:0\"")
            .unwrap_err();
        assert!(e.to_string().contains("worker count"), "{e:#}");
        let e = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nparts = 1",
        )
        .unwrap_err();
        assert!(e.to_string().contains("parts >= 2"), "{e:#}");
        let e = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 300\nparts = 300",
        )
        .unwrap_err();
        assert!(e.to_string().contains("wire v5"), "{e:#}");
        // more workers than pair jobs would strand real processes
        let e = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nparts = 2",
        )
        .unwrap_err();
        assert!(e.to_string().contains("pair jobs"), "{e:#}");
        assert!(RunConfig::from_toml("transport = \"carrier-pigeon\"").is_err());
        // sim configs are untouched by the tcp-only requirements
        let sim = RunConfig::from_toml("workers = 0").unwrap();
        assert_eq!(sim.workers, 0, "workers = 0 still means auto under sim");
        let e = RunConfig::from_toml("spawn_workers = true").unwrap_err();
        assert!(e.to_string().contains("spawn-workers"), "{e:#}");
    }

    #[test]
    fn panel_keys_parse_and_validate_early() {
        let def = RunConfig::default();
        assert!(def.panel_simd, "SIMD panels are on by default");
        assert_eq!(def.panel_threads, 0, "0 means all available cores");
        let cfg = RunConfig::from_toml("panel_simd = false\npanel_threads = 4").unwrap();
        assert!(!cfg.panel_simd);
        assert_eq!(cfg.panel_threads, 4);
        // boundary values: 256 is the cap, 0 means auto
        RunConfig::from_toml("panel_threads = 256").unwrap();
        RunConfig::from_toml("panel_threads = 0").unwrap();
        let e = RunConfig::from_toml("panel_threads = 257").unwrap_err();
        assert!(e.to_string().contains("1..=256"), "{e:#}");
        // the resolved settings honour the off switch regardless of env
        let off = RunConfig::from_toml("panel_simd = false").unwrap();
        assert_eq!(off.panel_settings().isa, crate::geometry::Isa::Scalar);
        assert!(off.panel_settings().threads >= 1);
    }

    #[test]
    fn shard_and_window_keys_validate_early() {
        assert_eq!(RunConfig::default().pipeline_window, 2, "window defaults to 2");
        assert!(RunConfig::default().shard_manifest.is_none());
        let cfg = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nshard_manifest = \"emb.manifest.toml\"\npipeline_window = 1",
        )
        .unwrap();
        assert_eq!(cfg.shard_manifest.as_deref(), Some(std::path::Path::new("emb.manifest.toml")));
        assert_eq!(cfg.pipeline_window, 1);
        // window bounds
        for bad in ["pipeline_window = 0", "pipeline_window = 65"] {
            let e = RunConfig::from_toml(bad).unwrap_err();
            assert!(e.to_string().contains("pipeline window"), "{e:#}");
        }
        // a sharded config defers the parts-dependent checks to the
        // manifest's shape: a fleet larger than the *default* parts' pair
        // count must still parse (the manifest may have many more shards)
        let big = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 64\nshard_manifest = \"m.toml\"",
        )
        .unwrap();
        assert_eq!(big.workers, 64);
        // ... and the deferred check still fires once the real shape is in
        let mut shaped = big.clone();
        shaped.parts = 8; // pair_count = 28 < 64 workers
        let e = shaped.validate_tcp_shape().unwrap_err();
        assert!(e.to_string().contains("pair jobs"), "{e:#}");
        shaped.parts = 64; // 2016 jobs: fine
        shaped.validate_tcp_shape().unwrap();
        // sharding requires tcp, affinity, and external workers
        let e = RunConfig::from_toml("shard_manifest = \"m.toml\"").unwrap_err();
        assert!(e.to_string().contains("--transport tcp"), "{e:#}");
        let e = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nshard_manifest = \"m.toml\"\naffinity = false",
        )
        .unwrap_err();
        assert!(e.to_string().contains("affinity"), "{e:#}");
        let e = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nshard_manifest = \"m.toml\"\nspawn_workers = true",
        )
        .unwrap_err();
        assert!(e.to_string().contains("spawn-workers"), "{e:#}");
    }

    #[test]
    fn reduce_topology_and_peer_route_keys() {
        let def = RunConfig::default();
        assert_eq!(def.reduce_topology, ReduceTopology::Leader);
        assert_eq!(def.peer_route, None);
        assert!(!def.effective_peer_route(), "unsharded default: leader-shipped trees");
        let cfg =
            RunConfig::from_toml("reduce_tree = true\nreduce_topology = \"ring\"").unwrap();
        assert_eq!(cfg.reduce_topology, ReduceTopology::Ring);
        let cfg =
            RunConfig::from_toml("reduce_tree = true\nreduce_topology = \"binomial\"").unwrap();
        assert_eq!(cfg.reduce_topology, ReduceTopology::Tree);
        // topologies need worker-local folding to have partials to fold
        let e = RunConfig::from_toml("reduce_topology = \"tree\"").unwrap_err();
        assert!(e.to_string().contains("--reduce-tree"), "{e:#}");
        assert!(RunConfig::from_toml("reduce_topology = \"star\"").is_err());
        // peer_route: explicit setting wins, None keys off shard_manifest
        let cfg = RunConfig::from_toml("peer_route = true").unwrap();
        assert_eq!(cfg.peer_route, Some(true));
        assert!(cfg.effective_peer_route());
        let cfg = RunConfig::from_toml(
            "transport = \"tcp\"\nlisten = \"127.0.0.1:0\"\nworkers = 2\nshard_manifest = \"m.toml\"",
        )
        .unwrap();
        assert!(cfg.effective_peer_route(), "sharded runs peer-route by default");
        let mut off = cfg.clone();
        off.peer_route = Some(false);
        assert!(!off.effective_peer_route());
        for (s, want) in
            [("leader", ReduceTopology::Leader), (" Ring ", ReduceTopology::Ring)]
        {
            assert_eq!(ReduceTopology::parse(s), Some(want), "{s:?}");
        }
        assert_eq!(ReduceTopology::parse("bogus"), None);
    }

    #[test]
    fn liveness_keys_parse_and_validate_early() {
        let def = RunConfig::default();
        assert_eq!(def.net.liveness_timeout_ms, 30_000, "liveness defaults to 30 s");
        assert_eq!(def.net.peer_connect_timeout_ms, 5_000, "peer dials default to 5 s");
        let cfg = RunConfig::from_toml(
            "[net]\nliveness_timeout_ms = 2000\npeer_connect_timeout_ms = 250",
        )
        .unwrap();
        assert_eq!(cfg.net.liveness_timeout_ms, 2000);
        assert_eq!(cfg.net.peer_connect_timeout_ms, 250);
        // 0 disables liveness entirely (no deadlines, no heartbeats)
        let off = RunConfig::from_toml("[net]\nliveness_timeout_ms = 0").unwrap();
        assert_eq!(off.net.liveness_timeout_ms, 0);
        // the wire carries liveness as u32 milliseconds
        let e = RunConfig::from_toml("[net]\nliveness_timeout_ms = 5000000000").unwrap_err();
        assert!(e.to_string().contains("u32 wire field"), "{e:#}");
        // a zero dial timeout would make every peer connect fail instantly
        let e = RunConfig::from_toml("[net]\npeer_connect_timeout_ms = 0").unwrap_err();
        assert!(e.to_string().contains("peer_connect_timeout_ms"), "{e:#}");
        assert!(RunConfig::from_toml("[net]\nliveness_timeout_ms = \"soon\"").is_err());
    }

    #[test]
    fn obs_keys_parse_and_default_quiet() {
        let def = RunConfig::default();
        assert!(!def.obs.trace, "tracing is off by default (hot path stays allocation-free)");
        assert!(def.obs.trace_out.is_none() && def.obs.report_out.is_none());
        assert!(def.obs.progress, "progress ticker defaults on (tty-gated at print time)");
        let cfg = RunConfig::from_toml(
            "[obs]\ntrace_out = \"trace.json\"\nreport_out = \"run.json\"\nprogress = false",
        )
        .unwrap();
        assert!(cfg.obs.trace, "trace_out implies span recording");
        assert_eq!(cfg.obs.trace_out.as_deref(), Some(std::path::Path::new("trace.json")));
        assert_eq!(cfg.obs.report_out.as_deref(), Some(std::path::Path::new("run.json")));
        assert!(!cfg.obs.progress);
        // trace can be enabled alone (spans land in RunMetrics, no file)
        let rec = RunConfig::from_toml("[obs]\ntrace = true").unwrap();
        assert!(rec.obs.trace && rec.obs.trace_out.is_none());
        assert!(RunConfig::from_toml("[obs]\ntrace = 3").is_err());
        assert!(RunConfig::from_toml("[obs]\nbogus = 1").is_err());
    }

    #[test]
    fn metrics_keys_parse_and_arm_correctly() {
        let def = RunConfig::default();
        assert!(!def.obs.metrics && def.obs.metrics_listen.is_none());
        assert_eq!(def.obs.metrics_push_ms, 1_000, "push cadence defaults to 1 s");
        assert!(!def.obs.metrics_armed(), "metrics off by default keeps byte models exact");
        let cfg = RunConfig::from_toml(
            "[obs]\nmetrics_listen = \"127.0.0.1:9399\"\nmetrics_push_ms = 250",
        )
        .unwrap();
        assert_eq!(cfg.obs.metrics_listen.as_deref(), Some("127.0.0.1:9399"));
        assert_eq!(cfg.obs.metrics_push_ms, 250);
        assert!(cfg.obs.metrics_armed(), "an exposition endpoint implies metrics");
        let rep = RunConfig::from_toml("[obs]\nreport_out = \"run.json\"").unwrap();
        assert!(rep.obs.metrics_armed(), "the report's histograms section implies metrics");
        let on = RunConfig::from_toml("[obs]\nmetrics = true").unwrap();
        assert!(on.obs.metrics_armed());
        // the wire carries the push cadence as u32 milliseconds
        let e = RunConfig::from_toml("[obs]\nmetrics_push_ms = 5000000000").unwrap_err();
        assert!(e.to_string().contains("u32 wire field"), "{e:#}");
        assert!(RunConfig::from_toml("[obs]\nmetrics = 3").is_err());
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_toml("bogus_key = 3").is_err());
        assert!(RunConfig::from_toml("[bogus]\nx = 3").is_err());
    }

    #[test]
    fn rejects_invalid_combinations() {
        assert!(RunConfig::from_toml("parts = 0").is_err());
        let r = RunConfig::from_toml("kernel = \"xla\"\nmetric = \"cosine\"");
        assert!(r.is_err(), "xla kernel + cosine must be rejected");
        let r = RunConfig::from_toml("[data]\nkind = \"npy\"");
        assert!(r.is_err(), "npy without path must be rejected");
    }

    #[test]
    fn build_dataset_kinds() {
        for kind in ["blobs", "uniform", "embedding", "shells"] {
            let mut cfg = RunConfig::default();
            cfg.data.kind = kind.into();
            cfg.data.n = 64;
            cfg.data.d = 16;
            cfg.data.latent = 4;
            cfg.data.clusters = 4;
            let (ds, _) = build_dataset(&cfg).unwrap();
            assert_eq!((ds.n, ds.d), (64, 16), "{kind}");
        }
    }

    #[test]
    fn parts_exceeding_n_rejected() {
        let r = RunConfig::from_toml("parts = 100\n[data]\nkind = \"uniform\"\nn = 10\nd = 2");
        assert!(r.is_err());
    }
}
