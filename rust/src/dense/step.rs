//! The Borůvka cheapest-edge step: the `O(N²D)` compute hot-spot.
//!
//! `step(points, comps)` returns, for every valid vertex `i`, the distance
//! (in the metric's *comparison form* — squared for (sq-)Euclidean) and index
//! of the closest vertex in a *different* component. Vertices with
//! `comps[i] < 0` are padding and ignored (they report `(+inf, -1)` and
//! never appear as neighbors).
//!
//! Tie-break contract: among equal distances the **smallest index j** wins.
//! As proven in `boruvka_dense::tests::smallest_j_matches_strict_order`, this
//! per-row rule coincides with the crate's strict `(w, u, v)` edge order, so
//! any provider honoring it yields the unique MST.
//!
//! Providers:
//! - [`RustStep`] — blocked distance rows via the metric-generic
//!   [`DistanceBlock`] kernels (Gram/dot form, pure Rust); any metric.
//! - `runtime::XlaStep` — the AOT-compiled Pallas kernel via PJRT
//!   (`backend-xla` feature; squared Euclidean only).

use crate::geometry::blocked::{distance_block, DistanceBlock};
use crate::geometry::MetricKind;

/// Provider of the cheapest-edge step. Not `Send`/`Sync` — the XLA provider
/// owns thread-local PJRT handles; build one per worker thread.
pub trait CheapestEdgeStep {
    /// `points`: row-major `(n, d)`. `comps[i] < 0` marks padding.
    /// Returns `(dist, idx)` of length `n` each: for valid `i`, the closest
    /// `j` with `comps[j] >= 0 && comps[j] != comps[i]` (smallest `j` on
    /// ties), or `(+inf, -1)` if no such `j` (single component / padding).
    fn step(&self, points: &[f32], n: usize, d: usize, comps: &[i32]) -> (Vec<f32>, Vec<i32>);

    /// Name for reporting.
    fn name(&self) -> &'static str;

    /// Metric whose comparison form the distances are in.
    fn metric(&self) -> MetricKind {
        MetricKind::SqEuclid
    }

    /// Distance evaluations charged per call (for E2 work accounting):
    /// valid_n², since the kernel computes the full masked matrix.
    fn evals_per_call(&self, valid_n: u64) -> u64 {
        valid_n * valid_n
    }
}

/// Pure-Rust provider: consumes blocked `(row × tile)` distance rows from
/// the metric-generic [`DistanceBlock`] kernels.
pub struct RustStep {
    /// column-block size for the distance tiles
    pub block: usize,
    metric: MetricKind,
    dist: Box<dyn DistanceBlock>,
}

impl RustStep {
    /// Blocked provider for any metric (default tile width).
    pub fn new(metric: MetricKind) -> Self {
        Self::with_block(metric, 64)
    }

    /// Blocked provider with an explicit column-tile width.
    pub fn with_block(metric: MetricKind, block: usize) -> Self {
        Self { block: block.max(1), metric, dist: distance_block(metric) }
    }
}

impl Default for RustStep {
    fn default() -> Self {
        Self::new(MetricKind::SqEuclid)
    }
}

impl CheapestEdgeStep for RustStep {
    fn step(&self, points: &[f32], n: usize, d: usize, comps: &[i32]) -> (Vec<f32>, Vec<i32>) {
        debug_assert_eq!(points.len(), n * d);
        debug_assert_eq!(comps.len(), n);
        let aux = self.dist.prepare(points, n, d);
        let mut dist = vec![f32::INFINITY; n];
        let mut idx = vec![-1i32; n];
        let b = self.block;
        // Perf note (EXPERIMENTS.md §Perf): column blocking keeps the b-rows
        // tile cache-resident across the i loop; the mask is applied on the
        // scan of the computed row (like the masked Pallas kernel computes
        // the full matrix), keeping the inner distance loop branch-free.
        let mut js: Vec<u32> = Vec::with_capacity(b);
        let mut row = vec![0.0f32; b];
        for j0 in (0..n).step_by(b) {
            let jm = (j0 + b).min(n);
            js.clear();
            js.extend(j0 as u32..jm as u32);
            for i in 0..n {
                let ci = comps[i];
                if ci < 0 {
                    continue;
                }
                self.dist.row(points, d, &aux, i, &js, &mut row[..js.len()]);
                let (mut bd, mut bj) = (dist[i], idx[i]);
                for (k, &j) in js.iter().enumerate() {
                    let cj = comps[j as usize];
                    if cj < 0 || cj == ci {
                        continue;
                    }
                    let v = row[k];
                    // strictly-less keeps the smallest j on ties because j
                    // increases monotonically within and across blocks
                    if v < bd {
                        bd = v;
                        bj = j as i32;
                    }
                }
                dist[i] = bd;
                idx[i] = bj;
            }
        }
        (dist, idx)
    }

    fn name(&self) -> &'static str {
        "rust-blocked"
    }

    fn metric(&self) -> MetricKind {
        self.metric
    }
}

/// Reference (unblocked, direct) provider used only in tests to validate the
/// blocked/XLA providers. Squared Euclidean.
pub struct NaiveStep;

impl CheapestEdgeStep for NaiveStep {
    fn step(&self, points: &[f32], n: usize, d: usize, comps: &[i32]) -> (Vec<f32>, Vec<i32>) {
        use crate::geometry::metric::sq_euclid;
        let mut dist = vec![f32::INFINITY; n];
        let mut idx = vec![-1i32; n];
        for i in 0..n {
            if comps[i] < 0 {
                continue;
            }
            for j in 0..n {
                if comps[j] < 0 || comps[j] == comps[i] {
                    continue;
                }
                let w = sq_euclid(&points[i * d..(i + 1) * d], &points[j * d..(j + 1) * d]);
                if w < dist[i] {
                    dist[i] = w;
                    idx[i] = j as i32;
                }
            }
        }
        (dist, idx)
    }

    fn name(&self) -> &'static str {
        "naive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::metric::{cosine, manhattan};
    use crate::util::prng::Pcg64;

    /// Integer-valued coordinates so matmul-form distances are exact and the
    /// blocked provider must agree with naive bit-for-bit.
    fn int_points(rng: &mut Pcg64, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.next_bounded(17) as f32 - 8.0).collect()
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg64::seeded(31);
        for &(n, d, block) in &[(10usize, 3usize, 4usize), (33, 7, 8), (65, 2, 64), (20, 5, 100)] {
            let pts = int_points(&mut rng, n, d);
            let comps: Vec<i32> = (0..n).map(|i| (i % 5) as i32).collect();
            let (d1, i1) = NaiveStep.step(&pts, n, d, &comps);
            let (d2, i2) = RustStep::with_block(MetricKind::SqEuclid, block).step(&pts, n, d, &comps);
            assert_eq!(i1, i2, "n={n} d={d} block={block}");
            assert_eq!(d1, d2);
        }
    }

    #[test]
    fn padding_rows_ignored() {
        let mut rng = Pcg64::seeded(32);
        let (n, d) = (12, 4);
        let pts = int_points(&mut rng, n, d);
        let mut comps: Vec<i32> = (0..n).map(|i| (i % 3) as i32).collect();
        comps[3] = -1;
        comps[7] = -1;
        let (dist, idx) = RustStep::default().step(&pts, n, d, &comps);
        assert_eq!(dist[3], f32::INFINITY);
        assert_eq!(idx[3], -1);
        assert!(idx.iter().all(|&j| j != 3 && j != 7), "padding never selected");
    }

    #[test]
    fn single_component_reports_inf() {
        let pts = vec![0.0, 1.0, 2.0, 3.0];
        let comps = vec![0, 0];
        let (dist, idx) = RustStep::default().step(&pts, 2, 2, &comps);
        assert_eq!(dist, vec![f32::INFINITY; 2]);
        assert_eq!(idx, vec![-1; 2]);
    }

    #[test]
    fn smallest_j_on_exact_ties() {
        // Vertex 0 at origin; vertices 1 and 2 equidistant.
        let pts = vec![
            0.0, 0.0, // v0, comp 0
            1.0, 0.0, // v1, comp 1
            0.0, 1.0, // v2, comp 1
        ];
        let comps = vec![0, 1, 1];
        for provider in [&NaiveStep as &dyn CheapestEdgeStep, &RustStep::default()] {
            let (_, idx) = provider.step(&pts, 3, 2, &comps);
            assert_eq!(idx[0], 1, "{}: smallest j wins tie", provider.name());
        }
    }

    #[test]
    fn metric_generic_step_matches_direct_scan() {
        // For cosine and manhattan, compare the blocked provider to a direct
        // O(n²) scan using the scalar distance functions (integer coords:
        // both paths are float-exact).
        let mut rng = Pcg64::seeded(33);
        let (n, d) = (40, 6);
        let pts = int_points(&mut rng, n, d);
        let comps: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
        for kind in [MetricKind::Cosine, MetricKind::Manhattan] {
            let (gd, gi) = RustStep::with_block(kind, 16).step(&pts, n, d, &comps);
            let mut wd = vec![f32::INFINITY; n];
            let mut wi = vec![-1i32; n];
            for i in 0..n {
                for j in 0..n {
                    if comps[j] == comps[i] {
                        continue;
                    }
                    let w = match kind {
                        MetricKind::Cosine => {
                            cosine(&pts[i * d..(i + 1) * d], &pts[j * d..(j + 1) * d])
                        }
                        _ => manhattan(&pts[i * d..(i + 1) * d], &pts[j * d..(j + 1) * d]),
                    };
                    if w < wd[i] {
                        wd[i] = w;
                        wi[i] = j as i32;
                    }
                }
            }
            assert_eq!(gi, wi, "{kind:?} indices");
            assert_eq!(gd, wd, "{kind:?} distances");
        }
    }

    #[test]
    fn step_reports_its_metric() {
        assert_eq!(RustStep::default().metric(), MetricKind::SqEuclid);
        assert_eq!(RustStep::new(MetricKind::Cosine).metric(), MetricKind::Cosine);
        assert_eq!(NaiveStep.metric(), MetricKind::SqEuclid);
    }
}
