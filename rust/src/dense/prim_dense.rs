//! Dense Prim: `O(n²)` time, `O(n)` memory MST of the complete graph.
//!
//! The textbook dense formulation: keep, for every vertex not yet in the
//! tree, its cheapest edge into the tree; each round admit the global
//! cheapest frontier vertex and relax the rest with one distance evaluation
//! per vertex. Exactly `n(n-1)/2` distance evaluations — the work unit that
//! experiment E2's `2(|P|-1)/|P|` overhead ratio is measured in.
//!
//! Two implementations share that structure:
//! - [`PrimDense`] — the hot path. Each round's relaxation consumes a
//!   *blocked distance row* from the metric-generic [`DistanceBlock`]
//!   kernels (Gram/dot form with precomputed norms for sq-Euclid/cosine, a
//!   tiled direct loop for Manhattan) instead of `n` virtual
//!   `Metric::dist` calls. Same `(w, u, v)` strict tie-break, same
//!   evaluation count, measurably faster at `d ≥ 64` (see bench E7).
//! - [`PrimScalar`] — the original scalar-`Metric` formulation, kept as the
//!   bit-for-bit oracle of the strict edge order and as the baseline the E7
//!   bench compares the blocked path against.

use super::DenseMst;
use crate::data::Dataset;
use crate::geometry::blocked::{distance_block, DistanceBlock};
use crate::geometry::{CountingMetric, Metric, MetricKind};
use crate::graph::Edge;
use crate::util::fkey::edge_cmp;

/// Pure-Rust dense Prim d-MST kernel over any metric, blocked hot path.
pub struct PrimDense {
    metric: CountingMetric,
    block: Box<dyn DistanceBlock>,
}

impl PrimDense {
    pub fn new(kind: MetricKind) -> Self {
        Self { metric: CountingMetric::new(kind), block: distance_block(kind) }
    }

    /// Squared-Euclidean kernel (the high-dimensional-embedding default; the
    /// monotone map x→x² preserves the MST vs true Euclidean).
    pub fn sq_euclid() -> Self {
        Self::new(MetricKind::SqEuclid)
    }

    /// Share this kernel's metric counter (e.g. to aggregate across workers).
    pub fn metric(&self) -> &CountingMetric {
        &self.metric
    }
}

impl DenseMst for PrimDense {
    fn mst(&self, ds: &Dataset) -> Vec<Edge> {
        let n = ds.n;
        let mut tree = Vec::with_capacity(n.saturating_sub(1));
        if n <= 1 {
            return tree;
        }
        // e.g. Euclid: rows compare in squared form, sqrt at edge emission
        let sqrt_at_emit = self.block.compare_form_is_squared();
        let data = ds.as_slice();
        let aux = self.block.prepare(data, n, ds.d);
        // best[i] = (weight, tree-endpoint) of i's cheapest edge into the tree
        let mut best_w = vec![f32::INFINITY; n];
        let mut best_to = vec![0u32; n];
        // vertices not yet in the tree (order is irrelevant: the strict
        // (w, u, v) order makes the per-round minimum unique)
        let mut active: Vec<u32> = (1..n as u32).collect();
        let mut row = vec![0.0f32; n];

        // Initial row: distances from the root (vertex 0) to everything else.
        self.block.row(data, ds.d, &aux, 0, &active, &mut row);
        self.metric.add_external(active.len() as u64);
        for (k, &i) in active.iter().enumerate() {
            best_w[i as usize] = row[k];
            best_to[i as usize] = 0;
        }

        for _round in 1..n {
            // pick frontier vertex with min (w, u, v) strict edge order
            let mut pick_at = usize::MAX;
            for (k, &i) in active.iter().enumerate() {
                let i = i as usize;
                if pick_at == usize::MAX {
                    pick_at = k;
                    continue;
                }
                let p = active[pick_at] as usize;
                if edge_cmp(
                    best_w[i],
                    best_to[i].min(i as u32),
                    best_to[i].max(i as u32),
                    best_w[p],
                    best_to[p].min(p as u32),
                    best_to[p].max(p as u32),
                ) == std::cmp::Ordering::Less
                {
                    pick_at = k;
                }
            }
            debug_assert_ne!(pick_at, usize::MAX);
            let pick = active.swap_remove(pick_at) as usize;
            let picked_w = if sqrt_at_emit { best_w[pick].sqrt() } else { best_w[pick] };
            tree.push(Edge::new(best_to[pick], pick as u32, picked_w));
            if active.is_empty() {
                break;
            }
            // relax: one blocked distance row pivot -> all active vertices
            self.block.row(data, ds.d, &aux, pick, &active, &mut row);
            self.metric.add_external(active.len() as u64);
            for (k, &i) in active.iter().enumerate() {
                let i = i as usize;
                let w = row[k];
                if edge_cmp(
                    w,
                    (pick as u32).min(i as u32),
                    (pick as u32).max(i as u32),
                    best_w[i],
                    best_to[i].min(i as u32),
                    best_to[i].max(i as u32),
                ) == std::cmp::Ordering::Less
                {
                    best_w[i] = w;
                    best_to[i] = pick as u32;
                }
            }
        }
        tree
    }

    fn name(&self) -> &'static str {
        "prim-dense"
    }

    fn dist_evals(&self) -> u64 {
        self.metric.evals()
    }

    fn reset_counters(&self) {
        self.metric.reset();
    }
}

/// The original scalar-metric dense Prim: one virtual `Metric::dist` call
/// per relaxation. Oracle for the blocked path and the E7 baseline.
pub struct PrimScalar {
    metric: CountingMetric,
}

impl PrimScalar {
    pub fn new(kind: MetricKind) -> Self {
        Self { metric: CountingMetric::new(kind) }
    }

    pub fn sq_euclid() -> Self {
        Self::new(MetricKind::SqEuclid)
    }
}

impl DenseMst for PrimScalar {
    fn mst(&self, ds: &Dataset) -> Vec<Edge> {
        let n = ds.n;
        let mut tree = Vec::with_capacity(n.saturating_sub(1));
        if n <= 1 {
            return tree;
        }
        let mut best_w = vec![f32::INFINITY; n];
        let mut best_to = vec![0u32; n];
        let mut in_tree = vec![false; n];
        in_tree[0] = true;
        for i in 1..n {
            best_w[i] = self.metric.dist(ds.row(0), ds.row(i));
            best_to[i] = 0;
        }
        for _round in 1..n {
            let mut pick = usize::MAX;
            for i in 0..n {
                if in_tree[i] {
                    continue;
                }
                if pick == usize::MAX
                    || edge_cmp(
                        best_w[i],
                        best_to[i].min(i as u32),
                        best_to[i].max(i as u32),
                        best_w[pick],
                        best_to[pick].min(pick as u32),
                        best_to[pick].max(pick as u32),
                    ) == std::cmp::Ordering::Less
                {
                    pick = i;
                }
            }
            debug_assert_ne!(pick, usize::MAX);
            in_tree[pick] = true;
            tree.push(Edge::new(best_to[pick], pick as u32, best_w[pick]));
            let prow = ds.row(pick);
            for i in 0..n {
                if in_tree[i] {
                    continue;
                }
                let w = self.metric.dist(prow, ds.row(i));
                if edge_cmp(
                    w,
                    (pick as u32).min(i as u32),
                    (pick as u32).max(i as u32),
                    best_w[i],
                    best_to[i].min(i as u32),
                    best_to[i].max(i as u32),
                ) == std::cmp::Ordering::Less
                {
                    best_w[i] = w;
                    best_to[i] = pick as u32;
                }
            }
        }
        tree
    }

    fn name(&self) -> &'static str {
        "prim-scalar"
    }

    fn dist_evals(&self) -> u64 {
        self.metric.evals()
    }

    fn reset_counters(&self) {
        self.metric.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    /// Integer coordinates: Gram-form and direct-difference distances are
    /// bit-identical, so the blocked and scalar kernels must agree exactly.
    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(21) as f32 - 10.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn trivial_sizes() {
        let k = PrimDense::sq_euclid();
        assert!(k.mst(&Dataset::zeros(0, 3)).is_empty());
        assert!(k.mst(&Dataset::zeros(1, 3)).is_empty());
        let two = Dataset::new(2, 1, vec![0.0, 3.0]);
        let t = k.mst(&two);
        assert_eq!(t, vec![Edge::new(0, 1, 9.0)]);
    }

    #[test]
    fn spanning_and_deterministic() {
        let ds = uniform(60, 8, 1.0, Pcg64::seeded(8));
        let k = PrimDense::sq_euclid();
        let t1 = k.mst(&ds);
        let t2 = k.mst(&ds);
        assert!(is_spanning_tree(ds.n, &t1));
        assert_eq!(t1, t2);
    }

    #[test]
    fn collinear_points_chain() {
        // Points on a line: MST must be the consecutive chain.
        let ds = Dataset::new(5, 1, vec![0.0, 10.0, 1.0, 11.0, 2.0]);
        let k = PrimDense::sq_euclid();
        let t = k.mst(&ds);
        let mut ws: Vec<f32> = t.iter().map(|e| e.w).collect();
        ws.sort_by(f32::total_cmp);
        // consecutive gaps: (0,2)=1, (2,4)=1, (1,3)=1, (4,1)=64 -> sq weights 1,1,1,64
        assert_eq!(ws, vec![1.0, 1.0, 1.0, 64.0]);
    }

    #[test]
    fn work_count_is_exactly_n_choose_2_plus_frontier() {
        // n-1 initial + sum_{k=1}^{n-1} (n-1-k) relaxations
        // = (n-1) + (n-1)(n-2)/2 = n(n-1)/2 — preserved by the blocked path
        // via CountingMetric::add_external per row.
        let n = 33;
        let ds = uniform(n, 4, 1.0, Pcg64::seeded(12));
        for kernel in [
            Box::new(PrimDense::sq_euclid()) as Box<dyn DenseMst>,
            Box::new(PrimScalar::sq_euclid()),
        ] {
            kernel.mst(&ds);
            assert_eq!(kernel.dist_evals(), (n * (n - 1) / 2) as u64, "{}", kernel.name());
            kernel.reset_counters();
            assert_eq!(kernel.dist_evals(), 0);
        }
    }

    #[test]
    fn other_metrics_give_spanning_trees() {
        let ds = uniform(24, 5, 1.0, Pcg64::seeded(14));
        for kind in [MetricKind::Euclid, MetricKind::Cosine, MetricKind::Manhattan] {
            let k = PrimDense::new(kind);
            let t = k.mst(&ds);
            assert!(is_spanning_tree(ds.n, &t), "{kind:?}");
        }
    }

    #[test]
    fn euclid_and_sqeuclid_same_structure() {
        let ds = uniform(40, 6, 2.0, Pcg64::seeded(15));
        let a = PrimDense::new(MetricKind::Euclid).mst(&ds);
        let b = PrimDense::new(MetricKind::SqEuclid).mst(&ds);
        let ea: Vec<(u32, u32)> = crate::mst::normalize_tree(&a).iter().map(|e| (e.u, e.v)).collect();
        let eb: Vec<(u32, u32)> = crate::mst::normalize_tree(&b).iter().map(|e| (e.u, e.v)).collect();
        assert_eq!(ea, eb, "monotone transform preserves MST structure");
    }

    #[test]
    fn euclid_weights_are_sqrt_of_sqeuclid() {
        let ds = int_dataset(50, 30, 4);
        let a = normalize_tree(&PrimDense::new(MetricKind::Euclid).mst(&ds));
        let b = normalize_tree(&PrimDense::new(MetricKind::SqEuclid).mst(&ds));
        for (ea, eb) in a.iter().zip(&b) {
            assert_eq!((ea.u, ea.v), (eb.u, eb.v));
            assert_eq!(ea.w, eb.w.sqrt(), "({},{})", ea.u, ea.v);
        }
    }

    #[test]
    fn blocked_matches_scalar_every_metric() {
        // The load-bearing refactor invariant: the blocked hot path emits the
        // identical canonical tree as the scalar-oracle formulation.
        for (seed, n, d) in [(1u64, 2usize, 3usize), (2, 17, 1), (3, 40, 8), (4, 64, 16)] {
            let ds = int_dataset(seed, n, d);
            for kind in [
                MetricKind::SqEuclid,
                MetricKind::Euclid,
                MetricKind::Cosine,
                MetricKind::Manhattan,
            ] {
                let blocked = PrimDense::new(kind).mst(&ds);
                let scalar = PrimScalar::new(kind).mst(&ds);
                assert_eq!(
                    normalize_tree(&blocked),
                    normalize_tree(&scalar),
                    "{kind:?} seed={seed} n={n} d={d}"
                );
            }
        }
    }
}
