//! Dense ("d-MST") kernels: exact MSTs of the *complete* graph over a vector
//! set, the subkernel the paper's Algorithm 1 calls per partition pair.
//!
//! Three implementations:
//! - [`PrimDense`] — `O(n²)` dense Prim whose relaxation consumes blocked
//!   distance rows from the metric-generic
//!   [`DistanceBlock`](crate::geometry::DistanceBlock) kernels. The default
//!   hot path for every metric.
//! - [`PrimScalar`] — the scalar-`Metric` Prim formulation: the bit-exact
//!   oracle for the blocked path and the E7 baseline.
//! - [`BoruvkaDense`] — Borůvka rounds where the `O(n²d)` cheapest-edge step
//!   is delegated to a [`CheapestEdgeStep`] provider: the pure-Rust blocked
//!   provider here, or (with `--features backend-xla`) the XLA executable
//!   provider in [`crate::runtime`] — the L1 Pallas kernel lowered AOT. This
//!   is the paper's "existing high performance kernel ... without
//!   adjustment" slot.
//!
//! All implementations observe the crate-wide strict edge order, so they all
//! produce the identical unique MST (Theorem 1's uniqueness assumption).

pub mod prim_dense;
pub mod step;
pub mod boruvka_dense;

pub use boruvka_dense::BoruvkaDense;
pub use prim_dense::{PrimDense, PrimScalar};
pub use step::{CheapestEdgeStep, RustStep};

use crate::data::Dataset;
use crate::graph::Edge;

/// A dense-MST kernel: forms the MST of the complete graph over `ds`'s
/// vectors with edge weights given by the kernel's distance function.
/// Returned edges use local indices `0..ds.n`.
///
/// Deliberately **not** `Send`/`Sync`: the XLA-backed kernel wraps PJRT
/// handles (raw pointers). Each worker thread builds its own kernel, which
/// mirrors per-rank process memory in the distributed setting.
pub trait DenseMst {
    fn mst(&self, ds: &Dataset) -> Vec<Edge>;

    /// Kernel name for reporting.
    fn name(&self) -> &'static str;

    /// Distance evaluations performed so far (work accounting, E2).
    fn dist_evals(&self) -> u64;

    /// Reset work counters.
    fn reset_counters(&self);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs, BlobSpec};
    use crate::geometry::MetricKind;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::{kruskal, normalize_tree};
    use crate::util::prng::Pcg64;

    /// Complete-graph edge list via direct metric evaluation — the brute
    /// oracle both dense kernels are compared against.
    fn complete_graph_edges(ds: &crate::data::Dataset) -> Vec<Edge> {
        let m = crate::geometry::metric::PlainMetric(MetricKind::SqEuclid);
        use crate::geometry::Metric;
        let mut edges = Vec::with_capacity(ds.n * (ds.n - 1) / 2);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                edges.push(Edge::new(i as u32, j as u32, m.dist(ds.row(i), ds.row(j))));
            }
        }
        edges
    }

    #[test]
    fn dense_kernels_match_sparse_oracle() {
        // Quantize coordinates to multiples of 1/8 so the matmul-form
        // distances (BoruvkaDense's blocked step) are bit-exact vs direct
        // evaluation and the unique-MST comparison is exact, not tolerant.
        let spec = BlobSpec { n: 48, d: 6, k: 4, std: 0.5, spread: 5.0 };
        let raw = gaussian_blobs(&spec, Pcg64::seeded(77));
        let quant: Vec<f32> =
            raw.as_slice().iter().map(|x| (x * 8.0).round() / 8.0).collect();
        let ds = crate::data::Dataset::new(raw.n, raw.d, quant);
        let oracle = kruskal(ds.n, &complete_graph_edges(&ds));

        let prim = PrimDense::sq_euclid();
        let t1 = prim.mst(&ds);
        assert!(is_spanning_tree(ds.n, &t1));
        assert_eq!(normalize_tree(&oracle), normalize_tree(&t1), "PrimDense");

        let boruvka = BoruvkaDense::new_rust(MetricKind::SqEuclid);
        let t2 = boruvka.mst(&ds);
        assert!(is_spanning_tree(ds.n, &t2));
        assert_eq!(normalize_tree(&oracle), normalize_tree(&t2), "BoruvkaDense");
    }
}
