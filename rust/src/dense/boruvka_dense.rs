//! Dense Borůvka d-MST: ≤⌈log₂n⌉ rounds of the cheapest-edge step.
//!
//! Each round delegates the `O(n²d)` distance work to a
//! [`CheapestEdgeStep`] provider (the metric-generic blocked Rust kernels,
//! or the AOT-compiled Pallas/XLA kernel behind `backend-xla`) and keeps
//! only the `O(n)` select-merge bookkeeping here, which is the structure
//! that makes the paper's "exploit existing high performance kernels
//! without adjustment" claim concrete.

use super::step::{CheapestEdgeStep, RustStep};
use super::DenseMst;
use crate::data::Dataset;
use crate::geometry::MetricKind;
use crate::graph::{Edge, UnionFind};
use crate::util::fkey::edge_cmp;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Dense Borůvka kernel parameterized by the step provider.
pub struct BoruvkaDense {
    step: Arc<dyn CheapestEdgeStep>,
    metric: MetricKind,
    evals: AtomicU64,
    rounds: AtomicU64,
}

impl BoruvkaDense {
    /// With the given provider. The provider must compute distances for the
    /// same metric family: providers advertise their metric via
    /// [`CheapestEdgeStep::metric`], and for `Euclid` the comparison form is
    /// squared (weights are `sqrt`ed at edge emission).
    pub fn new(step: Arc<dyn CheapestEdgeStep>, metric: MetricKind) -> Self {
        let provided = step.metric();
        let compatible = provided == metric || provided == metric.compare_form();
        assert!(
            compatible,
            "step provider computes {provided:?} distances but the kernel metric is {metric:?}"
        );
        Self { step, metric, evals: AtomicU64::new(0), rounds: AtomicU64::new(0) }
    }

    /// Pure-Rust blocked provider for any metric.
    pub fn new_rust(metric: MetricKind) -> Self {
        Self::new(Arc::new(RustStep::new(metric.compare_form())), metric)
    }

    /// Borůvka rounds executed so far (across all `mst` calls since reset).
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    pub fn provider_name(&self) -> &'static str {
        self.step.name()
    }

    /// Run the Borůvka loop over `points` with an externally-supplied
    /// initial labeling (used directly by tests; `mst` wraps this).
    fn run(&self, ds: &Dataset) -> Vec<Edge> {
        let n = ds.n;
        let mut tree = Vec::with_capacity(n.saturating_sub(1));
        if n <= 1 {
            return tree;
        }
        let mut uf = UnionFind::new(n);
        let mut comps: Vec<i32> = (0..n as i32).collect();
        // Safety bound: Borůvka halves components each round.
        let max_rounds = (usize::BITS - n.leading_zeros()) as usize + 2;
        for _ in 0..max_rounds {
            if uf.components() == 1 {
                break;
            }
            let (dist, idx) = self.step.step(ds.as_slice(), n, ds.d, &comps);
            self.evals.fetch_add(self.step.evals_per_call(n as u64), Ordering::Relaxed);
            self.rounds.fetch_add(1, Ordering::Relaxed);

            // Reduce per-vertex candidates to per-component best (strict order).
            // best[root] = (w, u, v) canonical
            let mut best: Vec<Option<(f32, u32, u32)>> = vec![None; n];
            for i in 0..n {
                let j = idx[i];
                if j < 0 {
                    continue;
                }
                let (u, v) = ((i as u32).min(j as u32), (i as u32).max(j as u32));
                let w = dist[i];
                let r = uf.find(i as u32) as usize;
                let replace = match best[r] {
                    None => true,
                    Some((bw, bu, bv)) => edge_cmp(w, u, v, bw, bu, bv) == std::cmp::Ordering::Less,
                };
                if replace {
                    best[r] = Some((w, u, v));
                }
            }
            let mut merged = false;
            for r in 0..n {
                if let Some((w, u, v)) = best[r] {
                    if uf.union(u, v) {
                        let w = if self.metric == MetricKind::Euclid { w.sqrt() } else { w };
                        tree.push(Edge::new(u, v, w));
                        merged = true;
                    }
                }
            }
            if !merged {
                break; // disconnected under mask (shouldn't happen for complete graphs)
            }
            // Refresh labels for the next round.
            for i in 0..n {
                comps[i] = uf.find(i as u32) as i32;
            }
        }
        tree
    }
}

impl DenseMst for BoruvkaDense {
    fn mst(&self, ds: &Dataset) -> Vec<Edge> {
        self.run(ds)
    }

    fn name(&self) -> &'static str {
        "boruvka-dense"
    }

    fn dist_evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    fn reset_counters(&self) {
        self.evals.store(0, Ordering::Relaxed);
        self.rounds.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_blobs, uniform, BlobSpec};
    use crate::dense::prim_dense::PrimScalar;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    #[test]
    fn matches_prim_dense_across_sizes() {
        for (seed, n, d) in [(1u64, 2usize, 3usize), (2, 7, 2), (3, 33, 5), (4, 100, 16), (5, 129, 8)] {
            // integer coords => exact distances in both paths
            let mut rng = Pcg64::seeded(seed);
            let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(21) as f32 - 10.0).collect();
            let ds = Dataset::new(n, d, data);
            let prim = crate::dense::PrimDense::sq_euclid();
            let a = prim.mst(&ds);
            let b = BoruvkaDense::new_rust(MetricKind::SqEuclid).mst(&ds);
            assert!(is_spanning_tree(n, &b), "n={n}");
            assert_eq!(normalize_tree(&a), normalize_tree(&b), "seed={seed} n={n} d={d}");
        }
    }

    #[test]
    fn round_count_logarithmic() {
        let ds = uniform(256, 8, 1.0, Pcg64::seeded(6));
        let k = BoruvkaDense::new_rust(MetricKind::SqEuclid);
        let t = k.mst(&ds);
        assert!(is_spanning_tree(ds.n, &t));
        assert!(k.rounds() <= 9, "rounds={} > log2(256)+1", k.rounds());
        assert!(k.rounds() >= 1);
    }

    #[test]
    fn euclid_variant_sqrt_weights() {
        let ds = Dataset::new(3, 1, vec![0.0, 3.0, 7.0]);
        let t = BoruvkaDense::new_rust(MetricKind::Euclid).mst(&ds);
        let mut ws: Vec<f32> = t.iter().map(|e| e.w).collect();
        ws.sort_by(f32::total_cmp);
        assert_eq!(ws, vec![3.0, 4.0]);
    }

    #[test]
    fn cosine_and_manhattan_match_scalar_prim() {
        // The generalized step providers must reproduce the scalar-metric
        // oracle tree exactly on integer coordinates (float-exact paths).
        let mut rng = Pcg64::seeded(77);
        let (n, d) = (60, 8);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(15) as f32 - 7.0).collect();
        let ds = Dataset::new(n, d, data);
        for kind in [MetricKind::Cosine, MetricKind::Manhattan] {
            let oracle = PrimScalar::new(kind).mst(&ds);
            let got = BoruvkaDense::new_rust(kind).mst(&ds);
            assert!(is_spanning_tree(n, &got), "{kind:?}");
            assert_eq!(normalize_tree(&oracle), normalize_tree(&got), "{kind:?}");
        }
    }

    #[test]
    #[should_panic(expected = "step provider computes")]
    fn rejects_mismatched_provider_metric() {
        // A cosine provider cannot back a Manhattan kernel.
        BoruvkaDense::new(Arc::new(RustStep::new(MetricKind::Cosine)), MetricKind::Manhattan);
    }

    #[test]
    fn work_accounting_counts_n_squared_per_round() {
        let ds = uniform(64, 4, 1.0, Pcg64::seeded(7));
        let k = BoruvkaDense::new_rust(MetricKind::SqEuclid);
        k.mst(&ds);
        let rounds = k.rounds();
        assert_eq!(k.dist_evals(), rounds * 64 * 64);
        k.reset_counters();
        assert_eq!(k.dist_evals(), 0);
        assert_eq!(k.rounds(), 0);
    }

    #[test]
    fn clustered_data_exact() {
        let spec = BlobSpec { n: 90, d: 12, k: 6, std: 0.3, spread: 10.0 };
        let ds = gaussian_blobs(&spec, Pcg64::seeded(44));
        let a = crate::dense::PrimDense::sq_euclid().mst(&ds);
        let b = BoruvkaDense::new_rust(MetricKind::SqEuclid).mst(&ds);
        // Continuous data: both paths compute matmul-form distances, so the
        // trees agree exactly; weights compared with a relative tolerance as
        // belt-and-braces.
        let (na, nb) = (normalize_tree(&a), normalize_tree(&b));
        let ea: Vec<(u32, u32)> = na.iter().map(|e| (e.u, e.v)).collect();
        let eb: Vec<(u32, u32)> = nb.iter().map(|e| (e.u, e.v)).collect();
        assert_eq!(ea, eb, "identical tree structure");
        let (wa, wb) = (crate::mst::total_weight(&a), crate::mst::total_weight(&b));
        assert!((wa - wb).abs() < 1e-4 * (1.0 + wa.abs()), "wa={wa} wb={wb}");
    }
}
