//! Borůvka's algorithm on sparse edge lists.
//!
//! Third independent MST oracle, and the sparse twin of the dense Borůvka
//! loop in `crate::dense::BoruvkaXla` — both select each component's
//! minimum outgoing edge per round, so this module is also where that
//! selection logic is tested in isolation.

use crate::graph::{Edge, UnionFind};
use crate::util::fkey::edge_cmp;

/// Minimum spanning forest via Borůvka rounds.
pub fn boruvka_sparse(n: usize, edges: &[Edge]) -> Vec<Edge> {
    let mut uf = UnionFind::new(n);
    let mut tree: Vec<Edge> = Vec::with_capacity(n.saturating_sub(1));
    if n == 0 || edges.is_empty() {
        return tree;
    }
    // best candidate edge index per component root, rebuilt each round
    let mut best: Vec<u32> = vec![u32::MAX; n];
    loop {
        let mut any = false;
        for slot in best.iter_mut() {
            *slot = u32::MAX;
        }
        for (idx, e) in edges.iter().enumerate() {
            let (ru, rv) = (uf.find(e.u), uf.find(e.v));
            if ru == rv {
                continue;
            }
            any = true;
            for r in [ru, rv] {
                let cur = best[r as usize];
                if cur == u32::MAX || better(e, &edges[cur as usize]) {
                    best[r as usize] = idx as u32;
                }
            }
        }
        if !any {
            break;
        }
        let mut merged = false;
        // Deterministic merge order: iterate roots ascending.
        for r in 0..n {
            let b = best[r];
            if b == u32::MAX {
                continue;
            }
            let e = edges[b as usize];
            if uf.union(e.u, e.v) {
                tree.push(Edge::new(e.u, e.v, e.w));
                merged = true;
            }
        }
        if !merged {
            break;
        }
        if uf.components() == 1 {
            break;
        }
    }
    tree
}

#[inline]
fn better(a: &Edge, b: &Edge) -> bool {
    edge_cmp(a.w, a.u, a.v, b.w, b.u, b.v) == std::cmp::Ordering::Less
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mst::{kruskal, normalize_tree};
    use crate::util::prng::Pcg64;

    #[test]
    fn matches_kruskal_with_ties() {
        let mut rng = Pcg64::seeded(33);
        for trial in 0..30 {
            let n = 2 + rng.next_bounded(50) as usize;
            let m = 1 + rng.next_bounded((2 * n) as u64) as usize;
            let mut edges = Vec::with_capacity(m);
            for _ in 0..m {
                let u = rng.next_bounded(n as u64) as u32;
                let mut v = rng.next_bounded(n as u64) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                let w = (rng.next_bounded(4) as f32) + 1.0; // heavy ties
                edges.push(Edge::new(u, v, w));
            }
            let k = kruskal(n, &edges);
            let b = boruvka_sparse(n, &edges);
            assert_eq!(normalize_tree(&k), normalize_tree(&b), "trial {trial} (n={n} m={m})");
        }
    }

    #[test]
    fn single_edge() {
        let t = boruvka_sparse(2, &[Edge::new(0, 1, 3.0)]);
        assert_eq!(t, vec![Edge::new(0, 1, 3.0)]);
    }

    #[test]
    fn terminates_on_disconnected() {
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(2, 3, 1.0)];
        let t = boruvka_sparse(5, &edges);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn rounds_are_logarithmic_path() {
        // Path graph: Borůvka still terminates quickly and exactly.
        let n = 128;
        let edges: Vec<Edge> = (0..n - 1).map(|i| Edge::new(i, i + 1, (i % 3) as f32 + 1.0)).collect();
        let t = boruvka_sparse(n as usize, &edges);
        assert_eq!(t.len(), (n - 1) as usize);
    }
}
