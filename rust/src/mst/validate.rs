//! MST validation helpers: structural checks and the cut/cycle properties.
//! Used in tests and by the `--verify` CLI flag.

use crate::graph::{components::is_forest, Edge, UnionFind};
use crate::mst::normalize_tree;
use crate::util::fkey::edge_cmp;

/// Panic unless two MSFs are the identical edge set (canonical order).
pub fn assert_same_tree(expected: &[Edge], got: &[Edge], context: &str) {
    let e = normalize_tree(expected);
    let g = normalize_tree(got);
    if e != g {
        let only_e: Vec<_> = e.iter().filter(|x| !g.contains(x)).collect();
        let only_g: Vec<_> = g.iter().filter(|x| !e.contains(x)).collect();
        panic!(
            "{context}: trees differ\n  expected {} edges, got {}\n  missing: {only_e:?}\n  extra:   {only_g:?}",
            e.len(),
            g.len()
        );
    }
}

/// Verify the cycle property: for every non-tree edge `e` of `graph_edges`,
/// `e` must not be strictly smaller than the maximum tree edge on the path
/// between its endpoints. O(m·n) — test-sized graphs only.
pub fn verify_cycle_property(n: usize, tree: &[Edge], graph_edges: &[Edge]) -> Result<(), String> {
    if !is_forest(n, tree) {
        return Err("tree is not a forest".into());
    }
    // adjacency over tree edges
    let mut adj: Vec<Vec<(u32, f32, u32, u32)>> = vec![Vec::new(); n];
    for e in tree {
        adj[e.u as usize].push((e.v, e.w, e.u, e.v));
        adj[e.v as usize].push((e.u, e.w, e.u, e.v));
    }
    let tree_norm = normalize_tree(tree);
    for ge in graph_edges {
        let ge = Edge::new(ge.u, ge.v, ge.w);
        if tree_norm.binary_search_by(|t| t.u.cmp(&ge.u).then(t.v.cmp(&ge.v))).is_ok() {
            continue; // tree edge
        }
        // max-weight edge on the tree path u -> v (BFS)
        if let Some((mw, mu, mv)) = path_max(&adj, n, ge.u, ge.v) {
            // strict order: non-tree edge must NOT be smaller than path max
            if edge_cmp(ge.w, ge.u, ge.v, mw, mu, mv) == std::cmp::Ordering::Less {
                return Err(format!(
                    "cycle property violated: non-tree edge ({},{},w={}) < path max ({},{},w={})",
                    ge.u, ge.v, ge.w, mu, mv, mw
                ));
            }
        }
        // endpoints in different forest components: edge connects two trees —
        // that's a violation too (forest should have used it)
        else {
            return Err(format!(
                "forest is not maximal: edge ({},{}) connects two components",
                ge.u, ge.v
            ));
        }
    }
    Ok(())
}

/// Verify the cut property on sampled cuts: for `k` random bipartitions, the
/// lightest crossing edge of the graph must be in the tree.
pub fn verify_cut_property(
    n: usize,
    tree: &[Edge],
    graph_edges: &[Edge],
    samples: usize,
    seed: u64,
) -> Result<(), String> {
    use crate::util::prng::Pcg64;
    let mut rng = Pcg64::seeded(seed);
    let tree_norm = normalize_tree(tree);
    // Only sample cuts that respect connectivity: we put each vertex on a
    // random side; lightest crossing edge within a connected component must
    // be a tree edge.
    let mut uf = UnionFind::new(n);
    for e in graph_edges {
        uf.union(e.u, e.v);
    }
    for _ in 0..samples {
        let side: Vec<bool> = (0..n).map(|_| rng.next_f64() < 0.5).collect();
        // lightest crossing edge per component root
        let mut best: Vec<Option<Edge>> = vec![None; n];
        for e in graph_edges {
            if side[e.u as usize] != side[e.v as usize] {
                let r = uf.find(e.u) as usize;
                let replace = match &best[r] {
                    None => true,
                    Some(b) => edge_cmp(e.w, e.u, e.v, b.w, b.u, b.v) == std::cmp::Ordering::Less,
                };
                if replace {
                    best[r] = Some(Edge::new(e.u, e.v, e.w));
                }
            }
        }
        for b in best.into_iter().flatten() {
            if tree_norm.binary_search_by(|t| t.u.cmp(&b.u).then(t.v.cmp(&b.v))).is_err() {
                return Err(format!(
                    "cut property violated: lightest crossing edge ({},{},w={}) not in tree",
                    b.u, b.v, b.w
                ));
            }
        }
    }
    Ok(())
}

/// Max-weight edge (in strict order) on the tree path between a and b, or
/// None if disconnected. BFS with parent tracking.
fn path_max(
    adj: &[Vec<(u32, f32, u32, u32)>],
    n: usize,
    a: u32,
    b: u32,
) -> Option<(f32, u32, u32)> {
    let mut prev: Vec<Option<(u32, f32, u32, u32)>> = vec![None; n];
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    visited[a as usize] = true;
    queue.push_back(a);
    while let Some(x) = queue.pop_front() {
        if x == b {
            break;
        }
        for &(to, w, eu, ev) in &adj[x as usize] {
            if !visited[to as usize] {
                visited[to as usize] = true;
                prev[to as usize] = Some((x, w, eu, ev));
                queue.push_back(to);
            }
        }
    }
    if !visited[b as usize] {
        return None;
    }
    let mut cur = b;
    let mut best: Option<(f32, u32, u32)> = None;
    while cur != a {
        let (p, w, eu, ev) = prev[cur as usize].unwrap();
        let replace = match best {
            None => true,
            Some((bw, bu, bv)) => edge_cmp(w, eu, ev, bw, bu, bv) == std::cmp::Ordering::Greater,
        };
        if replace {
            best = Some((w, eu, ev));
        }
        cur = p;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mst::kruskal;
    use crate::util::prng::Pcg64;

    fn random_graph(seed: u64, n: usize, m: usize) -> Vec<Edge> {
        let mut rng = Pcg64::seeded(seed);
        (0..m)
            .map(|_| {
                let u = rng.next_bounded(n as u64) as u32;
                let mut v = rng.next_bounded(n as u64) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                Edge::new(u, v, rng.next_f32() * 10.0)
            })
            .collect()
    }

    #[test]
    fn kruskal_passes_both_properties() {
        for seed in 0..5 {
            let n = 30;
            let edges = random_graph(seed, n, 120);
            let t = kruskal(n, &edges);
            verify_cycle_property(n, &t, &edges).unwrap();
            verify_cut_property(n, &t, &edges, 20, seed).unwrap();
        }
    }

    #[test]
    fn detects_bad_tree() {
        // Replace the lightest edge with a heavy detour: must fail.
        let edges = vec![
            Edge::new(0, 1, 1.0),
            Edge::new(1, 2, 2.0),
            Edge::new(0, 2, 10.0),
        ];
        let bad_tree = vec![Edge::new(0, 2, 10.0), Edge::new(1, 2, 2.0)];
        assert!(verify_cycle_property(3, &bad_tree, &edges).is_err());
    }

    #[test]
    fn assert_same_tree_passes_on_equal() {
        let t = vec![Edge::new(0, 1, 1.0)];
        assert_same_tree(&t, &t.clone(), "self");
    }

    #[test]
    #[should_panic(expected = "trees differ")]
    fn assert_same_tree_panics_on_diff() {
        assert_same_tree(&[Edge::new(0, 1, 1.0)], &[Edge::new(0, 2, 1.0)], "diff");
    }

    #[test]
    fn detects_non_maximal_forest() {
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(1, 2, 1.0)];
        let incomplete = vec![Edge::new(0, 1, 1.0)];
        assert!(verify_cycle_property(3, &incomplete, &edges).is_err());
    }
}
