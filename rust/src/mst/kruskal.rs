//! Kruskal's algorithm: sort + union-find. The workhorse sparse MST used by
//! the coordinator's gather step (edge count there is `O(|V|·|P|)`, so the
//! sort dominates at `O(|V||P| log(|V||P|))` — cheap relative to d-MST work).

use crate::graph::{Edge, UnionFind};

/// Minimum spanning forest of `n` vertices over `edges`.
/// Returns edges in the order they were admitted (ascending strict order).
pub fn kruskal(n: usize, edges: &[Edge]) -> Vec<Edge> {
    let mut es: Vec<Edge> = edges.iter().map(|e| Edge::new(e.u, e.v, e.w)).collect();
    es.sort_unstable(); // strict (w, u, v) order => unique MSF under ties
    kruskal_presorted(n, &es)
}

/// Kruskal over edges already sorted in strict order (skips the sort).
pub fn kruskal_presorted(n: usize, sorted_edges: &[Edge]) -> Vec<Edge> {
    let mut uf = UnionFind::new(n);
    let mut tree = Vec::with_capacity(n.saturating_sub(1));
    for &e in sorted_edges {
        if uf.union(e.u, e.v) {
            tree.push(e);
            if uf.components() == 1 {
                break;
            }
        }
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::is_spanning_tree;
    use crate::mst::total_weight;

    fn sample_graph() -> (usize, Vec<Edge>) {
        // CLRS-style example, unique weights.
        let edges = vec![
            Edge::new(0, 1, 4.0),
            Edge::new(0, 7, 8.0),
            Edge::new(1, 7, 11.0),
            Edge::new(1, 2, 8.0),
            Edge::new(7, 8, 7.0),
            Edge::new(7, 6, 1.0),
            Edge::new(2, 8, 2.0),
            Edge::new(8, 6, 6.0),
            Edge::new(2, 3, 7.0),
            Edge::new(2, 5, 4.0),
            Edge::new(6, 5, 2.0),
            Edge::new(3, 5, 14.0),
            Edge::new(3, 4, 9.0),
            Edge::new(5, 4, 10.0),
        ];
        (9, edges)
    }

    #[test]
    fn clrs_example_weight() {
        let (n, edges) = sample_graph();
        let t = kruskal(n, &edges);
        assert!(is_spanning_tree(n, &t));
        assert_eq!(total_weight(&t), 37.0);
    }

    #[test]
    fn disconnected_graph_gives_forest() {
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(2, 3, 2.0)];
        let t = kruskal(5, &edges);
        assert_eq!(t.len(), 2, "two components joined internally; vertex 4 isolated");
    }

    #[test]
    fn empty_and_single() {
        assert!(kruskal(0, &[]).is_empty());
        assert!(kruskal(1, &[]).is_empty());
    }

    #[test]
    fn parallel_edges_pick_cheapest() {
        let edges = vec![
            Edge::new(0, 1, 5.0),
            Edge::new(0, 1, 1.0),
            Edge::new(0, 1, 3.0),
        ];
        let t = kruskal(2, &edges);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].w, 1.0);
    }

    #[test]
    fn tie_break_deterministic() {
        // Square with all-equal weights: unique MSF under (w,u,v) order is
        // the 3 lexicographically-smallest edges that stay acyclic.
        let edges = vec![
            Edge::new(0, 1, 1.0),
            Edge::new(1, 2, 1.0),
            Edge::new(2, 3, 1.0),
            Edge::new(0, 3, 1.0),
        ];
        let t = kruskal(4, &edges);
        assert_eq!(t, vec![Edge::new(0, 1, 1.0), Edge::new(0, 3, 1.0), Edge::new(1, 2, 1.0)]);
    }
}
