//! Prim's algorithm with a binary heap over an adjacency list.
//! Used as an independent oracle against Kruskal/Borůvka in tests.

use crate::graph::Edge;
use crate::util::fkey::edge_cmp;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: candidate edge into the tree. Min-heap via reversed order;
/// tie-broken with the strict edge order so the MSF matches Kruskal's exactly.
struct Cand {
    w: f32,
    u: u32,
    v: u32,
    /// vertex this candidate would add
    add: u32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap
        edge_cmp(other.w, other.u, other.v, self.w, self.u, self.v)
    }
}

/// Minimum spanning forest via Prim (restarted per component).
pub fn prim_sparse(n: usize, edges: &[Edge]) -> Vec<Edge> {
    // adjacency list
    let mut deg = vec![0u32; n];
    for e in edges {
        deg[e.u as usize] += 1;
        deg[e.v as usize] += 1;
    }
    let mut start = vec![0usize; n + 1];
    for i in 0..n {
        start[i + 1] = start[i] + deg[i] as usize;
    }
    let mut adj = vec![(0u32, 0f32); edges.len() * 2];
    let mut fill = start.clone();
    for e in edges {
        adj[fill[e.u as usize]] = (e.v, e.w);
        fill[e.u as usize] += 1;
        adj[fill[e.v as usize]] = (e.u, e.w);
        fill[e.v as usize] += 1;
    }

    let mut in_tree = vec![false; n];
    let mut tree = Vec::with_capacity(n.saturating_sub(1));
    let mut heap = BinaryHeap::new();

    for root in 0..n as u32 {
        if in_tree[root as usize] {
            continue;
        }
        in_tree[root as usize] = true;
        push_neighbors(&adj, &start, root, &in_tree, &mut heap);
        while let Some(c) = heap.pop() {
            if in_tree[c.add as usize] {
                continue;
            }
            in_tree[c.add as usize] = true;
            tree.push(Edge::new(c.u, c.v, c.w));
            push_neighbors(&adj, &start, c.add, &in_tree, &mut heap);
        }
    }
    tree
}

fn push_neighbors(
    adj: &[(u32, f32)],
    start: &[usize],
    v: u32,
    in_tree: &[bool],
    heap: &mut BinaryHeap<Cand>,
) {
    for &(to, w) in &adj[start[v as usize]..start[v as usize + 1]] {
        if !in_tree[to as usize] {
            let (a, b) = if v < to { (v, to) } else { (to, v) };
            heap.push(Cand { w, u: a, v: b, add: to });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::is_forest;
    use crate::mst::{kruskal, normalize_tree, total_weight};
    use crate::util::prng::Pcg64;

    #[test]
    fn matches_kruskal_on_random_graphs() {
        let mut rng = Pcg64::seeded(21);
        for trial in 0..30 {
            let n = 2 + (rng.next_bounded(40) as usize);
            let m = rng.next_bounded((n * (n - 1) / 2 + 1) as u64) as usize;
            let mut edges = Vec::with_capacity(m);
            for _ in 0..m {
                let u = rng.next_bounded(n as u64) as u32;
                let mut v = rng.next_bounded(n as u64) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                // small weight alphabet to force plenty of ties
                let w = (rng.next_bounded(8) as f32) * 0.5;
                edges.push(Edge::new(u, v, w));
            }
            let k = kruskal(n, &edges);
            let p = prim_sparse(n, &edges);
            assert!(is_forest(n, &p));
            assert_eq!(
                normalize_tree(&k),
                normalize_tree(&p),
                "trial {trial}: identical MSF expected (n={n}, m={m})"
            );
            assert_eq!(total_weight(&k), total_weight(&p));
        }
    }

    #[test]
    fn empty_graph() {
        assert!(prim_sparse(4, &[]).is_empty());
    }
}
