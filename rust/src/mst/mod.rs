//! Sparse minimum-spanning-tree/forest algorithms over explicit edge lists.
//!
//! These implement the paper's outer `MST(TreeEdges)` step — the cheap sparse
//! pass over the `O(|V|·|P|)` union of pairwise d-MST edges — plus two
//! independent algorithms used as cross-checking oracles in tests.
//!
//! All algorithms break weight ties with the crate-wide strict edge order
//! `(w, u, v)`, so the MSF is unique and all of them (plus the dense kernels
//! and the decomposed algorithm) return *identical* edge sets, not just equal
//! weights.

pub mod kruskal;
pub mod prim;
pub mod boruvka;
pub mod validate;

pub use boruvka::boruvka_sparse;
pub use kruskal::kruskal;
pub use prim::prim_sparse;
pub use validate::{assert_same_tree, verify_cut_property, verify_cycle_property};

use crate::graph::Edge;

/// Sum of edge weights (f64 accumulator for stability).
pub fn total_weight(edges: &[Edge]) -> f64 {
    edges.iter().map(|e| e.w as f64).sum()
}

/// Canonically sorted copy of an MSF edge list, for equality comparisons.
pub fn normalize_tree(edges: &[Edge]) -> Vec<Edge> {
    let mut es: Vec<Edge> = edges.iter().map(|e| Edge::new(e.u, e.v, e.w)).collect();
    es.sort_unstable_by(|a, b| a.u.cmp(&b.u).then(a.v.cmp(&b.v)));
    es
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_weight_sums() {
        let es = vec![Edge::new(0, 1, 1.5), Edge::new(1, 2, 2.5)];
        assert_eq!(total_weight(&es), 4.0);
        assert_eq!(total_weight(&[]), 0.0);
    }

    #[test]
    fn normalize_sorts_by_endpoints() {
        let es = vec![Edge::new(5, 2, 1.0), Edge::new(0, 1, 9.0)];
        let n = normalize_tree(&es);
        assert_eq!(n[0], Edge::new(0, 1, 9.0));
        assert_eq!(n[1], Edge::new(2, 5, 1.0));
    }
}
