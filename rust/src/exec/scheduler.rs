//! Cost-aware job dealing: per-worker affinity decks with idle stealing,
//! a return lane for jobs lost to worker failures, and optional
//! capability masks for sharded residency.
//!
//! Three shapes behind one type:
//!
//! - [`JobQueue::new`] — a single shared deck in LPT (longest-processing-
//!   time-first) order; every worker claims the next-heaviest unclaimed job
//!   (the classical self-scheduling arrangement, kept for the no-affinity
//!   path and the local-MST build).
//! - [`JobQueue::with_decks`] — one deck per worker (typically
//!   [`AffinityPlan::decks`](super::plan::AffinityPlan)): a worker drains
//!   its own deck first and only then steals round-robin from the others,
//!   so jobs run at their subset's anchor whenever the load allows and the
//!   deal still adapts to observed speed (an idle worker never waits while
//!   any deck holds work).
//! - [`JobQueue::with_decks_capped`] — decks plus a per-worker capability
//!   mask (`caps[w][job]`): only capable workers may claim a job. Used by
//!   sharded runs, where job `(i, j)` can only execute on a worker whose
//!   local shard files hold both subsets — cross-deck stealing is disabled
//!   (a steal would claim a job the thief may be unable to run), so load
//!   adaptation happens through the deal and the return lane only.
//!
//! **Elastic return lane**: when a remote worker dies mid-run, its claimed
//! but unfinished jobs are [returned](JobQueue::push_returned) and handed
//! out again by [`JobQueue::pop_for`] — to any worker under open decks, to
//! capable workers under masks. Combined with the atomic per-deck claim
//! cursors this keeps every job *recorded exactly once at the leader*: a
//! job is returned only when its claimant provably never delivered a
//! result, and re-claims go through the same exactly-once lane.
//!
//! Claims are atomic per-deck cursors: every job index is handed out at
//! most once per claim generation regardless of interleaving.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};

/// The growable deck table: decks, their claim cursors, and the optional
/// capability masks, kept together so [`JobQueue::admit_worker`] can append
/// a deck atomically with its capability row. Readers (claims) take the
/// read lock — the cursors stay atomic, so concurrent claims remain
/// exactly-once; only admission takes the write lock.
#[derive(Debug)]
struct Decks {
    decks: Vec<Vec<usize>>,
    cursors: Vec<AtomicUsize>,
    /// `caps[w][job]` — whether worker `w` can run `job`. `None` = every
    /// worker can run everything (and cross-deck stealing is allowed).
    caps: Option<Vec<Vec<bool>>>,
}

/// A shared set of job decks with atomic claim cursors, a mutex-guarded
/// return lane, optional capability masks — and mid-run growth: a worker
/// admitted while the run is in flight gets a fresh deck carved from the
/// return lane plus a bounded slice of the largest surviving deck.
#[derive(Debug)]
pub struct JobQueue {
    inner: RwLock<Decks>,
    /// jobs returned after a worker failure, awaiting re-claim
    returned: Mutex<Vec<usize>>,
    /// cheap fast-path guard so `pop_for` skips the lock while empty
    has_returned: AtomicBool,
}

impl JobQueue {
    /// Single shared deck over `order` (typically [`ExecPlan::lpt_order`]).
    /// Each element is handed out exactly once across all threads.
    ///
    /// [`ExecPlan::lpt_order`]: crate::exec::ExecPlan
    pub fn new(order: Vec<usize>) -> Self {
        Self::with_decks(vec![order])
    }

    /// One deck per worker; worker `w` owns `decks[w]` and steals from the
    /// rest when its own deck drains.
    pub fn with_decks(decks: Vec<Vec<usize>>) -> Self {
        Self::build(decks, None)
    }

    /// Decks plus capability masks (`caps[w][job]`); stealing disabled.
    /// Every deck entry must be runnable by the deck's owner.
    pub fn with_decks_capped(decks: Vec<Vec<usize>>, caps: Vec<Vec<bool>>) -> Self {
        assert_eq!(decks.len(), caps.len(), "one capability row per deck");
        for (w, deck) in decks.iter().enumerate() {
            debug_assert!(
                deck.iter().all(|&j| caps[w][j]),
                "deck {w} holds a job its owner cannot run"
            );
        }
        Self::build(decks, Some(caps))
    }

    fn build(decks: Vec<Vec<usize>>, caps: Option<Vec<Vec<bool>>>) -> Self {
        assert!(!decks.is_empty(), "JobQueue needs at least one deck");
        let cursors = decks.iter().map(|_| AtomicUsize::new(0)).collect();
        Self {
            inner: RwLock::new(Decks { decks, cursors, caps }),
            returned: Mutex::new(Vec::new()),
            has_returned: AtomicBool::new(false),
        }
    }

    /// Whether worker `w` may run `job` under the capability masks. A
    /// worker with no capability row yet (admission racing a lane check)
    /// can run nothing.
    pub fn capable(&self, w: usize, job: usize) -> bool {
        let inner = self.inner.read().unwrap();
        match &inner.caps {
            None => true,
            Some(c) => c.get(w).is_some_and(|row| row[job]),
        }
    }

    /// Open a deck for a worker admitted mid-run and return its deck index
    /// (== its worker id under the affinity layout). The new deck is a
    /// **bounded rebalance**: half the unclaimed tail of the largest
    /// surviving deck, filtered by the newcomer's capability row — plus
    /// whatever it later claims from the return lane through the normal
    /// [`Self::pop_for`] path. Taking the *tail* keeps the donor's
    /// LPT-heavy head where it is, so the rebalance never un-anchors a job
    /// a resident worker was about to claim cheaply. `caps_row` is required
    /// exactly when the queue runs capped (sharded residency).
    pub fn admit_worker(&self, caps_row: Option<Vec<bool>>) -> usize {
        let mut guard = self.inner.write().unwrap();
        let inner = &mut *guard;
        let w = inner.decks.len();
        if let Some(caps) = &mut inner.caps {
            let jobs = caps.first().map_or(0, |row| row.len());
            caps.push(caps_row.unwrap_or_else(|| vec![true; jobs]));
        }
        // donor = deck with the largest unclaimed region
        let mut donor: Option<(usize, usize, usize)> = None; // (deck, start, unclaimed)
        for v in 0..w {
            let start = inner.cursors[v].load(Ordering::Relaxed).min(inner.decks[v].len());
            let unclaimed = inner.decks[v].len() - start;
            if unclaimed > donor.map_or(0, |(_, _, u)| u) {
                donor = Some((v, start, unclaimed));
            }
        }
        let mut deck = Vec::new();
        if let Some((v, start, unclaimed)) = donor {
            let budget = unclaimed / 2;
            if budget > 0 {
                let runnable = inner.caps.as_ref().map(|c| c[w].clone());
                let tail: Vec<usize> = inner.decks[v].drain(start..).collect();
                let mut keep = Vec::with_capacity(tail.len());
                // walk the unclaimed region from its end (lightest jobs in
                // LPT order) and move up to `budget` runnable jobs over
                for &job in tail.iter().rev() {
                    let ok = match &runnable {
                        None => true,
                        Some(row) => row.get(job).copied().unwrap_or(false),
                    };
                    if ok && deck.len() < budget {
                        deck.push(job);
                    } else {
                        keep.push(job);
                    }
                }
                keep.reverse();
                deck.reverse(); // preserve LPT orientation in the new deck
                inner.decks[v].extend(keep);
            }
        }
        inner.decks.push(deck);
        inner.cursors.push(AtomicUsize::new(0));
        w
    }

    /// Claim the next unclaimed job index from the first deck (the shared-
    /// deck view), or `None` when everything is drained.
    pub fn pop(&self) -> Option<usize> {
        self.pop_for(0).map(|(job, _)| job)
    }

    /// Claim for `worker`: the return lane first (jobs lost to a failed
    /// worker, capability-filtered), then its own deck, then — without
    /// capability masks — steal round-robin from the other decks. Returns
    /// the job index and whether it was claimed off another worker's deck.
    pub fn pop_for(&self, worker: usize) -> Option<(usize, bool)> {
        if let Some(job) = self.pop_returned(worker) {
            return Some((job, false));
        }
        let inner = self.inner.read().unwrap();
        let n = inner.decks.len();
        let home = worker % n;
        let reach = if inner.caps.is_some() { 1 } else { n };
        for step in 0..reach {
            let v = (home + step) % n;
            let k = inner.cursors[v].fetch_add(1, Ordering::Relaxed);
            if let Some(&job) = inner.decks[v].get(k) {
                return Some((job, step != 0));
            }
        }
        None
    }

    /// Take one runnable job off the return lane, if any.
    fn pop_returned(&self, worker: usize) -> Option<usize> {
        if !self.has_returned.load(Ordering::Acquire) {
            return None;
        }
        let mut lane = self.returned.lock().unwrap();
        let at = lane.iter().position(|&job| self.capable(worker, job))?;
        let job = lane.swap_remove(at);
        if lane.is_empty() {
            self.has_returned.store(false, Ordering::Release);
        }
        Some(job)
    }

    /// Return jobs whose claimant died before delivering their results;
    /// they become claimable again through [`Self::pop_for`].
    pub fn push_returned(&self, jobs: &[usize]) {
        if jobs.is_empty() {
            return;
        }
        let mut lane = self.returned.lock().unwrap();
        lane.extend_from_slice(jobs);
        self.has_returned.store(true, Ordering::Release);
    }

    /// Drain every unclaimed job from `worker`'s own deck into the return
    /// lane (used when the worker's link dies: under capability masks no
    /// one can steal from its deck, and even with stealing the survivors
    /// would race a dead cursor).
    pub fn abandon_deck(&self, worker: usize) {
        let mut moved = Vec::new();
        {
            let inner = self.inner.read().unwrap();
            let home = worker % inner.decks.len();
            loop {
                let k = inner.cursors[home].fetch_add(1, Ordering::Relaxed);
                match inner.decks[home].get(k) {
                    Some(&job) => moved.push(job),
                    None => break,
                }
            }
        }
        self.push_returned(&moved);
    }

    /// A returned job that no worker in `alive` can run, if any — the
    /// stranded-work check an idle elastic fleet uses to fail fast instead
    /// of waiting for jobs that can never complete.
    pub fn stranded_job(&self, alive: &[bool]) -> Option<usize> {
        if !self.has_returned.load(Ordering::Acquire) {
            return None;
        }
        let lane = self.returned.lock().unwrap();
        lane.iter()
            .copied()
            .find(|&job| !alive.iter().enumerate().any(|(w, &a)| a && self.capable(w, job)))
    }

    /// Total jobs across all decks (claimed or not).
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().decks.iter().map(|d| d.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn pops_in_order_then_drains() {
        let q = JobQueue::new(vec![4, 2, 7]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "stays drained");
    }

    #[test]
    fn empty_queue() {
        let q = JobQueue::new(Vec::new());
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn own_deck_first_then_steals() {
        let q = JobQueue::with_decks(vec![vec![0, 1], vec![2], vec![]]);
        assert_eq!(q.len(), 3);
        // worker 1 drains its own deck, then steals from deck 2 (empty) and 0
        assert_eq!(q.pop_for(1), Some((2, false)));
        assert_eq!(q.pop_for(1), Some((0, true)));
        // worker 0 takes what's left of its own deck — no steal flag
        assert_eq!(q.pop_for(0), Some((1, false)));
        assert_eq!(q.pop_for(0), None);
        assert_eq!(q.pop_for(2), None);
    }

    #[test]
    fn worker_index_wraps_past_deck_count() {
        let q = JobQueue::with_decks(vec![vec![9], vec![8]]);
        // worker 3 homes on deck 3 % 2 = 1
        assert_eq!(q.pop_for(3), Some((8, false)));
        assert_eq!(q.pop_for(3), Some((9, true)));
    }

    #[test]
    fn returned_jobs_are_reclaimed_first() {
        let q = JobQueue::with_decks(vec![vec![0], vec![1]]);
        assert_eq!(q.pop_for(0), Some((0, false)));
        q.push_returned(&[0]);
        // the returned job outranks worker 1's own deck
        assert_eq!(q.pop_for(1), Some((0, false)));
        assert_eq!(q.pop_for(1), Some((1, false)));
        assert_eq!(q.pop_for(1), None);
    }

    #[test]
    fn caps_disable_stealing_and_filter_returns() {
        // jobs 0,1 runnable by worker 0; job 2 by both; job 1 also by w1
        let caps = vec![vec![true, true, true], vec![false, true, true]];
        let q = JobQueue::with_decks_capped(vec![vec![0, 2], vec![1]], caps);
        // worker 1 cannot steal worker 0's deck
        assert_eq!(q.pop_for(1), Some((1, false)));
        assert_eq!(q.pop_for(1), None, "no stealing under capability masks");
        // a returned job only goes to a capable worker
        q.push_returned(&[0]);
        assert_eq!(q.pop_for(1), None, "worker 1 cannot run job 0");
        assert_eq!(q.pop_for(0), Some((0, false)));
        assert_eq!(q.pop_for(0), Some((2, false)));
        assert_eq!(q.pop_for(0), None);
    }

    #[test]
    fn abandon_deck_moves_unclaimed_jobs_to_the_return_lane() {
        let caps = vec![vec![true; 3], vec![true; 3]];
        let q = JobQueue::with_decks_capped(vec![vec![0, 1, 2], vec![]], caps);
        assert_eq!(q.pop_for(0), Some((0, false)));
        q.abandon_deck(0);
        // worker 1 (which cannot steal) now sees the abandoned jobs
        assert_eq!(q.pop_for(1), Some((1, false)));
        assert_eq!(q.pop_for(1), Some((2, false)));
        assert_eq!(q.pop_for(1), None);
    }

    #[test]
    fn stranded_job_detection() {
        let caps = vec![vec![true, false], vec![false, true]];
        let q = JobQueue::with_decks_capped(vec![vec![0], vec![1]], caps);
        assert_eq!(q.stranded_job(&[true, true]), None, "nothing returned yet");
        q.push_returned(&[1]);
        assert_eq!(q.stranded_job(&[true, true]), None, "worker 1 can still run it");
        assert_eq!(q.stranded_job(&[true, false]), Some(1), "only holder is dead");
        // open decks: anyone alive can run anything
        let open = JobQueue::with_decks(vec![vec![0], vec![1]]);
        open.push_returned(&[0]);
        assert_eq!(open.stranded_job(&[false, true]), None);
        assert_eq!(open.stranded_job(&[false, false]), Some(0));
    }

    #[test]
    fn concurrent_claims_are_exactly_once() {
        let q = JobQueue::new((0..500).collect());
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    while let Some(j) = q.pop() {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 500);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 500, "every job claimed exactly once");
    }

    #[test]
    fn concurrent_deck_claims_with_stealing_are_exactly_once() {
        let decks: Vec<Vec<usize>> = (0..4).map(|w| (w * 100..(w + 1) * 100).collect()).collect();
        let q = JobQueue::with_decks(decks);
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let q = &q;
            let claimed = &claimed;
            for w in 0..6usize {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    while let Some((j, _stolen)) = q.pop_for(w) {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 400);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 400, "every job claimed exactly once under stealing");
    }

    #[test]
    fn admit_worker_rebalances_half_the_largest_deck_tail() {
        let q = JobQueue::with_decks(vec![vec![0, 1, 2, 3, 4, 5], vec![6]]);
        assert_eq!(q.pop_for(0), Some((0, false)), "claimed before admission stays claimed");
        // largest unclaimed region is deck 0's [1,2,3,4,5] → half = 2 off
        // the tail, LPT orientation preserved
        let w = q.admit_worker(None);
        assert_eq!(w, 2, "next free deck index");
        assert_eq!(q.pop_for(2), Some((4, false)));
        assert_eq!(q.pop_for(2), Some((5, false)));
        // the donor keeps its head in order
        assert_eq!(q.pop_for(0), Some((1, false)));
        assert_eq!(q.pop_for(0), Some((2, false)));
        assert_eq!(q.pop_for(0), Some((3, false)));
        // exactly-once across the rebalance: nothing left but deck 1's job
        assert_eq!(q.pop_for(1), Some((6, false)));
        for w in 0..3 {
            assert!(q.pop_for(w).is_none(), "worker {w} sees a drained queue");
        }
    }

    #[test]
    fn admit_worker_respects_capability_masks() {
        let caps = vec![vec![true; 4], vec![true; 4]];
        let q = JobQueue::with_decks_capped(vec![vec![0, 1, 2, 3], vec![]], caps);
        // the newcomer can only run jobs 1 and 3
        let w = q.admit_worker(Some(vec![false, true, false, true]));
        assert_eq!(w, 2);
        // tail walk moves runnable jobs only (budget 2): job 3, then job 1
        assert_eq!(q.pop_for(2), Some((1, false)));
        assert_eq!(q.pop_for(2), Some((3, false)));
        assert_eq!(q.pop_for(2), None, "capped: no stealing");
        // unrunnable jobs stayed with the donor, in order
        assert_eq!(q.pop_for(0), Some((0, false)));
        assert_eq!(q.pop_for(0), Some((2, false)));
        assert_eq!(q.pop_for(0), None);
        // the admitted worker's capability row filters the return lane
        q.push_returned(&[0, 1]);
        assert_eq!(q.pop_for(2), Some((1, false)), "capable return reclaimed");
        assert_eq!(q.pop_for(2), None, "job 0 is not runnable by the newcomer");
        assert_eq!(q.pop_for(0), Some((0, false)));
    }

    #[test]
    fn capable_guards_workers_without_a_row() {
        let caps = vec![vec![true, true]];
        let q = JobQueue::with_decks_capped(vec![vec![0, 1]], caps);
        assert!(q.capable(0, 1));
        assert!(!q.capable(5, 1), "no capability row yet → can run nothing");
    }

    #[test]
    fn admission_races_concurrent_claims_exactly_once() {
        let q = JobQueue::with_decks(vec![(0..300).collect(), (300..400).collect()]);
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let q = &q;
            let claimed = &claimed;
            for w in 0..2usize {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    while let Some((j, _)) = q.pop_for(w) {
                        local.push(j);
                        std::thread::yield_now();
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
            scope.spawn(move || {
                std::thread::yield_now();
                let w = q.admit_worker(None);
                let mut local = Vec::new();
                while let Some((j, _)) = q.pop_for(w) {
                    local.push(j);
                }
                claimed.lock().unwrap().extend(local);
            });
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 400);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 400, "rebalance must never duplicate or drop a job");
    }

    #[test]
    fn concurrent_returns_stay_exactly_once() {
        // Claim 200 jobs, return half of them once, drain concurrently:
        // the returned half must come out exactly once more, the rest not.
        let q = JobQueue::new((0..200).collect());
        let mut first: Vec<usize> = Vec::new();
        while let Some(j) = q.pop() {
            first.push(j);
        }
        let lost: Vec<usize> = first.iter().copied().filter(|j| j % 2 == 0).collect();
        q.push_returned(&lost);
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let q = &q;
            let claimed = &claimed;
            for w in 0..4usize {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    while let Some((j, _)) = q.pop_for(w) {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let mut got = claimed.into_inner().unwrap();
        got.sort_unstable();
        let mut want = lost.clone();
        want.sort_unstable();
        assert_eq!(got, want, "each returned job reclaimed exactly once");
    }
}
