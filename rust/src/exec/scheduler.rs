//! Cost-aware job dealing: per-worker affinity decks with idle stealing.
//!
//! Two shapes behind one type:
//!
//! - [`JobQueue::new`] — a single shared deck in LPT (longest-processing-
//!   time-first) order; every worker claims the next-heaviest unclaimed job
//!   (the classical self-scheduling arrangement, kept for the no-affinity
//!   path and the local-MST build).
//! - [`JobQueue::with_decks`] — one deck per worker (typically
//!   [`AffinityPlan::decks`](super::plan::AffinityPlan)): a worker drains
//!   its own deck first and only then steals round-robin from the others,
//!   so jobs run at their subset's anchor whenever the load allows and the
//!   deal still adapts to observed speed (an idle worker never waits while
//!   any deck holds work).
//!
//! Claims are atomic per-deck cursors: every job index is handed out exactly
//! once across all threads regardless of interleaving.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A shared, immutable set of job decks with atomic claim cursors.
#[derive(Debug)]
pub struct JobQueue {
    decks: Vec<Vec<usize>>,
    cursors: Vec<AtomicUsize>,
}

impl JobQueue {
    /// Single shared deck over `order` (typically [`ExecPlan::lpt_order`]).
    /// Each element is handed out exactly once across all threads.
    ///
    /// [`ExecPlan::lpt_order`]: crate::exec::ExecPlan
    pub fn new(order: Vec<usize>) -> Self {
        Self::with_decks(vec![order])
    }

    /// One deck per worker; worker `w` owns `decks[w]` and steals from the
    /// rest when its own deck drains.
    pub fn with_decks(decks: Vec<Vec<usize>>) -> Self {
        assert!(!decks.is_empty(), "JobQueue needs at least one deck");
        let cursors = decks.iter().map(|_| AtomicUsize::new(0)).collect();
        Self { decks, cursors }
    }

    /// Claim the next unclaimed job index from the first deck (the shared-
    /// deck view), or `None` when everything is drained.
    pub fn pop(&self) -> Option<usize> {
        self.pop_for(0).map(|(job, _)| job)
    }

    /// Claim for `worker`: own deck first, then steal round-robin from the
    /// other decks. Returns the job index and whether it was stolen.
    pub fn pop_for(&self, worker: usize) -> Option<(usize, bool)> {
        let n = self.decks.len();
        let home = worker % n;
        for step in 0..n {
            let v = (home + step) % n;
            let k = self.cursors[v].fetch_add(1, Ordering::Relaxed);
            if let Some(&job) = self.decks[v].get(k) {
                return Some((job, step != 0));
            }
        }
        None
    }

    /// Total jobs across all decks (claimed or not).
    pub fn len(&self) -> usize {
        self.decks.iter().map(|d| d.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex;

    #[test]
    fn pops_in_order_then_drains() {
        let q = JobQueue::new(vec![4, 2, 7]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "stays drained");
    }

    #[test]
    fn empty_queue() {
        let q = JobQueue::new(Vec::new());
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn own_deck_first_then_steals() {
        let q = JobQueue::with_decks(vec![vec![0, 1], vec![2], vec![]]);
        assert_eq!(q.len(), 3);
        // worker 1 drains its own deck, then steals from deck 2 (empty) and 0
        assert_eq!(q.pop_for(1), Some((2, false)));
        assert_eq!(q.pop_for(1), Some((0, true)));
        // worker 0 takes what's left of its own deck — no steal flag
        assert_eq!(q.pop_for(0), Some((1, false)));
        assert_eq!(q.pop_for(0), None);
        assert_eq!(q.pop_for(2), None);
    }

    #[test]
    fn worker_index_wraps_past_deck_count() {
        let q = JobQueue::with_decks(vec![vec![9], vec![8]]);
        // worker 3 homes on deck 3 % 2 = 1
        assert_eq!(q.pop_for(3), Some((8, false)));
        assert_eq!(q.pop_for(3), Some((9, true)));
    }

    #[test]
    fn concurrent_claims_are_exactly_once() {
        let q = JobQueue::new((0..500).collect());
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    while let Some(j) = q.pop() {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 500);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 500, "every job claimed exactly once");
    }

    #[test]
    fn concurrent_deck_claims_with_stealing_are_exactly_once() {
        let decks: Vec<Vec<usize>> = (0..4).map(|w| (w * 100..(w + 1) * 100).collect()).collect();
        let q = JobQueue::with_decks(decks);
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let q = &q;
            let claimed = &claimed;
            for w in 0..6usize {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    while let Some((j, _stolen)) = q.pop_for(w) {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 400);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 400, "every job claimed exactly once under stealing");
    }
}
