//! Cost-aware job dealing: a lock-free central queue that workers pull from.
//!
//! Jobs are enqueued in LPT (longest-processing-time-first) order by the
//! plan's `|S_i|·|S_j|` cost estimate; each idle worker atomically claims the
//! next-heaviest unclaimed job. This is the classical self-scheduling /
//! work-stealing-from-one-deck arrangement: the deal adapts to observed
//! speed (a slow worker simply claims fewer jobs), replacing the fixed
//! round-robin deal that pinned jobs to ranks regardless of load.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A shared, immutable job order with an atomic claim cursor.
#[derive(Debug)]
pub struct JobQueue {
    order: Vec<usize>,
    next: AtomicUsize,
}

impl JobQueue {
    /// Queue over `order` (typically [`ExecPlan::lpt_order`]). Each element
    /// is handed out exactly once across all threads.
    ///
    /// [`ExecPlan::lpt_order`]: crate::exec::ExecPlan
    pub fn new(order: Vec<usize>) -> Self {
        Self { order, next: AtomicUsize::new(0) }
    }

    /// Claim the next unclaimed job index, or `None` when drained.
    pub fn pop(&self) -> Option<usize> {
        let k = self.next.fetch_add(1, Ordering::Relaxed);
        self.order.get(k).copied()
    }

    /// Total jobs in the queue (claimed or not).
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex;

    #[test]
    fn pops_in_order_then_drains() {
        let q = JobQueue::new(vec![4, 2, 7]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "stays drained");
    }

    #[test]
    fn empty_queue() {
        let q = JobQueue::new(Vec::new());
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_claims_are_exactly_once() {
        let q = JobQueue::new((0..500).collect());
        let claimed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    while let Some(j) = q.pop() {
                        local.push(j);
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), 500);
        let distinct: HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 500, "every job claimed exactly once");
    }
}
